# Multi-model bucketed continuous-batching GNN serving (the paper's
# deployment story: offline preprocessing feeding the blocked
# aggregate/combine/update pipe, one engine serving a heterogeneous model
# catalog through pluggable schedulers and admission control).
from repro.serving.admission import (
    ADMISSION_POLICIES,
    AdmissionController,
    AdmissionStats,
)
from repro.serving.bucketing import (
    Bucket,
    bucket_for,
    next_pow2,
    node_mask_for_bucket,
    pad_features_to_bucket,
    pad_partition_to_bucket,
)
from repro.serving.cache import (
    CacheEntry,
    CacheStats,
    PreprocessCache,
    graph_content_hash,
)
from repro.serving.engine import GnnServeEngine, QueueFullError, gcn_prepare
from repro.serving.registry import (
    ExecutorPool,
    HostGraphCatalog,
    HostGraphEntry,
    ModelEntry,
    ModelRegistry,
)
from repro.serving.router import EngineRouter
from repro.serving.report import (
    RequestRecord,
    ServeReport,
    build_report,
    slo_attainment_from,
)
from repro.serving.sampler import (
    HostGraph,
    SampleResult,
    gcn_sample_prepare,
    sample_khop,
)
from repro.serving.scheduler import (
    SCHEDULERS,
    DeadlineScheduler,
    FifoScheduler,
    GroupState,
    OccupancyScheduler,
    Scheduler,
    make_scheduler,
)
