"""Neighborhood-sampled node-query serving: the million-node intake path.

Everything the engine served before this module was a whole small graph.
GHOST's own motivating workloads — recommendation and social-network
analysis (paper Section 1) — are *node queries against one huge resident
graph* that never fits a single blocked forward.  Both GNN-acceleration
surveys in PAPERS.md (arXiv 2010.00130, arXiv 2306.14052) identify
GraphSAGE-style neighborhood sampling as the scalability lever for that
regime; this module supplies it:

  ``HostGraph``
      one large resident graph as a host-side (numpy) CSR *in-adjacency*
      store — ``indptr``/``indices`` over destination vertices, so the
      sampler can pull the in-neighborhood of any vertex in O(degree).
      Millions of nodes cost tens of MB; nothing here touches a device.
  ``sample_khop``
      deterministic per-layer fanout sampler: expand ``seeds`` for
      ``len(fanouts)`` hops (``None`` fanout = take every in-neighbor),
      then extract the sampled subgraph as an ordinary ``core.graph.Graph``
      the rest of the serving stack (partition cache, bucketing, vmapped
      executors) consumes unchanged.
  ``gcn_sample_prepare``
      the degree bookkeeping that keeps GCN normalization well-defined on
      sampled neighborhoods: symmetric-normalized edge weights computed
      from the *host graph's* degrees (not the truncated subgraph's), via
      the same float64 formula as ``Graph.gcn_edge_weights``.

Exactness contract (what the tests pin):

A full-fanout sample of the whole k-hop in-neighborhood reproduces the
full-graph blocked forward *bit-exactly* at the seed rows, on every
backend.  Two mechanisms make that true:

  * **Block-aligned local numbering.**  Local ids preserve host ids modulo
    ``align`` (pass ``align = lcm(V, N)``): the sampler keeps whole
    ``align``-sized host-id blocks, so every sampled vertex keeps its
    position inside its V- and N-group.  Each sampled adjacency tile is
    then a bitwise *restriction* of the corresponding full-graph tile —
    same values at the same within-tile positions — so the per-tile
    ``(V x N) @ (N x F)`` products and the tile-order accumulation match
    the full forward bit-for-bit (missing tiles contribute exact zeros).
    Unoccupied slots in a kept block are "ghost" rows: zero features, no
    edges, sliced away with the rest of the padding.
  * **Host-degree normalization.**  MEAN degrees are tile row sums, which
    under full fanout equal the full-graph degrees for every vertex whose
    output can reach a seed.  GCN's symmetric weights additionally involve
    the *source* vertex's degree — truncated at the sample frontier — so
    ``gcn_sample_prepare`` computes every weight from ``HostGraph``
    degrees instead.

Determinism: the sample for a given ``(rng_seed, vertex)`` pair never
depends on the batch it appears in, so a hot query node resamples the
identical subgraph on every request and the engine's content-hash cache
collapses them onto one partition entry (sampled-query cache hits are the
whole point of a fixed rng policy).
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import NamedTuple, Optional, Sequence

import numpy as np

from repro.core.graph import Graph


@dataclasses.dataclass
class HostGraph:
    """One large resident graph, host-side, CSR over *in*-edges.

    Attributes:
      indptr: [Nv + 1] int64 — in-edge CSR offsets per destination vertex.
      indices: [E] int32 — source vertex of each in-edge, ascending within
        each destination's slice (ties = parallel edges are kept: the
        partitioner accumulates them exactly like the edge list would).
      features: [Nv, F] float node features (dtype preserved end-to-end).
      has_loop: [Nv] bool — vertex already carries a self-loop (consumed by
        the GCN degree bookkeeping, which must not double-count it).
      fingerprint: content hash of the *structure* (not the features, which
        enter per-request): distinguishes cache entries sampled from
        different host graphs, and will version delta updates later.
    """

    indptr: np.ndarray
    indices: np.ndarray
    features: np.ndarray
    has_loop: np.ndarray
    fingerprint: str
    name: str = "host"

    @property
    def num_nodes(self) -> int:
        return int(self.indptr.shape[0]) - 1

    @property
    def num_edges(self) -> int:
        return int(self.indices.shape[0])

    @property
    def num_features(self) -> int:
        return int(self.features.shape[1])

    def in_degrees(self) -> np.ndarray:
        return np.diff(self.indptr)

    def in_neighbors(self, v: int) -> np.ndarray:
        return self.indices[self.indptr[v]: self.indptr[v + 1]]

    @classmethod
    def from_edges(cls, edge_src: np.ndarray, edge_dst: np.ndarray,
                   features: np.ndarray, name: str = "host") -> "HostGraph":
        """Build the CSR store from an edge list (A[dst, src] convention)."""
        nv = int(features.shape[0])
        edge_src = np.asarray(edge_src, dtype=np.int64)
        edge_dst = np.asarray(edge_dst, dtype=np.int64)
        if edge_src.shape != edge_dst.shape:
            raise ValueError("edge_src/edge_dst shape mismatch")
        if edge_src.size and (edge_src.min() < 0 or edge_dst.min() < 0
                              or edge_src.max() >= nv or edge_dst.max() >= nv):
            raise ValueError("edge endpoint out of range")
        order = np.lexsort((edge_src, edge_dst))
        src, dst = edge_src[order], edge_dst[order]
        indptr = np.zeros(nv + 1, dtype=np.int64)
        np.add.at(indptr, dst + 1, 1)
        indptr = np.cumsum(indptr)
        has_loop = np.zeros(nv, dtype=bool)
        has_loop[dst[src == dst]] = True
        h = hashlib.sha1()
        h.update(np.int64(nv).tobytes())
        h.update(indptr.tobytes())
        h.update(src.astype(np.int32).tobytes())
        return cls(indptr=indptr, indices=src.astype(np.int32),
                   features=features, has_loop=has_loop,
                   fingerprint=h.hexdigest(), name=name)

    @classmethod
    def from_graph(cls, graph: Graph, name: Optional[str] = None) -> "HostGraph":
        return cls.from_edges(graph.edge_src, graph.edge_dst, graph.node_feat,
                              name=name or graph.name)

    @classmethod
    def synthetic_power_law(cls, num_nodes: int, avg_degree: int = 8,
                            num_features: int = 16, seed: int = 0,
                            exponent: float = 1.1,
                            name: str = "power_law") -> "HostGraph":
        """Skewed synthetic social/recommendation graph for demos and sweeps.

        Destination endpoints are uniform (every user has a neighborhood);
        source endpoints follow a Zipf-like propensity over a random node
        permutation, so a few hub vertices appear in a large fraction of
        neighborhoods — the degree skew neighborhood sampling exists to tame.
        """
        rng = np.random.default_rng(seed)
        num_edges = num_nodes * avg_degree
        ranks = np.arange(1, num_nodes + 1, dtype=np.float64)
        p = ranks ** (-exponent)
        p /= p.sum()
        perm = rng.permutation(num_nodes)
        src = perm[rng.choice(num_nodes, size=num_edges, p=p)]
        dst = rng.integers(0, num_nodes, num_edges)
        feat = rng.standard_normal((num_nodes, num_features)).astype(np.float32)
        return cls.from_edges(src.astype(np.int64), dst.astype(np.int64),
                              feat, name=name)


class SampleResult(NamedTuple):
    """One sampled k-hop subgraph, laid out for the blocked pipeline.

    ``graph`` is ghost-padded: local rows whose ``host_ids`` entry is -1
    are unoccupied slots of a kept ``align`` block (zero features, no
    edges).  ``num_sampled_nodes``/``num_sampled_edges`` count the real
    content; ``graph.num_nodes`` counts rows including ghosts.
    """

    graph: Graph            # sampled subgraph (ghost-padded, edges sorted)
    seed_rows: np.ndarray   # [S] int32 local row of each input seed, in order
    host_ids: np.ndarray    # [graph.num_nodes] int64 host id per row, -1=ghost
    num_sampled_nodes: int
    num_sampled_edges: int
    fanouts: tuple
    rng_seed: int

    @property
    def real_rows(self) -> np.ndarray:
        return np.flatnonzero(self.host_ids >= 0).astype(np.int32)


def _gather_csr(host: HostGraph, targets: np.ndarray
                ) -> tuple[np.ndarray, np.ndarray]:
    """All in-edges of ``targets``: (src, dst) host-id arrays, vectorized."""
    deg = host.indptr[targets + 1] - host.indptr[targets]
    total = int(deg.sum())
    if total == 0:
        return (np.zeros(0, np.int64),) * 2
    starts = host.indptr[targets]
    # Range-gather: positions [start_i, start_i + deg_i) for every target.
    offs = np.arange(total, dtype=np.int64) - np.repeat(
        np.cumsum(deg) - deg, deg)
    idx = np.repeat(starts, deg) + offs
    return host.indices[idx].astype(np.int64), np.repeat(targets, deg)


def sample_khop(
    host: HostGraph,
    seeds: Sequence[int],
    fanouts: Sequence[Optional[int]],
    rng_seed: int = 0,
    align: int = 1,
) -> SampleResult:
    """Deterministic per-layer fanout sample of the k-hop in-neighborhood.

    Layer ``l`` (l = 1..len(fanouts)) draws up to ``fanouts[l-1]``
    in-neighbors (without replacement; ``None`` = all of them) for every
    vertex first reached at layer ``l-1``; sampled sources join the node
    set and become the next frontier.  A vertex's draw depends only on
    ``(rng_seed, vertex)`` — never on the batch — so hot query nodes
    resample identical subgraphs and collapse onto one partition-cache
    entry.

    ``align`` controls the local numbering: host-id blocks of this size
    are kept whole (unsampled slots become ghost rows), which preserves
    every vertex's position modulo ``align``.  Pass ``lcm(V, N)`` to make
    sampled adjacency tiles bitwise restrictions of the full graph's
    (the engine does); ``align=1`` gives plain compaction.

    Returns the subgraph with edges sorted by (dst, src) — a canonical
    byte layout, so identical samples content-hash identically.
    """
    seeds = np.asarray(seeds, dtype=np.int64)
    if seeds.ndim != 1 or seeds.size == 0:
        raise ValueError("seeds must be a non-empty 1-D sequence of node ids")
    if seeds.min() < 0 or seeds.max() >= host.num_nodes:
        raise ValueError(
            f"seed out of range [0, {host.num_nodes}): "
            f"{seeds[(seeds < 0) | (seeds >= host.num_nodes)][:4]}")
    if align < 1:
        raise ValueError("align must be >= 1")
    fanouts = tuple(fanouts)
    for f in fanouts:
        if f is not None and f < 1:
            raise ValueError(f"fanouts must be positive or None, got {f}")

    node_set = np.unique(seeds)
    frontier = node_set
    src_parts: list[np.ndarray] = []
    dst_parts: list[np.ndarray] = []
    for fanout in fanouts:
        if frontier.size == 0:
            break
        src, dst = _gather_csr(host, frontier)
        if fanout is not None and src.size:
            deg = host.indptr[frontier + 1] - host.indptr[frontier]
            over = frontier[deg > fanout]
            if over.size:
                keep = np.ones(src.size, dtype=bool)
                # Per-vertex deterministic draw: seeded by (rng_seed, v)
                # only, so the subsample never depends on batch
                # composition.  dst is grouped by frontier order, so each
                # over-fanout vertex owns one contiguous slice.
                bounds = np.cumsum(deg) - deg
                pos = {int(v): int(b) for v, b in zip(frontier, bounds)}
                dmap = dict(zip(frontier.tolist(), deg.tolist()))
                for v in over:
                    d, b = dmap[int(v)], pos[int(v)]
                    rng = np.random.default_rng((rng_seed, int(v)))
                    chosen = rng.choice(d, size=fanout, replace=False)
                    drop = np.ones(d, dtype=bool)
                    drop[chosen] = False
                    keep[b: b + d] &= ~drop
                src, dst = src[keep], dst[keep]
        src_parts.append(src)
        dst_parts.append(dst)
        grown = np.union1d(node_set, src)
        frontier = np.setdiff1d(grown, node_set, assume_unique=True)
        node_set = grown

    edge_src = (np.concatenate(src_parts) if src_parts
                else np.zeros(0, np.int64))
    edge_dst = (np.concatenate(dst_parts) if dst_parts
                else np.zeros(0, np.int64))

    # Block-aligned local numbering: keep whole align-sized host-id blocks.
    blocks = np.unique(node_set // align)
    num_local = int(blocks.size) * align

    def to_local(h: np.ndarray) -> np.ndarray:
        return np.searchsorted(blocks, h // align) * align + h % align

    host_ids = np.full(num_local, -1, dtype=np.int64)
    host_ids[to_local(node_set)] = node_set
    feat = np.zeros((num_local, host.num_features), host.features.dtype)
    feat[to_local(node_set)] = host.features[node_set]

    src_l = to_local(edge_src)
    dst_l = to_local(edge_dst)
    order = np.lexsort((src_l, dst_l))
    graph = Graph(
        edge_src=src_l[order].astype(np.int32),
        edge_dst=dst_l[order].astype(np.int32),
        node_feat=feat,
        name=f"{host.name}:sample",
    )
    return SampleResult(
        graph=graph,
        seed_rows=to_local(seeds).astype(np.int32),
        host_ids=host_ids,
        num_sampled_nodes=int(node_set.size),
        num_sampled_edges=int(edge_src.size),
        fanouts=fanouts,
        rng_seed=rng_seed,
    )


def gcn_sample_prepare(sample: SampleResult, host: HostGraph
                       ) -> tuple[Graph, np.ndarray]:
    """GCN preprocessing for a sampled subgraph, with host-degree weights.

    Mirrors ``serving.engine.gcn_prepare`` (self-loops + symmetric
    normalization) but takes every degree from the *host* graph: the
    subgraph truncates the in-edges of frontier vertices, and normalizing
    by the truncated degree would silently re-weight every message those
    vertices send inward.  Weights use the same float64 expression as
    ``Graph.gcn_edge_weights``, so under full fanout each per-edge weight
    is bitwise identical to the full-graph one.

    Self-loops are added for real (sampled) rows only — ghost rows carry
    no edges at all, exactly like the padding they are.
    """
    g = sample.graph
    real = sample.real_rows
    hosts = sample.host_ids[real]
    # With-self-loop degree: the host in-degree plus the loop this prepare
    # adds (unless the host vertex already carries one).
    deg = np.zeros(g.num_nodes, dtype=np.int64)
    deg[real] = host.in_degrees()[hosts] + np.where(host.has_loop[hosts], 0, 1)
    loop_rows = real[~host.has_loop[hosts]].astype(np.int32)
    g2 = dataclasses.replace(
        g,
        edge_src=np.concatenate([g.edge_src, loop_rows]),
        edge_dst=np.concatenate([g.edge_dst, loop_rows]),
    )
    degf = deg.astype(np.float64)
    w = 1.0 / np.sqrt(np.maximum(degf[g2.edge_dst], 1)
                      * np.maximum(degf[g2.edge_src], 1))
    return g2, w.astype(np.float32)
