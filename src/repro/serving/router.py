"""Replica router: N serving engines behind one submit/result surface.

One ``GnnServeEngine`` is one executor pool on one mesh — scaling past a
single pool means running several engine *replicas* and routing requests
between them.  ``EngineRouter`` owns that seam:

  placement (catalog-aware)
      "hot" models register on every replica, so any replica can absorb
      their traffic; "cold" models pin to exactly one replica (fewest
      pinned models first), so the long tail of rarely-served models costs
      one trace set instead of N.  Placement is decided at ``register``
      time and never migrates — a model's compiled executors live where
      its traffic lands.
  routing (slack-aware, admission-respecting)
      each request goes to the eligible replica with the smallest
      *estimated completion slack cost*: queued batches times the
      replica's learned expected service time (``engine.queue_pressure``),
      tie-broken by raw queue length.  Two replicas with equal queue
      depths but different catalogs (one serving 2 ms batches, one 40 ms)
      are not equally loaded — time-weighted backlog routes around the
      slow one where raw queue length cannot.  Before any service time is
      learned every backlog estimate is 0.0 and the tie-break reproduces
      shortest-queue routing exactly.  When the chosen replica's
      admission controller rejects, the router falls back through the
      remaining eligible replicas (in the same order) before giving up.
      Every replica keeps its own admission bound — overload on one hot
      replica sheds there without disturbing the others.
  identity (global rids)
      replica-local rids never leak: the router hands out global rids and
      keeps the (replica, local rid) mapping for ``take_result`` /
      ``result``.  The mapping is lock-protected, so routing is safe from
      many client threads (each replica's intake is already thread-safe).
  lifecycle (always-on passthrough)
      ``start()``/``stop()`` start and stop every replica's serve loop;
      ``result(rid)`` blocks on the owning replica.  Tick-driven
      ``step``/``drain``/``run`` remain for closed-loop use.
  accounting (merged + per-replica)
      ``report`` folds every replica's records into one ``ServeReport``
      (same math a single engine would produce for the union stream —
      including SLO attainment, merged across replicas from the union
      record set) and fills ``ServeReport.replicas`` with per-replica
      served counts, admission outcomes, per-replica attainment, and mesh
      topology — the dashboard view of where traffic actually went.

The replicas are plain engines: everything pluggable on an engine
(scheduler, admission policy, backend, tuner, mesh) is pluggable per
router via ``**engine_kwargs``, applied uniformly to every replica.
``meshes=`` overrides that uniformity for device placement — one mesh per
replica, so a host's devices can be split between replicas rather than
shared.
"""

from __future__ import annotations

import threading
import time
from typing import Optional, Sequence

import numpy as np

from repro.core.graph import Graph
from repro.serving.admission import AdmissionStats
from repro.serving.cache import CacheStats
from repro.serving.engine import GnnServeEngine, QueueFullError
from repro.serving.report import ServeReport, build_report, slo_attainment_from
from repro.serving.sampler import HostGraph


class EngineRouter:
    """Catalog-aware request router over ``GnnServeEngine`` replicas.

    Args:
      num_replicas: how many engine replicas to build (>= 1).
      meshes: optional sequence of one mesh (or None) per replica, so
        replicas can own disjoint device slices; without it every replica
        shares whatever ``mesh=`` is in ``engine_kwargs`` (usually None).
      engine_kwargs: forwarded verbatim to every ``GnnServeEngine``.
    """

    def __init__(self, num_replicas: int = 2, *, meshes=None, **engine_kwargs):
        if num_replicas < 1:
            raise ValueError("num_replicas must be >= 1")
        if meshes is not None:
            meshes = list(meshes)
            if len(meshes) != num_replicas:
                raise ValueError(
                    f"meshes has {len(meshes)} entries for "
                    f"{num_replicas} replicas")
            if "mesh" in engine_kwargs:
                raise ValueError("pass either meshes= or mesh=, not both")
        self.replicas: list[GnnServeEngine] = []
        for i in range(num_replicas):
            kwargs = dict(engine_kwargs)
            if meshes is not None:
                kwargs["mesh"] = meshes[i]
            self.replicas.append(GnnServeEngine(**kwargs))
        # model_id -> tuple of eligible replica indices (len>1 iff hot).
        self._placement: dict[str, tuple[int, ...]] = {}
        # host graph name -> tuple of replica indices holding a copy.
        self._host_placement: dict[str, tuple[int, ...]] = {}
        self._pinned_count = [0] * num_replicas  # cold models per replica
        # global rid -> (replica index, replica-local rid); guarded by
        # _rid_lock so concurrent client threads can route safely.
        self._rid_map: dict[int, tuple[int, int]] = {}
        self._next_rid = 0
        self._rid_lock = threading.Lock()

    @property
    def num_replicas(self) -> int:
        return len(self.replicas)

    # ------------------------------------------------------------------
    # Catalog placement.
    # ------------------------------------------------------------------

    def register(self, model_id: str, model, params, *, hot: bool = False,
                 replica: Optional[int] = None, **kwargs) -> tuple[int, ...]:
        """Place one model and register it on its replica(s).

        hot=True registers on every replica (traffic spreads by load);
        otherwise the model pins to ``replica`` if given, else to the
        replica carrying the fewest pinned models.  Returns the tuple of
        replica indices serving this model.
        """
        if model_id in self._placement:
            raise ValueError(f"model_id '{model_id}' already placed")
        if hot:
            if replica is not None:
                raise ValueError("hot models go to every replica; "
                                 "replica= only applies to cold models")
            where = tuple(range(self.num_replicas))
        else:
            if replica is None:
                replica = int(np.argmin(self._pinned_count))
            if not 0 <= replica < self.num_replicas:
                raise ValueError(f"replica {replica} out of range "
                                 f"[0, {self.num_replicas})")
            self._pinned_count[replica] += 1
            where = (replica,)
        for i in where:
            self.replicas[i].register(model_id, model, params, **kwargs)
        self._placement[model_id] = where
        return where

    def placement(self, model_id: str) -> tuple[int, ...]:
        where = self._placement.get(model_id)
        if where is None:
            raise KeyError(f"unknown model_id '{model_id}'; placed: "
                           f"{list(self._placement)}")
        return where

    def register_host_graph(
        self, name: str, host: HostGraph, *,
        replicas: Optional[Sequence[int]] = None,
        fanouts: Sequence[Optional[int]] = (10, 10),
        rng_seed: int = 0,
    ) -> tuple[int, ...]:
        """Place one resident ``HostGraph`` for node-query serving.

        ``replicas=None`` registers the store on every replica (the numpy
        CSR is host memory, cheap to share in-process); an explicit index
        list pins it — node queries then only route to replicas holding
        the graph.  Returns the tuple of holding replica indices.
        """
        if name in self._host_placement:
            raise ValueError(f"host graph '{name}' already placed")
        if replicas is None:
            where = tuple(range(self.num_replicas))
        else:
            where = tuple(sorted(set(int(i) for i in replicas)))
            if not where:
                raise ValueError("replicas must name at least one replica")
            if where[0] < 0 or where[-1] >= self.num_replicas:
                raise ValueError(f"replica index out of range "
                                 f"[0, {self.num_replicas}): {where}")
        for i in where:
            self.replicas[i].register_host_graph(
                name, host, fanouts=fanouts, rng_seed=rng_seed)
        self._host_placement[name] = where
        return where

    def host_placement(self, name: str) -> tuple[int, ...]:
        where = self._host_placement.get(name)
        if where is None:
            raise KeyError(f"unknown host graph '{name}'; placed: "
                           f"{list(self._host_placement)}")
        return where

    # ------------------------------------------------------------------
    # Request intake and routing.
    # ------------------------------------------------------------------

    @property
    def num_waiting(self) -> int:
        return sum(e.num_waiting for e in self.replicas)

    def try_submit(self, model_id: str, graph: Graph) -> Optional[int]:
        """Route one request; returns a global rid or None when every
        eligible replica's admission controller rejected it."""
        where = self.placement(model_id)
        # Least-estimated-backlog first among eligible replicas (queued
        # batches x learned service time, raw queue length as tie-break —
        # see queue_pressure); on rejection fall back to the next (per-
        # replica admission, router-level failover).  Sort is stable, so
        # fully tied replicas keep placement order.
        order = sorted(where, key=lambda i: self.replicas[i].queue_pressure())
        for i in order:
            local = self.replicas[i].try_submit(model_id, graph)
            if local is not None:
                return self._alloc_rid(i, local)
        return None

    def _alloc_rid(self, replica: int, local: int) -> int:
        with self._rid_lock:
            rid = self._next_rid
            self._next_rid += 1
            self._rid_map[rid] = (replica, local)
            return rid

    def submit(self, model_id: str, graph: Graph) -> int:
        rid = self.try_submit(model_id, graph)
        if rid is None:
            raise QueueFullError(
                f"all {len(self.placement(model_id))} eligible replicas "
                f"rejected model '{model_id}' (waiting queues full)")
        return rid

    def try_submit_nodes(self, model_id: str, seed_ids, *,
                         host: Optional[str] = None,
                         **kwargs) -> Optional[int]:
        """Route one node query to a replica holding both the model and the
        host graph (least estimated backlog first, admission failover);
        returns a global rid or None when every such replica rejected it."""
        where_m = self.placement(model_id)
        if host is None:
            if len(self._host_placement) != 1:
                raise ValueError(
                    "node queries without host= need exactly one placed "
                    f"host graph; router holds {list(self._host_placement)}")
            host = next(iter(self._host_placement))
        where_h = set(self.host_placement(host))
        eligible = [i for i in where_m if i in where_h]
        if not eligible:
            raise ValueError(
                f"no replica holds both model '{model_id}' ({where_m}) and "
                f"host graph '{host}' ({sorted(where_h)})")
        order = sorted(eligible,
                       key=lambda i: self.replicas[i].queue_pressure())
        for i in order:
            local = self.replicas[i].try_submit_nodes(
                model_id, seed_ids, host=host, **kwargs)
            if local is not None:
                return self._alloc_rid(i, local)
        return None

    def submit_nodes(self, model_id: str, seed_ids, **kwargs) -> int:
        rid = self.try_submit_nodes(model_id, seed_ids, **kwargs)
        if rid is None:
            raise QueueFullError(
                f"all replicas eligible for node queries on '{model_id}' "
                "rejected the request (waiting queues full)")
        return rid

    # ------------------------------------------------------------------
    # Serving.
    # ------------------------------------------------------------------

    def start(self) -> "EngineRouter":
        """Start every replica's always-on serve loop."""
        for e in self.replicas:
            e.start()
        return self

    def stop(self, drain: bool = True) -> None:
        """Stop every replica's serve loop (draining by default)."""
        errors = []
        for e in self.replicas:
            try:
                e.stop(drain=drain)
            except RuntimeError as exc:  # keep stopping the rest
                errors.append(exc)
        if errors:
            raise errors[0]

    def result(self, rid: int, timeout: Optional[float] = None) -> np.ndarray:
        """Blocking pickup by global rid (see ``GnnServeEngine.result``)."""
        with self._rid_lock:
            replica, local = self._rid_map.pop(rid)
        try:
            return self.replicas[replica].result(local, timeout=timeout)
        except TimeoutError:
            with self._rid_lock:  # not delivered: keep the mapping alive
                self._rid_map[rid] = (replica, local)
            raise

    def step(self) -> int:
        """One tick on every replica with waiting work; returns total served."""
        return sum(e.step() for e in self.replicas if e.num_waiting)

    def drain(self) -> int:
        total = 0
        while True:
            served = self.step()
            if not served:
                return total
            total += served

    def run(self, requests) -> ServeReport:
        """Submit a stream, drain every replica, and build the merged report.

        Mirrors ``GnnServeEngine.run`` closed-loop semantics: when every
        eligible replica is at its admission bound the router serves ticks
        until one frees up instead of rejecting.
        """
        t0 = time.perf_counter()
        for item in requests:
            if isinstance(item, Graph):
                if len(self._placement) != 1:
                    raise ValueError(
                        "bare-graph requests need exactly one placed model; "
                        f"router holds {list(self._placement)}")
                model_id, graph = next(iter(self._placement)), item
            else:
                model_id, graph = item
            while True:
                rid = self.try_submit(model_id, graph)
                if rid is not None:
                    break
                if not self.step():
                    raise RuntimeError(
                        "request rejected with no waiting work to drain")
        self.drain()
        return self.report(time.perf_counter() - t0)

    def take_result(self, rid: int) -> np.ndarray:
        """Pop one result by global rid (KeyError if absent/already taken)."""
        with self._rid_lock:
            replica, local = self._rid_map.pop(rid)
        return self.replicas[replica].take_result(local)

    # ------------------------------------------------------------------
    # Merged accounting.
    # ------------------------------------------------------------------

    def report(self, wall_s: float) -> ServeReport:
        records = []
        cache = CacheStats()
        admission = AdmissionStats()
        per_replica: dict[str, dict] = {}
        wait_ticks, wait_s = 0, 0.0
        for i, e in enumerate(self.replicas):
            replica_records = list(e.records)
            records.extend(replica_records)
            cache.hits += e.cache.stats.hits
            cache.misses += e.cache.stats.misses
            cache.evictions += e.cache.stats.evictions
            admission.admitted += e.admission.stats.admitted
            admission.rejected += e.admission.stats.rejected
            admission.shed += e.admission.stats.shed
            admission.unmeetable += e.admission.stats.unmeetable
            t, s = e.queue_wait_gauges()
            wait_ticks, wait_s = max(wait_ticks, t), max(wait_s, s)
            served: dict[str, int] = {}
            for r in replica_records:
                served[r.model_id] = served.get(r.model_id, 0) + 1
            per_replica[f"replica{i}"] = {
                "served": len(replica_records),
                "per_model": served,
                "admitted": e.admission.stats.admitted,
                "rejected": e.admission.stats.rejected,
                "unmeetable": e.admission.stats.unmeetable,
                "shed": e.admission.stats.shed,
                "slo_attainment": slo_attainment_from(replica_records),
                "traces_compiled": e.pool.trace_count,
                "topology": e.pool.topology(),
                "kernel_configs": e.pool.kernel_configs(),
                "service_time_ms": e.service_time_ms(),
                "pipeline": e.pipeline_stats(),
            }
        first = self.replicas[0]
        # The merged ServeReport computes union-stream SLO attainment from
        # the concatenated records itself (build_report -> slo_attainment_
        # from), so cross-replica attainment needs no extra merge step.
        return build_report(
            records, wall_s, cache,
            sum(e.pool.trace_count for e in self.replicas),
            first.backend,
            scheduler=first.scheduler.name,
            admission_stats=admission,
            queue_max_wait_ticks=wait_ticks,
            queue_max_wait_s=wait_s,
            kernel_configs=self._merged_kernel_configs(),
            topology=self._merged_topology(),
            replicas=per_replica,
            service_time_ms=self._merged_service_times(),
            pipeline=self._merged_pipeline(),
        )

    def _merged_service_times(self) -> dict:
        """Mean expected service time per key across replicas that know it.

        The replicas run on one host here, so a cross-replica mean is a
        fair summary; replica-exact EWMAs stay in
        ``ServeReport.replicas[...]["service_time_ms"]``.
        """
        sums: dict[str, float] = {}
        counts: dict[str, int] = {}
        for e in self.replicas:
            for key, ms in e.service_time_ms().items():
                sums[key] = sums.get(key, 0.0) + ms
                counts[key] = counts.get(key, 0) + 1
        return {key: sums[key] / counts[key] for key in sums}

    def _merged_pipeline(self) -> dict:
        """Summed per-stage busy seconds over all replicas (the router's
        replicas share one configured depth; per-replica splits stay in
        ``ServeReport.replicas``)."""
        stats = [e.pipeline_stats() for e in self.replicas]
        return {
            "depth": stats[0]["depth"],
            "stack_busy_s": sum(s["stack_busy_s"] for s in stats),
            "exec_busy_s": sum(s["exec_busy_s"] for s in stats),
        }

    def _merged_kernel_configs(self) -> dict:
        """Union of every replica's live kernel configs.

        Taking replica 0's view alone would silently drop everything
        replicas 1..N-1 compiled (per-replica tuners resolve their own
        winners; heterogeneous pools can pin different overrides).  Keys
        agreeing across replicas merge; a key whose config *differs* from
        the one already merged is kept under a ``replicaI:`` prefix so no
        resolution is lost.  Full per-replica views live in
        ``ServeReport.replicas[...]["kernel_configs"]``.
        """
        merged: dict = {}
        for i, e in enumerate(self.replicas):
            for key, cfg in e.pool.kernel_configs().items():
                if key not in merged:
                    merged[key] = cfg
                elif merged[key] != cfg:
                    merged[f"replica{i}:{key}"] = cfg
        return merged

    def _merged_topology(self) -> dict:
        """One topology when the replicas agree; an aggregate otherwise.

        Uniform replicas (the common case) report their shared mesh
        unchanged.  With per-replica meshes the merged view sums the
        device counts and marks itself heterogeneous — per-replica meshes
        stay in ``ServeReport.replicas[...]["topology"]``.
        """
        topos = [e.pool.topology() for e in self.replicas]
        if not any(topos):
            return {}
        if all(t == topos[0] for t in topos):
            return dict(topos[0])
        return {
            # A replica without a mesh still occupies one device.
            "num_devices": sum(t.get("num_devices", 1) for t in topos),
            "heterogeneous": True,
            "mesh_shapes": {f"replica{i}": t.get("mesh_shape")
                            for i, t in enumerate(topos)},
        }

    def reset_metrics(self) -> None:
        for e in self.replicas:
            e.reset_metrics()
