"""Model registry + executor pool: the multi-model seam of the engine.

GHOST's pitch (paper Section 4.1) is one substrate serving GCN / GraphSAGE /
GAT / GIN alike; the serving-side analogue is one engine serving a
heterogeneous *catalog*.  Two pieces:

  * ``ModelRegistry`` — named catalog entries (``ModelEntry``): the model
    object and params plus everything per-model the engine used to take as
    constructor state (task, analytic spec, quantization, prepare
    transform, dataset label, feature width).  Registration fail-fast
    validates the task/model contract.
  * ``ExecutorPool`` — compiled vmapped blocked forwards keyed by
    ``(model_id, Bucket)``.  Each executor is one jit trace; the pool is
    the engine's whole compilation state, so the trace count is bounded by
    |models| x |buckets observed|.

Executors accept feature batches at the *bucket's* padded feature width and
slice back to the model's true ``f_in`` inside the trace — the zero padding
columns never enter the arithmetic, so per-request outputs stay bit-exact
vs the unbatched ``apply_blocked`` while models with different feature
widths share the host-side batching machinery.
"""

from __future__ import annotations

import dataclasses
import threading
from collections import OrderedDict
from typing import Callable, Iterator, Optional

import jax
import jax.numpy as jnp

from repro.core.aggregate import (
    AGGREGATE_BACKENDS,
    BlockedGraph,
    aggregate_backend,
    kernel_config_scope,
    shard_scope,
    with_degrees,
)
from repro.serving.bucketing import Bucket


@dataclasses.dataclass
class ModelEntry:
    """One catalog entry: a model plus its per-model serving config."""

    model_id: str
    model: object
    params: object
    task: str                      # "node" | "graph"
    f_in: int                      # true (unpadded) input feature width
    spec: Optional[object] = None  # GnnModelSpec for analytic hw costing
    quantized: bool = False
    prepare_fn: Optional[Callable] = None
    dataset_name: str = "served"
    # Per-model latency contract: every request for this model carries the
    # deadline ``t_submit + slo_ms`` into scheduling (DeadlineScheduler
    # preempts for at-risk heads, deadline-aware shed drops the least
    # salvageable victim) and into accounting (``RequestRecord.slo_met``,
    # per-model p99-vs-SLO attainment in the serve report).  None = no
    # contract: infinite slack, excluded from attainment.
    slo_ms: Optional[float] = None
    # Sampled-serving counterpart of prepare_fn: maps a
    # ``(SampleResult, HostGraph)`` pair to ``(graph, edge_weights)``, with
    # degree bookkeeping taken from the host graph (subgraph degrees
    # undercount frontier vertices).  Models with no prepare_fn need none;
    # models with a prepare_fn cannot serve node queries without one.
    sample_prepare_fn: Optional[Callable] = None

    @property
    def salt(self) -> str:
        """Cache-key salt: identifies the prepare transform, not the model,
        so models sharing a transform share preprocessing artifacts."""
        return self.prepare_fn.__qualname__ if self.prepare_fn else ""

    @property
    def sample_salt(self) -> str:
        """Cache-key salt for the sampled intake path (distinct from the
        whole-graph path: same raw structure, different transform)."""
        fn = self.sample_prepare_fn
        return "sampled:" + (fn.__qualname__ if fn else "")


class ModelRegistry:
    """Named, validated catalog of servable models."""

    def __init__(self):
        self._entries: "OrderedDict[str, ModelEntry]" = OrderedDict()

    def register(
        self,
        model_id: str,
        model,
        params,
        *,
        task: str = "node",
        spec=None,
        quantized: bool = False,
        prepare_fn: Optional[Callable] = None,
        dataset_name: str = "served",
        f_in: Optional[int] = None,
        sample_prepare_fn: Optional[Callable] = None,
        slo_ms: Optional[float] = None,
    ) -> ModelEntry:
        if model_id in self._entries:
            raise ValueError(f"model_id '{model_id}' already registered")
        if task not in ("node", "graph"):
            raise ValueError(f"unknown task '{task}'")
        if slo_ms is not None and slo_ms <= 0:
            raise ValueError("slo_ms must be positive (or None = no SLO)")
        if task == "graph" and not (hasattr(model, "node_embed_blocked")
                                    and hasattr(model, "readout")):
            raise ValueError(
                "task='graph' needs a model with node_embed_blocked + "
                "readout (e.g. GIN); node-level models serve task='node'")
        if not hasattr(model, "apply_blocked"):
            raise ValueError("model must expose apply_blocked(...)")
        if f_in is None:
            f_in = getattr(model, "f_in", None)
        if f_in is None or f_in < 1:
            raise ValueError("pass f_in= (model has no f_in attribute)")
        entry = ModelEntry(
            model_id=model_id, model=model, params=params, task=task,
            f_in=int(f_in), spec=spec, quantized=quantized,
            prepare_fn=prepare_fn, dataset_name=dataset_name,
            sample_prepare_fn=sample_prepare_fn,
            slo_ms=float(slo_ms) if slo_ms is not None else None)
        self._entries[model_id] = entry
        return entry

    def __getitem__(self, model_id: str) -> ModelEntry:
        entry = self._entries.get(model_id)
        if entry is None:
            raise KeyError(f"unknown model_id '{model_id}'; registered: "
                           f"{list(self._entries)}")
        return entry

    def __contains__(self, model_id: str) -> bool:
        return model_id in self._entries

    def __len__(self) -> int:
        return len(self._entries)

    def __iter__(self) -> Iterator[ModelEntry]:
        return iter(self._entries.values())

    def ids(self) -> list[str]:
        return list(self._entries)

    @property
    def sole_id(self) -> str:
        """The single registered model id (bare-graph request convenience)."""
        if len(self._entries) != 1:
            raise ValueError(
                "bare-graph requests need exactly one registered model; "
                f"registry holds {list(self._entries)}")
        return next(iter(self._entries))


class HostGraphCatalog:
    """Named resident ``HostGraph`` stores for the node-query intake path.

    The model catalog answers *which forward to run*; this catalog answers
    *which graph the query nodes live in*.  Each entry pins the serving
    policy alongside the store — default per-layer fanouts and the rng
    seed — because determinism is what lets hot query nodes resample
    identical subgraphs and share one partition-cache entry.
    """

    def __init__(self):
        self._entries: "OrderedDict[str, HostGraphEntry]" = OrderedDict()

    def register(self, name: str, host, *,
                 fanouts=(10, 10), rng_seed: int = 0) -> "HostGraphEntry":
        if name in self._entries:
            raise ValueError(f"host graph '{name}' already registered")
        fanouts = tuple(fanouts)
        if not fanouts:
            raise ValueError("fanouts must name at least one sampled layer")
        entry = HostGraphEntry(name=name, host=host, fanouts=fanouts,
                               rng_seed=int(rng_seed))
        self._entries[name] = entry
        return entry

    def __getitem__(self, name: str) -> "HostGraphEntry":
        entry = self._entries.get(name)
        if entry is None:
            raise KeyError(f"unknown host graph '{name}'; registered: "
                           f"{list(self._entries)}")
        return entry

    def __contains__(self, name: str) -> bool:
        return name in self._entries

    def __len__(self) -> int:
        return len(self._entries)

    def ids(self) -> list[str]:
        return list(self._entries)

    @property
    def sole_id(self) -> str:
        """The single registered host graph (bare submit_nodes convenience)."""
        if len(self._entries) != 1:
            raise ValueError(
                "submit_nodes without host= needs exactly one registered "
                f"host graph; catalog holds {list(self._entries)}")
        return next(iter(self._entries))


@dataclasses.dataclass
class HostGraphEntry:
    """One resident graph plus its sampling policy."""

    name: str
    host: object               # serving.sampler.HostGraph
    fanouts: tuple             # default per-layer fanouts (None = full)
    rng_seed: int = 0


class ExecutorPool:
    """Compiled vmapped blocked forwards, one per (model_id, bucket).

    ``backend`` selects the aggregation lowering baked into every trace the
    pool builds: "jnp" (oracle), "pallas" (unfused block_spmm), or
    "pallas_fused" (fused aggregate+combine epilogue kernel; the layer-level
    order planner then decides aggregate-first vs combine-first per layer).

    Per-site kernel configs resolve at trace-build time, in precedence
    order: ``kernel_config`` (one explicit config applied to every site —
    the deterministic override tests pin) beats ``tuner`` (a duck-typed
    ``kernels.autotune.Autotuner``-like object with ``resolve(site)``)
    beats the hardcoded defaults.  With a tuner, ``_build`` first runs the
    forward *abstractly* (``jax.eval_shape``) under a recording resolver to
    enumerate the trace's kernel sites — they are all-static Python values,
    so no compute runs — then tunes each off-trace (plain host timing,
    warm-started from the tuner's persisted cache), and only then builds
    the real jit with a lookup resolver.  Timing never happens inside a
    trace, and a warm cache makes the pre-pass pure lookup.
    """

    def __init__(self, slots: int, backend: str, *,
                 tuner=None, kernel_config=None, mesh=None,
                 shard_axis: str = "data"):
        if slots < 1:
            raise ValueError("slots must be >= 1")
        if backend not in AGGREGATE_BACKENDS:
            raise ValueError(f"unknown backend '{backend}'; expected one of "
                             f"{AGGREGATE_BACKENDS}")
        if mesh is not None and shard_axis not in mesh.axis_names:
            raise ValueError(f"mesh has no axis '{shard_axis}'; axes are "
                             f"{tuple(mesh.axis_names)}")
        self.slots = slots
        self.backend = backend
        self.tuner = tuner
        self.kernel_config = kernel_config
        # The pool's device topology: every trace it builds is keyed by
        # (model_id, bucket) *within* this mesh — a pool IS one mesh, so
        # the effective trace key is (model_id, bucket, mesh).  A 1-device
        # mesh is equivalent to no mesh (the shard router is a no-op).
        self.mesh = mesh
        self.shard_axis = shard_axis
        self._executors: dict[tuple[str, Bucket], Callable] = {}
        self._trace_count = 0
        # Pipelined serving runs several executor workers; the get-or-build
        # below must not race (a lost race would double-compile and skew
        # trace_count).  Build time under the lock is acceptable: it is
        # paid once per (model, bucket) and concurrent callers of a cold
        # key need the same trace anyway.
        self._lock = threading.Lock()

    @property
    def num_shards(self) -> int:
        if self.mesh is None:
            return 1
        return int(self.mesh.shape[self.shard_axis])

    def topology(self) -> dict:
        """Mesh topology baked into this pool's traces (report surface)."""
        if self.mesh is None:
            return {}
        return {
            "num_devices": self.num_shards,
            "mesh_shape": {a: int(s) for a, s in self.mesh.shape.items()},
            "shard_axis": self.shard_axis,
            "strategy": "feature" if self.num_shards > 1 else "none",
        }

    def kernel_configs(self) -> dict:
        """Shape-class -> config resolved so far (report surface)."""
        if self.kernel_config is not None:
            cfg = self.kernel_config
            to_dict = getattr(cfg, "to_dict", None)
            return {"*": to_dict() if to_dict else dict(vars(cfg))}
        if self.tuner is not None:
            return self.tuner.live_configs()
        return {}

    @property
    def trace_count(self) -> int:
        return self._trace_count

    def __len__(self) -> int:
        return len(self._executors)

    def executor(self, entry: ModelEntry, bucket: Bucket) -> Callable:
        key = (entry.model_id, bucket)
        with self._lock:
            exe = self._executors.get(key)
            if exe is None:
                exe = self._executors[key] = self._build(entry, bucket)
            return exe

    def _build(self, entry: ModelEntry, bucket: Bucket) -> Callable:
        model, task = entry.model, entry.task
        quantized, f_in = entry.quantized, entry.f_in
        backend = self.backend
        # The executor's static node count: padded rows past this are pure
        # padding on both the source and destination sides; per-request
        # validity is handled by host-side slicing.  The graph task runs the
        # blocked *embedding* batch-wide and leaves the sum-pool readout to
        # the per-request path (the fp32 pooled sum depends on row count, so
        # pooling at the bucket shape would break bit-exactness).
        num_nodes = min(bucket.padded_dst, bucket.padded_src)
        # A 1-device mesh is a no-op shard scope; None suppresses sharding
        # entirely, so the trace is identical to the meshless pool's.
        shard_mesh = self.mesh if self.num_shards > 1 else None
        shard_axis = self.shard_axis

        def make_fwd(resolver, count_trace):
            def fwd(params, blocks, row, col, feat):
                if count_trace:
                    self._trace_count += 1  # runs at trace time only
                feat = feat[:, :f_in]   # strip feature-dim bucket padding
                bg = BlockedGraph(
                    blocks=blocks, block_row=row, block_col=col,
                    num_dst_groups=bucket.num_dst_groups,
                    num_src_groups=bucket.num_src_groups,
                    v=bucket.v, n=bucket.n, num_nodes=num_nodes,
                )
                # Degrees are structure-static: reduce them once per forward
                # so every MEAN layer in the model shares the result (XLA
                # drops the reduction entirely for models that never read it).
                bg = with_degrees(bg)
                # Backend and kernel-config selections are read at trace
                # time, so they bake into this executor's compiled program.
                with aggregate_backend(backend), \
                        kernel_config_scope(resolver), \
                        shard_scope(shard_mesh, shard_axis):
                    if task == "graph":
                        return model.node_embed_blocked(params, bg, feat,
                                                        quantized)
                    return model.apply_blocked(params, bg, feat, quantized)
            return fwd

        resolver = self._resolve_sites(entry, bucket, make_fwd)
        batched = jax.vmap(make_fwd(resolver, count_trace=True),
                           in_axes=(None, 0, 0, 0, 0))
        return jax.jit(batched)

    def _resolve_sites(self, entry: ModelEntry, bucket: Bucket, make_fwd):
        """The trace's kernel-config resolver (None = hardcoded defaults)."""
        if self.kernel_config is not None:
            cfg = self.kernel_config
            return lambda site: cfg
        if self.tuner is None:
            return None
        # Enumerate kernel sites abstractly: eval_shape runs the forward on
        # shape/dtype structs only, so the recording resolver sees every
        # site this trace will hit without executing (or timing) anything.
        # The recording fwd does NOT count as a trace — only the real build
        # below does.
        sites: list = []

        def record(site):
            if site not in sites:
                sites.append(site)
            return None

        struct = jax.ShapeDtypeStruct
        jax.eval_shape(
            make_fwd(record, count_trace=False),
            entry.params,
            struct((bucket.num_blocks, bucket.v, bucket.n), jnp.float32),
            struct((bucket.num_blocks,), jnp.int32),
            struct((bucket.num_blocks,), jnp.int32),
            struct((bucket.padded_src, bucket.f), jnp.float32),
        )
        # Tune (or cache-lookup) each site off-trace, then hand the real
        # trace a pure-lookup resolver over the frozen results.
        resolved = {site: self.tuner.resolve(site) for site in sites}
        return lambda site: resolved.get(site)
