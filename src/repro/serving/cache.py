"""Content-addressed BlockedGraph preprocessing cache.

GHOST's partition matrix and fetch order are generated *offline* (paper
Section 3.4.1); a serving deployment therefore should pay the partitioning
cost once per distinct graph, not once per request.  The cache key is a
content hash of everything the partitioner consumes — edge list, node count,
(V, N) group sizes, and optional per-edge weights — so two requests carrying
the same structure (regardless of features, which only enter at execute
time) share one preprocessing artifact.  The key deliberately excludes the
model: in a multi-model catalog every model using the same prepare
transform (the ``salt``) shares one partition per structure.

Entries are LRU-evicted.  Each entry also carries a free-form ``extras``
dict that the engine uses to memoize downstream per-structure artifacts
(the structural shape bucket, bucket-padded tile arrays, and per-model
analytic hardware cost under ``("hw", model_id)`` keys), all invariant
under the same key.

Thread safety: the cache carries its own internal lock — many client
threads preprocess concurrently on the async submit path, *outside* the
engine's intake lock (partitioning is the expensive step; serializing it
behind the intake lock would make every submit pay every other submit's
partitioning).  The lock is held across ``partition_graph`` on a miss, so
concurrent submits of the same structure dedupe onto one partitioning run
instead of racing to insert N identical entries.  ``extras`` mutation by
the engine happens under the engine's own lock; the two locks are never
held simultaneously (cache calls never nest inside engine critical
sections and vice versa), so no lock-order deadlock is possible.
"""

from __future__ import annotations

import dataclasses
import hashlib
import threading
from collections import OrderedDict
from typing import Optional

import numpy as np

from repro.core.graph import Graph
from repro.core.partition import PartitionedGraph, partition_graph


def graph_content_hash(
    graph: Graph,
    v: int,
    n: int,
    edge_weights: Optional[np.ndarray] = None,
    salt: str = "",
    extra: bytes = b"",
) -> str:
    """Hash the partitioner's inputs: structure + group sizes (+ weights).

    ``salt`` distinguishes deterministic structure transforms (e.g. GCN
    self-loops + symmetric normalization) applied on cache miss, so the raw
    graph can be hashed without re-running the transform on every request.
    ``extra`` is opaque caller context that the transform closes over (the
    sampled-serving path hashes the host-node ids here: two samples with
    identical local structure but different host vertices get different
    host-degree GCN weights, so they must not share a partition).

    Edge weights hash as their *original* dtype's bytes plus a dtype tag:
    downcasting to one common dtype before hashing would collide weightings
    that differ only beyond that dtype's precision (e.g. two float64
    vectors 1e-12 apart) onto one cache key, silently sharing a partition.
    """
    h = hashlib.sha1()
    h.update(salt.encode())
    h.update(extra)
    h.update(np.int64(graph.num_nodes).tobytes())
    h.update(np.int64(v).tobytes())
    h.update(np.int64(n).tobytes())
    h.update(np.ascontiguousarray(graph.edge_src, dtype=np.int32).tobytes())
    h.update(np.ascontiguousarray(graph.edge_dst, dtype=np.int32).tobytes())
    if edge_weights is not None:
        w = np.ascontiguousarray(edge_weights)
        h.update(str(w.dtype).encode())
        h.update(w.tobytes())
    return h.hexdigest()


@dataclasses.dataclass
class CacheEntry:
    key: str
    pg: PartitionedGraph
    extras: dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


class PreprocessCache:
    """LRU cache: content hash -> partitioned (blocked) graph."""

    def __init__(self, capacity: int = 256):
        if capacity < 1:
            raise ValueError("cache capacity must be >= 1")
        self.capacity = capacity
        self._entries: OrderedDict[str, CacheEntry] = OrderedDict()
        self.stats = CacheStats()
        self._lock = threading.Lock()

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def peek(self, key: str, touch: bool = True) -> Optional[CacheEntry]:
        """Look up an entry by key without counting a hit or miss.

        For consumers on the *serve* path (per-slot hardware accounting,
        report assembly) that revisit an entry created at submit time:
        ``touch=True`` (default) refreshes LRU recency, so a structure
        that is served often but submitted rarely stays resident.  Stats
        are untouched either way — hit/miss rates measure submit-path
        memoization only.
        """
        with self._lock:
            entry = self._entries.get(key)
            if entry is not None and touch:
                self._entries.move_to_end(key)
            return entry

    def get_or_partition(
        self,
        graph: Graph,
        v: int,
        n: int,
        edge_weights: Optional[np.ndarray] = None,
        transform=None,
        salt: str = "",
        extra: bytes = b"",
    ) -> tuple[CacheEntry, bool]:
        """Return (entry, was_hit); partitions and inserts on miss.

        ``transform``, if given, maps the raw graph to
        ``(graph, edge_weights)`` on miss only (its identity must be encoded
        in ``salt`` so distinct transforms don't collide on the same raw
        structure; any other context it closes over — e.g. the sampled
        host-node ids — goes in ``extra``).  The transformed graph is kept
        on the entry for consumers that model the executed (not the
        submitted) structure.
        """
        key = graph_content_hash(graph, v, n, edge_weights, salt, extra)
        with self._lock:
            entry = self._entries.get(key)
            if entry is not None:
                self.stats.hits += 1
                self._entries.move_to_end(key)
                return entry, True
            # Partition while holding the lock: concurrent submits of the
            # same structure dedupe onto this run instead of all missing.
            self.stats.misses += 1
            executed = graph
            if transform is not None:
                executed, edge_weights = transform(graph)
            pg = partition_graph(executed, v=v, n=n,
                                 edge_weights=edge_weights)
            entry = CacheEntry(key=key, pg=pg)
            entry.extras["graph"] = executed
            self._entries[key] = entry
            if len(self._entries) > self.capacity:
                self._entries.popitem(last=False)
                self.stats.evictions += 1
            return entry, False
