"""Served-throughput accounting: wall-clock + analytic GHOST hardware cost.

The engine records one ``RequestRecord`` per served request; ``ServeReport``
folds them into the numbers a deployment dashboard (or the serving
benchmark's JSON) wants: functional req/s on this host, latency percentiles,
per-model served counts, queue-wait / anti-starvation behavior (max wait in
engine ticks), admission-control outcomes (admitted / rejected / shed),
preprocessing-cache effectiveness, how many jit traces the (model, bucket)
executor pool actually paid, and the accumulated GHOST latency/energy from
the analytic model (photonic/perf.py) — i.e. what the same request stream
would cost on the accelerator.

Durations are measured with ``time.perf_counter()`` (monotonic): wall-clock
time is not, and latency stats must never go negative under a clock step.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Optional

import numpy as np


@dataclasses.dataclass
class RequestRecord:
    rid: int
    model_id: str
    num_nodes: int
    num_edges: int
    bucket: str
    cache_hit: bool
    latency_s: float           # monotonic time: submit -> result materialized
    batch_size: int            # real requests in the batch that served it
    wait_ticks: int = 0        # engine ticks spent waiting in the queue
    hw_latency_s: float = 0.0  # analytic GHOST inference latency
    hw_energy_j: float = 0.0
    # Node-query (neighborhood-sampled) intake path only:
    node_query: bool = False
    num_seeds: int = 0         # query nodes answered by this request
    sample_s: float = 0.0      # host-side k-hop sampling time
    sampled_nodes: int = 0     # real vertices in the sampled subgraph
    sampled_edges: int = 0
    fanouts: str = ""          # e.g. "10x5" ("full" for a None layer)


def _percentile(values, q: float) -> float:
    return float(np.percentile(np.asarray(values, np.float64), q)) if values else 0.0


@dataclasses.dataclass
class ServeReport:
    requests: int
    wall_s: float
    req_per_s: float
    p50_latency_ms: float
    p99_latency_ms: float
    mean_batch_size: float
    cache_hits: int
    cache_misses: int
    cache_hit_rate: float
    traces_compiled: int
    buckets: dict            # bucket description -> requests served in it
    per_model: dict          # model_id -> requests served for it
    backend: str
    scheduler: str
    max_wait_ticks: int      # worst queue wait observed — served, still
                             # waiting, or shed (starvation gauge)
    admitted: int
    rejected: int
    shed: int
    reject_rate: float
    hw_latency_s: float
    hw_energy_j: float
    hw_req_per_s: float
    hw_avg_power_w: float
    kernel_configs: dict = dataclasses.field(default_factory=dict)
                             # shape-class key -> live kernel config
                             # ({} = hardcoded defaults, no tuner/override)
    topology: dict = dataclasses.field(default_factory=dict)
                             # mesh topology baked into the executor traces
                             # (num_devices / mesh_shape / shard_axis);
                             # {} = single-device, no mesh
    replicas: dict = dataclasses.field(default_factory=dict)
                             # replica name -> per-replica summary (router
                             # reports only; {} for a single engine)
    node_query_stats: dict = dataclasses.field(default_factory=dict)
                             # neighborhood-sampled intake counters ({} when
                             # no node queries were served): queries, seeds,
                             # sample-time percentiles, subgraph sizes,
                             # fanout mix

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), indent=2, default=float)

    def pretty(self) -> str:
        return (
            f"served {self.requests} requests in {self.wall_s:.2f}s "
            f"({self.req_per_s:.1f} req/s functional, backend={self.backend}, "
            f"scheduler={self.scheduler})\n"
            f"  latency p50={self.p50_latency_ms:.1f}ms "
            f"p99={self.p99_latency_ms:.1f}ms, "
            f"mean batch {self.mean_batch_size:.1f}, "
            f"max queue wait {self.max_wait_ticks} ticks\n"
            f"  admission: {self.admitted} admitted / {self.rejected} rejected"
            f" / {self.shed} shed (reject rate {self.reject_rate:.2f})\n"
            f"  per model: {self.per_model}\n"
            f"  preprocess cache: {self.cache_hits} hits / "
            f"{self.cache_misses} misses (hit rate {self.cache_hit_rate:.2f})\n"
            f"  jit traces compiled: {self.traces_compiled} "
            f"across buckets {self.buckets}\n"
            + (f"  kernel configs: {self.kernel_configs}\n"
               if self.kernel_configs else "")
            + (f"  mesh: {self.topology.get('num_devices')} devices "
               f"{self.topology.get('mesh_shape')} "
               f"(axis={self.topology.get('shard_axis')}, "
               f"strategy={self.topology.get('strategy')})\n"
               if self.topology else "")
            + (f"  replicas: {self.replicas}\n" if self.replicas else "")
            + (f"  node queries: {self.node_query_stats['queries']} "
               f"({self.node_query_stats['seeds']} seeds, "
               f"fanouts {self.node_query_stats['fanouts']}), "
               f"sample p50={self.node_query_stats['sample_p50_ms']:.1f}ms "
               f"p99={self.node_query_stats['sample_p99_ms']:.1f}ms, "
               f"mean subgraph "
               f"{self.node_query_stats['mean_sampled_nodes']:.0f} nodes / "
               f"{self.node_query_stats['mean_sampled_edges']:.0f} edges\n"
               if self.node_query_stats else "")
            + f"  GHOST hardware estimate: {self.hw_latency_s * 1e6:.1f} us, "
            f"{self.hw_energy_j * 1e3:.3f} mJ, {self.hw_req_per_s:.0f} req/s, "
            f"avg power {self.hw_avg_power_w:.1f} W"
        )


def build_report(
    records: list[RequestRecord],
    wall_s: float,
    cache_stats,
    traces_compiled: int,
    backend: str,
    scheduler: str = "fifo",
    admission_stats=None,
    queue_max_wait_ticks: int = 0,
    kernel_configs: Optional[dict] = None,
    topology: Optional[dict] = None,
    replicas: Optional[dict] = None,
) -> ServeReport:
    lats = [r.latency_s for r in records]
    buckets: dict[str, int] = {}
    per_model: dict[str, int] = {}
    for r in records:
        buckets[r.bucket] = buckets.get(r.bucket, 0) + 1
        per_model[r.model_id] = per_model.get(r.model_id, 0) + 1
    hw_lat = sum(r.hw_latency_s for r in records)
    hw_e = sum(r.hw_energy_j for r in records)
    nq = [r for r in records if r.node_query]
    node_query_stats: dict = {}
    if nq:
        samples = [r.sample_s for r in nq]
        fanout_mix: dict[str, int] = {}
        for r in nq:
            fanout_mix[r.fanouts] = fanout_mix.get(r.fanouts, 0) + 1
        node_query_stats = {
            "queries": len(nq),
            "seeds": sum(r.num_seeds for r in nq),
            "fanouts": fanout_mix,
            "sample_p50_ms": _percentile(samples, 50) * 1e3,
            "sample_p99_ms": _percentile(samples, 99) * 1e3,
            "mean_sampled_nodes": float(np.mean(
                [r.sampled_nodes for r in nq])),
            "mean_sampled_edges": float(np.mean(
                [r.sampled_edges for r in nq])),
        }
    return ServeReport(
        requests=len(records),
        wall_s=wall_s,
        req_per_s=len(records) / wall_s if wall_s > 0 else 0.0,
        p50_latency_ms=_percentile(lats, 50) * 1e3,
        p99_latency_ms=_percentile(lats, 99) * 1e3,
        mean_batch_size=(float(np.mean([r.batch_size for r in records]))
                         if records else 0.0),
        cache_hits=cache_stats.hits,
        cache_misses=cache_stats.misses,
        cache_hit_rate=cache_stats.hit_rate,
        traces_compiled=traces_compiled,
        buckets=buckets,
        per_model=per_model,
        backend=backend,
        scheduler=scheduler,
        max_wait_ticks=max(
            max((r.wait_ticks for r in records), default=0),
            queue_max_wait_ticks),
        admitted=admission_stats.admitted if admission_stats else len(records),
        rejected=admission_stats.rejected if admission_stats else 0,
        shed=admission_stats.shed if admission_stats else 0,
        reject_rate=admission_stats.reject_rate if admission_stats else 0.0,
        hw_latency_s=hw_lat,
        hw_energy_j=hw_e,
        hw_req_per_s=len(records) / hw_lat if hw_lat > 0 else 0.0,
        hw_avg_power_w=hw_e / hw_lat if hw_lat > 0 else 0.0,
        kernel_configs=kernel_configs or {},
        topology=topology or {},
        replicas=replicas or {},
        node_query_stats=node_query_stats,
    )
