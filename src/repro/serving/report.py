"""Served-throughput accounting: wall-clock + analytic GHOST hardware cost.

The engine records one ``RequestRecord`` per served request; ``ServeReport``
folds them into the numbers a deployment dashboard (or the serving
benchmark's JSON) wants: functional req/s on this host, latency percentiles,
per-model served counts, queue-wait / anti-starvation behavior (max wait in
wall seconds, plus legacy serve-iteration ticks), per-model p99-vs-SLO
attainment for every model carrying an ``slo_ms`` contract,
admission-control outcomes (admitted / rejected / shed), preprocessing-cache
effectiveness, how many jit traces the (model, bucket) executor pool
actually paid, and the accumulated GHOST latency/energy from the analytic
model (photonic/perf.py) — i.e. what the same request stream would cost on
the accelerator.

Durations are measured with ``time.perf_counter()`` (monotonic): wall-clock
time is not, and latency stats must never go negative under a clock step.
SLO deadlines are absolute ``perf_counter`` instants (``t_submit +
slo_ms``), so ``slo_met`` is exactly ``latency_s * 1e3 <= slo_ms``.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Optional

import numpy as np


@dataclasses.dataclass
class RequestRecord:
    rid: int
    model_id: str
    num_nodes: int
    num_edges: int
    bucket: str
    cache_hit: bool
    latency_s: float           # monotonic time: submit -> result materialized
    batch_size: int            # real requests in the batch that served it
    wait_ticks: int = 0        # serve iterations spent waiting in the queue
    wait_s: float = 0.0        # wall seconds spent waiting in the queue
    hw_latency_s: float = 0.0  # analytic GHOST inference latency
    hw_energy_j: float = 0.0
    # SLO contract (models registered with slo_ms=): 0.0 = no contract.
    slo_ms: float = 0.0
    deadline_s: float = float("inf")  # absolute perf_counter deadline
    slo_met: Optional[bool] = None    # None when the model has no SLO
    # Node-query (neighborhood-sampled) intake path only:
    node_query: bool = False
    num_seeds: int = 0         # query nodes answered by this request
    sample_s: float = 0.0      # host-side k-hop sampling time
    sampled_nodes: int = 0     # real vertices in the sampled subgraph
    sampled_edges: int = 0
    fanouts: str = ""          # e.g. "10x5" ("full" for a None layer)


def _percentile(values, q: float) -> float:
    return float(np.percentile(np.asarray(values, np.float64), q)) if values else 0.0


def slo_attainment_from(records: list["RequestRecord"]) -> dict:
    """Per-model (and overall) SLO attainment over served records.

    Only records whose model carries a contract (``slo_ms > 0``) count;
    a catalog without SLOs yields ``{}``.  Per model: the contract, how
    many requests it covered, how many met it, the attainment fraction,
    and the served p99 latency next to the SLO it is measured against —
    the "p99 vs SLO" pairing a latency dashboard plots.  Shed/rejected
    requests never produce records, so attainment here is over *answered*
    requests; the admission counters in the same report complete the
    offered-traffic picture.
    """
    slo_records = [r for r in records if r.slo_ms > 0]
    if not slo_records:
        return {}
    per_model: dict[str, dict] = {}
    by_model: dict[str, list[RequestRecord]] = {}
    for r in slo_records:
        by_model.setdefault(r.model_id, []).append(r)
    for model_id, recs in by_model.items():
        met = sum(1 for r in recs if r.slo_met)
        lats = [r.latency_s for r in recs]
        per_model[model_id] = {
            "slo_ms": recs[0].slo_ms,
            "served": len(recs),
            "met": met,
            "attainment": met / len(recs),
            "p99_latency_ms": _percentile(lats, 99) * 1e3,
            "p99_over_slo": (_percentile(lats, 99) * 1e3 / recs[0].slo_ms
                             if recs[0].slo_ms else 0.0),
        }
    total_met = sum(m["met"] for m in per_model.values())
    total = sum(m["served"] for m in per_model.values())
    return {
        "served": total,
        "met": total_met,
        "attainment": total_met / total,
        "per_model": per_model,
    }


@dataclasses.dataclass
class ServeReport:
    requests: int
    wall_s: float
    req_per_s: float
    p50_latency_ms: float
    p99_latency_ms: float
    mean_batch_size: float
    cache_hits: int
    cache_misses: int
    cache_hit_rate: float
    traces_compiled: int
    buckets: dict            # bucket description -> requests served in it
    per_model: dict          # model_id -> requests served for it
    backend: str
    scheduler: str
    max_wait_ticks: int      # worst queue wait observed in serve iterations
                             # (legacy gauge — iteration rate varies with
                             # load under the always-on loop)
    admitted: int
    rejected: int
    shed: int
    reject_rate: float
    hw_latency_s: float
    hw_energy_j: float
    hw_req_per_s: float
    hw_avg_power_w: float
    max_wait_s: float = 0.0  # worst queue wait in wall seconds — served,
                             # still waiting, or shed (the primary
                             # starvation gauge under the async loop)
    slo_attainment: dict = dataclasses.field(default_factory=dict)
                             # per-model p99-vs-SLO attainment (see
                             # slo_attainment_from); {} = no SLO'd models
    kernel_configs: dict = dataclasses.field(default_factory=dict)
                             # shape-class key -> live kernel config
                             # ({} = hardcoded defaults, no tuner/override)
    topology: dict = dataclasses.field(default_factory=dict)
                             # mesh topology baked into the executor traces
                             # (num_devices / mesh_shape / shard_axis);
                             # {} = single-device, no mesh
    replicas: dict = dataclasses.field(default_factory=dict)
                             # replica name -> per-replica summary (router
                             # reports only; {} for a single engine)
    node_query_stats: dict = dataclasses.field(default_factory=dict)
                             # neighborhood-sampled intake counters ({} when
                             # no node queries were served): queries, seeds,
                             # sample-time percentiles, subgraph sizes,
                             # fanout mix
    unmeetable: int = 0      # subset of `rejected`: refused at enqueue
                             # because the SLO deadline was infeasible per
                             # the learned service-time model
    service_time_ms: dict = dataclasses.field(default_factory=dict)
                             # "model_id/bucket" -> expected batch service
                             # time (ms), the EWMA driving admission /
                             # urgency / router slack ({} = nothing warm)
    pipeline: dict = dataclasses.field(default_factory=dict)
                             # serve-loop pipeline overlap: depth plus
                             # per-stage busy seconds and busy fractions
                             # of wall clock (device execution serializes
                             # behind the engine's device lock, so exec is
                             # occupancy <= ~1.0; overlap shows up as
                             # exec staying near 1.0 while stack-busy is
                             # nonzero — host work hidden behind the device)

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), indent=2, default=float)

    def pretty(self) -> str:
        return (
            f"served {self.requests} requests in {self.wall_s:.2f}s "
            f"({self.req_per_s:.1f} req/s functional, backend={self.backend}, "
            f"scheduler={self.scheduler})\n"
            f"  latency p50={self.p50_latency_ms:.1f}ms "
            f"p99={self.p99_latency_ms:.1f}ms, "
            f"mean batch {self.mean_batch_size:.1f}, "
            f"max queue wait {self.max_wait_s * 1e3:.1f}ms "
            f"({self.max_wait_ticks} ticks)\n"
            f"  admission: {self.admitted} admitted / {self.rejected} rejected"
            f" ({self.unmeetable} SLO-unmeetable)"
            f" / {self.shed} shed (reject rate {self.reject_rate:.2f})\n"
            + (f"  SLO attainment: {self.slo_attainment['met']}/"
               f"{self.slo_attainment['served']} "
               f"({self.slo_attainment['attainment']:.3f}) — "
               + ", ".join(
                   f"{m}: {v['attainment']:.2f} "
                   f"(p99 {v['p99_latency_ms']:.1f}ms vs SLO "
                   f"{v['slo_ms']:.0f}ms)"
                   for m, v in self.slo_attainment["per_model"].items())
               + "\n" if self.slo_attainment else "")
            + (f"  expected service (EWMA): "
               + ", ".join(f"{k}: {v:.2f}ms"
                           for k, v in sorted(self.service_time_ms.items()))
               + "\n" if self.service_time_ms else "")
            + (f"  pipeline depth {self.pipeline['depth']}: "
               f"device-busy {self.pipeline.get('exec_busy_frac', 0.0):.0%} / "
               f"stack-busy {self.pipeline.get('stack_busy_frac', 0.0):.0%} "
               f"of wall clock\n" if self.pipeline else "")
            + f"  per model: {self.per_model}\n"
            f"  preprocess cache: {self.cache_hits} hits / "
            f"{self.cache_misses} misses (hit rate {self.cache_hit_rate:.2f})\n"
            f"  jit traces compiled: {self.traces_compiled} "
            f"across buckets {self.buckets}\n"
            + (f"  kernel configs: {self.kernel_configs}\n"
               if self.kernel_configs else "")
            + (f"  mesh: {self.topology.get('num_devices')} devices "
               f"{self.topology.get('mesh_shape')} "
               f"(axis={self.topology.get('shard_axis')}, "
               f"strategy={self.topology.get('strategy')})\n"
               if self.topology else "")
            + (f"  replicas: {self.replicas}\n" if self.replicas else "")
            + (f"  node queries: {self.node_query_stats['queries']} "
               f"({self.node_query_stats['seeds']} seeds, "
               f"fanouts {self.node_query_stats['fanouts']}), "
               f"sample p50={self.node_query_stats['sample_p50_ms']:.1f}ms "
               f"p99={self.node_query_stats['sample_p99_ms']:.1f}ms, "
               f"mean subgraph "
               f"{self.node_query_stats['mean_sampled_nodes']:.0f} nodes / "
               f"{self.node_query_stats['mean_sampled_edges']:.0f} edges\n"
               if self.node_query_stats else "")
            + f"  GHOST hardware estimate: {self.hw_latency_s * 1e6:.1f} us, "
            f"{self.hw_energy_j * 1e3:.3f} mJ, {self.hw_req_per_s:.0f} req/s, "
            f"avg power {self.hw_avg_power_w:.1f} W"
        )


def build_report(
    records: list[RequestRecord],
    wall_s: float,
    cache_stats,
    traces_compiled: int,
    backend: str,
    scheduler: str = "fifo",
    admission_stats=None,
    queue_max_wait_ticks: int = 0,
    queue_max_wait_s: float = 0.0,
    kernel_configs: Optional[dict] = None,
    topology: Optional[dict] = None,
    replicas: Optional[dict] = None,
    service_time_ms: Optional[dict] = None,
    pipeline: Optional[dict] = None,
) -> ServeReport:
    if pipeline:
        # Busy seconds -> fractions of the measured wall clock.  Device
        # execution serializes behind the engine's device lock, so the
        # exec fraction is device occupancy (~<= 1.0); pipelining shows
        # up as exec near 1.0 with stacking/readout hidden behind it.
        pipeline = dict(pipeline)
        for stage in ("stack", "exec"):
            busy = pipeline.get(f"{stage}_busy_s", 0.0)
            pipeline[f"{stage}_busy_frac"] = (busy / wall_s
                                              if wall_s > 0 else 0.0)
    lats = [r.latency_s for r in records]
    buckets: dict[str, int] = {}
    per_model: dict[str, int] = {}
    for r in records:
        buckets[r.bucket] = buckets.get(r.bucket, 0) + 1
        per_model[r.model_id] = per_model.get(r.model_id, 0) + 1
    hw_lat = sum(r.hw_latency_s for r in records)
    hw_e = sum(r.hw_energy_j for r in records)
    nq = [r for r in records if r.node_query]
    node_query_stats: dict = {}
    if nq:
        samples = [r.sample_s for r in nq]
        fanout_mix: dict[str, int] = {}
        for r in nq:
            fanout_mix[r.fanouts] = fanout_mix.get(r.fanouts, 0) + 1
        node_query_stats = {
            "queries": len(nq),
            "seeds": sum(r.num_seeds for r in nq),
            "fanouts": fanout_mix,
            "sample_p50_ms": _percentile(samples, 50) * 1e3,
            "sample_p99_ms": _percentile(samples, 99) * 1e3,
            "mean_sampled_nodes": float(np.mean(
                [r.sampled_nodes for r in nq])),
            "mean_sampled_edges": float(np.mean(
                [r.sampled_edges for r in nq])),
        }
    return ServeReport(
        requests=len(records),
        wall_s=wall_s,
        req_per_s=len(records) / wall_s if wall_s > 0 else 0.0,
        p50_latency_ms=_percentile(lats, 50) * 1e3,
        p99_latency_ms=_percentile(lats, 99) * 1e3,
        mean_batch_size=(float(np.mean([r.batch_size for r in records]))
                         if records else 0.0),
        cache_hits=cache_stats.hits,
        cache_misses=cache_stats.misses,
        cache_hit_rate=cache_stats.hit_rate,
        traces_compiled=traces_compiled,
        buckets=buckets,
        per_model=per_model,
        backend=backend,
        scheduler=scheduler,
        max_wait_ticks=max(
            max((r.wait_ticks for r in records), default=0),
            queue_max_wait_ticks),
        max_wait_s=max(
            max((r.wait_s for r in records), default=0.0),
            queue_max_wait_s),
        slo_attainment=slo_attainment_from(records),
        admitted=admission_stats.admitted if admission_stats else len(records),
        rejected=admission_stats.rejected if admission_stats else 0,
        shed=admission_stats.shed if admission_stats else 0,
        reject_rate=admission_stats.reject_rate if admission_stats else 0.0,
        hw_latency_s=hw_lat,
        hw_energy_j=hw_e,
        hw_req_per_s=len(records) / hw_lat if hw_lat > 0 else 0.0,
        hw_avg_power_w=hw_e / hw_lat if hw_lat > 0 else 0.0,
        kernel_configs=kernel_configs or {},
        topology=topology or {},
        replicas=replicas or {},
        node_query_stats=node_query_stats,
        unmeetable=(getattr(admission_stats, "unmeetable", 0)
                    if admission_stats else 0),
        service_time_ms=service_time_ms or {},
        pipeline=pipeline or {},
    )
