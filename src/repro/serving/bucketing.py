"""Shape bucketing: bound the number of distinct jit traces under load.

Every distinct ``(num_blocks, num_dst_groups, num_src_groups, feat_dim)``
tuple is a distinct static shape for the blocked forward, and therefore a
fresh jit trace — unacceptable when serving arbitrary graphs.  We round each
dimension up to its power-of-two bucket and pad with all-zero tiles:

  * padding tiles sit at ``(row, col) = (G_dst_p - 1, G_src_p - 1)``, which
    keeps ``block_row`` non-decreasing (the CSR-sortedness the Pallas kernel
    requires) and keeps every index in range;
  * all-zero tiles are exact no-ops for SUM/MEAN (they contribute 0 to both
    the numerator and the degree) and for MAX/attention (the ``blocks != 0``
    mask excludes them), so bucketed outputs match the unpadded forward
    value-for-value on real rows;
  * padded destination/source rows carry zeros (or masked garbage) that
    callers slice off per request;
  * the feature dimension is rounded up too (``Bucket.f``) and padded with
    zero *columns*, so a heterogeneous model catalog (different ``f_in``
    per model) shares one set of host-side batching shapes; executors slice
    the zero columns back off before the model forward, which keeps the
    computation bit-identical to the unpadded one.  The rounding trades
    host-buffer size (worst case ~2x zero columns staged and transferred,
    immediately sliced off in-trace) for a bounded set of feature widths —
    the same deal the structural dims make, and what keeps the shape-class
    count finite if a model ever serves variable-width requests.

With power-of-two rounding the number of traces for graphs up to B blocks
and G groups is O(log B * log^2 G) per (model, feature-dim) — in practice a
handful.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.partition import PartitionedGraph


def next_pow2(x: int) -> int:
    """Smallest power of two >= x (1 for x <= 1)."""
    if x <= 1:
        return 1
    return 1 << (int(x) - 1).bit_length()


@dataclasses.dataclass(frozen=True)
class Bucket:
    """A padded static shape class for the blocked forward.

    ``f`` is the padded feature dimension (power-of-two rounded).  The
    structural fields depend only on the partition; ``f`` depends only on
    the request's feature width, so a structural bucket can be re-used
    across feature dims via ``dataclasses.replace(bucket, f=...)``.
    """

    num_blocks: int
    num_dst_groups: int
    num_src_groups: int
    v: int
    n: int
    f: int = 1

    @property
    def padded_dst(self) -> int:
        return self.num_dst_groups * self.v

    @property
    def padded_src(self) -> int:
        return self.num_src_groups * self.n

    def describe(self) -> str:
        return (f"B{self.num_blocks}xD{self.num_dst_groups}"
                f"xS{self.num_src_groups}(v{self.v},n{self.n},f{self.f})")


def bucket_for(pg: PartitionedGraph, feat_dim: int = 1) -> Bucket:
    """The power-of-two bucket a partitioned graph (+ feature width) lands in."""
    return Bucket(
        num_blocks=next_pow2(pg.blocks.shape[0]),
        num_dst_groups=next_pow2(pg.num_dst_groups),
        num_src_groups=next_pow2(pg.num_src_groups),
        v=pg.v,
        n=pg.n,
        f=next_pow2(feat_dim),
    )


def pad_partition_to_bucket(
    pg: PartitionedGraph, bucket: Bucket
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Pad (blocks, block_row, block_col) with zero tiles up to the bucket.

    Returns numpy arrays of shapes ([Bp, V, N], [Bp], [Bp]).
    """
    b = pg.blocks.shape[0]
    if (b > bucket.num_blocks
            or pg.num_dst_groups > bucket.num_dst_groups
            or pg.num_src_groups > bucket.num_src_groups
            or (pg.v, pg.n) != (bucket.v, bucket.n)):
        raise ValueError(f"graph does not fit bucket {bucket.describe()}")
    pad = bucket.num_blocks - b
    blocks = np.concatenate(
        [pg.blocks, np.zeros((pad, pg.v, pg.n), pg.blocks.dtype)], axis=0)
    row = np.concatenate(
        [pg.block_row,
         np.full(pad, bucket.num_dst_groups - 1, np.int32)]).astype(np.int32)
    col = np.concatenate(
        [pg.block_col,
         np.full(pad, bucket.num_src_groups - 1, np.int32)]).astype(np.int32)
    return blocks, row, col


def pad_features_to_bucket(
    pg: PartitionedGraph, bucket: Bucket, feat: np.ndarray
) -> np.ndarray:
    """Pad [Nv, F] features to the bucket's [Gs_p * N, f] (rows and columns).

    Zero columns are stripped again inside the executor before the model
    forward, so they never enter the arithmetic — they exist only so
    heterogeneous feature widths stack into one host-side batch shape.
    """
    rows = bucket.padded_src
    if feat.shape[0] > rows:
        raise ValueError("feature matrix larger than bucket source rows")
    if feat.shape[1] > bucket.f:
        raise ValueError("feature dim larger than bucket feature dim")
    dtype = np.dtype(feat.dtype)
    if dtype.kind != "f":
        # Integer/bool features would previously be *up*cast to f32 here
        # silently; refuse instead so the caller converts deliberately.
        raise TypeError(
            f"pad_features_to_bucket requires floating features, got {dtype}")
    # Preserve the request dtype: allocating f32 unconditionally would
    # silently downcast float64 (or future bf16) features before they ever
    # reach the executor.
    out = np.zeros((rows, bucket.f), dtype)
    out[: feat.shape[0], : feat.shape[1]] = feat
    return out


def node_mask_for_bucket(pg: PartitionedGraph, bucket: Bucket) -> np.ndarray:
    """[min(Gd_p*V, Gs_p*N)] 1/0 validity mask over the executor's node rows.

    The executor treats ``min(padded_dst, padded_src)`` as its static node
    count (see engine._make_executor); the mask zeroes padding rows for
    graph-level readouts.
    """
    rows = min(bucket.padded_dst, bucket.padded_src)
    mask = np.zeros((rows,), np.float32)
    mask[: pg.num_nodes] = 1.0
    return mask
