"""Slot-based continuous-batching GNN serving engine.

The GNN-side analogue of ``repro.launch.serve.ServeEngine``: requests join a
waiting queue; each engine tick gathers up to ``slots`` waiting requests
that share a shape bucket, stacks their bucketed tile arrays into
``[R, B, V, N]``, and runs one vmapped blocked forward — via the Pallas
``block_spmm`` kernel (interpret mode on CPU) or the jnp oracle, selected by
``backend``.

Serving costs the ad-hoc loop pays on every request are paid once here:

  partitioning     -> PreprocessCache, keyed by graph content hash
  jit tracing      -> one executor per (model, bucket), shapes padded to
                      power-of-two buckets so the trace count is bounded
  hardware costing -> analytic GHOST latency/energy memoized per structure

Executor numerics: zero padding tiles are exact no-ops (see
serving/bucketing.py), so per-request outputs match the unbatched
``model.apply_blocked`` value-for-value at fp32.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregate import (
    AGGREGATE_BACKENDS,
    BlockedGraph,
    aggregate_backend,
)
from repro.core.graph import Graph
from repro.photonic.perf import GhostConfig, GnnModelSpec, OrchFlags, simulate
from repro.serving.bucketing import (
    Bucket,
    bucket_for,
    pad_features_to_bucket,
    pad_partition_to_bucket,
)
from repro.serving.cache import PreprocessCache
from repro.serving.report import RequestRecord, ServeReport, build_report


def gcn_prepare(graph: Graph):
    """Standard GCN preprocessing: self-loops + symmetric normalization."""
    g = graph.with_self_loops()
    return g, g.gcn_edge_weights()


@dataclasses.dataclass
class _Pending:
    rid: int
    graph: Graph
    bucket: Bucket
    cache_key: str
    cache_hit: bool
    blocks: np.ndarray      # [Bp, V, N] bucket-padded tiles
    block_row: np.ndarray   # [Bp]
    block_col: np.ndarray   # [Bp]
    feat: np.ndarray        # [Gs_p * N, F]
    t_submit: float = 0.0


class GnnServeEngine:
    """Bucketed continuous batching over blocked GNN forwards.

    Args:
      model: a repro.gnn model (GCN/GraphSAGE/GAT/GIN) — anything exposing
        ``apply_blocked(params, bg, feat_padded, quantized)`` for the node
        task; the graph task additionally needs ``node_embed_blocked`` +
        ``readout`` (GIN-style) so the pooled readout can run per request
        at its true node count.
      params: the model's parameter pytree.
      task: "node" (per-node outputs, sliced to each request's node count)
        or "graph" (graph-level logits via the split embed/readout path).
      cfg: GhostConfig — supplies the (V, N) partition group sizes and the
        analytic hardware model's architecture point.
      spec: optional GnnModelSpec; when given, each request is also costed
        on the GHOST analytic model (memoized per graph structure).
      slots: batch width R; every executor call runs exactly R slots (free
        slots are zero-filled) so each bucket compiles exactly once.
      backend: "jnp" oracle or "pallas" kernel for SUM/MEAN aggregation.
      prepare_fn: optional structure transform run once per distinct graph
        on cache miss, returning (graph, edge_weights) — e.g. gcn_prepare.
    """

    def __init__(
        self,
        model,
        params,
        *,
        task: str = "node",
        cfg: GhostConfig = GhostConfig(),
        spec: Optional[GnnModelSpec] = None,
        flags: OrchFlags = OrchFlags(),
        slots: int = 8,
        backend: str = "jnp",
        quantized: bool = False,
        prepare_fn: Optional[Callable] = None,
        cache_capacity: int = 256,
        dataset_name: str = "served",
    ):
        if task not in ("node", "graph"):
            raise ValueError(f"unknown task '{task}'")
        if task == "graph" and not (hasattr(model, "node_embed_blocked")
                                    and hasattr(model, "readout")):
            raise ValueError(
                "task='graph' needs a model with node_embed_blocked + "
                "readout (e.g. GIN); node-level models serve task='node'")
        if backend not in AGGREGATE_BACKENDS:
            raise ValueError(f"unknown backend '{backend}'; expected one of "
                             f"{AGGREGATE_BACKENDS}")
        if slots < 1:
            raise ValueError("slots must be >= 1")
        self.model = model
        self.params = params
        self.task = task
        self.cfg = cfg.validate()
        self.spec = spec
        self.flags = flags.validate()
        self.slots = slots
        self.backend = backend
        self.quantized = quantized
        self.prepare_fn = prepare_fn
        self.dataset_name = dataset_name

        self.cache = PreprocessCache(cache_capacity)
        self.results: dict[int, np.ndarray] = {}
        self.records: list[RequestRecord] = []
        self._waiting: deque[_Pending] = deque()
        self._executors: dict[Bucket, Callable] = {}
        self._trace_count = 0
        self._next_rid = 0
        self._salt = (prepare_fn.__qualname__ if prepare_fn is not None
                      else "")

    # ------------------------------------------------------------------
    # Request intake.
    # ------------------------------------------------------------------

    def submit(self, graph: Graph) -> int:
        """Preprocess (cached) and enqueue one request; returns its rid."""
        t0 = time.time()
        entry, hit = self.cache.get_or_partition(
            graph, self.cfg.v, self.cfg.n,
            transform=self.prepare_fn, salt=self._salt)
        pg = entry.pg
        if "bucket" not in entry.extras:
            bucket = bucket_for(pg)
            entry.extras["bucket"] = bucket
            entry.extras["padded"] = pad_partition_to_bucket(pg, bucket)
        bucket = entry.extras["bucket"]
        blocks, row, col = entry.extras["padded"]
        rid = self._next_rid
        self._next_rid += 1
        self._waiting.append(_Pending(
            rid=rid,
            graph=graph,
            bucket=bucket,
            cache_key=entry.key,
            cache_hit=hit,
            blocks=blocks,
            block_row=row,
            block_col=col,
            feat=pad_features_to_bucket(pg, bucket, graph.node_feat),
            t_submit=t0,
        ))
        return rid

    # ------------------------------------------------------------------
    # Executors: one jit trace per (model, bucket).
    # ------------------------------------------------------------------

    def _make_executor(self, bucket: Bucket) -> Callable:
        model, task, backend = self.model, self.task, self.backend
        quantized = self.quantized
        # The executor's static node count: padded rows past this are pure
        # padding on both the source and destination sides; per-request
        # validity is handled by host-side slicing.  The graph task runs the
        # blocked *embedding* batch-wide and leaves the sum-pool readout to
        # the per-request path (the fp32 pooled sum depends on row count, so
        # pooling at the bucket shape would break bit-exactness).
        num_nodes = min(bucket.padded_dst, bucket.padded_src)

        def fwd(params, blocks, row, col, feat):
            self._trace_count += 1  # runs at trace time only
            bg = BlockedGraph(
                blocks=blocks, block_row=row, block_col=col,
                num_dst_groups=bucket.num_dst_groups,
                num_src_groups=bucket.num_src_groups,
                v=bucket.v, n=bucket.n, num_nodes=num_nodes,
            )
            with aggregate_backend(backend):
                if task == "graph":
                    return model.node_embed_blocked(params, bg, feat,
                                                    quantized)
                return model.apply_blocked(params, bg, feat, quantized)

        batched = jax.vmap(fwd, in_axes=(None, 0, 0, 0, 0))
        return jax.jit(batched)

    # ------------------------------------------------------------------
    # Engine ticks.
    # ------------------------------------------------------------------

    def step(self) -> int:
        """Serve one batch: the head-of-line bucket, up to ``slots`` deep.

        Returns the number of requests served (0 when the queue is empty).
        """
        if not self._waiting:
            return 0
        bucket = self._waiting[0].bucket
        batch: list[_Pending] = []
        keep: deque[_Pending] = deque()
        while self._waiting:
            p = self._waiting.popleft()
            if p.bucket == bucket and len(batch) < self.slots:
                batch.append(p)
            else:
                keep.append(p)
        self._waiting = keep

        r = self.slots
        bp, v, n = bucket.num_blocks, bucket.v, bucket.n
        f = batch[0].feat.shape[1]
        blocks = np.zeros((r, bp, v, n), np.float32)
        rows = np.zeros((r, bp), np.int32)
        cols = np.zeros((r, bp), np.int32)
        feats = np.zeros((r, bucket.padded_src, f), np.float32)
        for i, p in enumerate(batch):
            blocks[i], rows[i], cols[i] = p.blocks, p.block_row, p.block_col
            feats[i] = p.feat

        exe = self._executors.get(bucket)
        if exe is None:
            exe = self._executors[bucket] = self._make_executor(bucket)
        out = exe(self.params, jnp.asarray(blocks), jnp.asarray(rows),
                  jnp.asarray(cols), jnp.asarray(feats))
        out = np.asarray(jax.block_until_ready(out))
        t_done = time.time()

        for i, p in enumerate(batch):
            valid = out[i][: p.graph.num_nodes]
            if self.task == "node":
                self.results[p.rid] = valid
            else:
                self.results[p.rid] = np.asarray(
                    self.model.readout(self.params, jnp.asarray(valid)))
            hw_lat, hw_e = self._hardware_cost(p)
            self.records.append(RequestRecord(
                rid=p.rid,
                num_nodes=p.graph.num_nodes,
                num_edges=p.graph.num_edges,
                bucket=bucket.describe(),
                cache_hit=p.cache_hit,
                latency_s=t_done - p.t_submit,
                batch_size=len(batch),
                hw_latency_s=hw_lat,
                hw_energy_j=hw_e,
            ))
        return len(batch)

    def _hardware_cost(self, p: _Pending) -> tuple[float, float]:
        if self.spec is None:
            return 0.0, 0.0
        entry = self.cache._entries.get(p.cache_key)
        if entry is not None and "hw" in entry.extras:
            return entry.extras["hw"]
        if entry is not None:
            graph = entry.extras.get("graph", p.graph)
        elif self.prepare_fn is not None:
            # Entry evicted between submit and serve: re-derive the executed
            # structure so the hardware numbers don't depend on cache state.
            graph, _ = self.prepare_fn(p.graph)
        else:
            graph = p.graph
        rep = simulate(self.spec, graph, self.cfg, self.flags,
                       self.dataset_name)
        cost = (rep.latency, rep.energy)
        if entry is not None:
            entry.extras["hw"] = cost
        return cost

    def drain(self) -> int:
        """Serve until the queue is empty; returns total requests served."""
        total = 0
        while True:
            served = self.step()
            if not served:
                return total
            total += served

    def run(self, graphs) -> ServeReport:
        """Submit every graph, drain, and build the throughput report."""
        t0 = time.time()
        for g in graphs:
            self.submit(g)
        self.drain()
        return self.report(time.time() - t0)

    def report(self, wall_s: float) -> ServeReport:
        return build_report(self.records, wall_s, self.cache.stats,
                            self._trace_count, self.backend)
