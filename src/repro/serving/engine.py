"""Multi-model bucketed continuous-batching GNN serving engine.

One engine instance serves a heterogeneous *catalog* of GNN models
(GCN/GraphSAGE/GAT/GIN, differing tasks, feature widths, quantization) on
one substrate — the serving-side analogue of GHOST's versatility claim
(paper Section 4.1).  The engine is a thin orchestrator over four seams:

  registry + executor pool (serving/registry.py)
      named ``ModelEntry`` catalog (each optionally carrying an ``slo_ms``
      latency contract); one jit trace per ``(model_id, bucket)`` so the
      compilation count stays bounded at |models| x |buckets|.
  scheduler (serving/scheduler.py)
      requests wait grouped by ``(model_id, bucket)``; a pluggable policy
      (head-of-line FIFO, occupancy-greedy with a wall-clock
      anti-starvation bound, or SLO-aware EDF/least-slack deadline
      scheduling) picks the group each serve iteration.
  admission control (serving/admission.py)
      optional bound on the waiting queue with reject / shed overload
      policies (the shed victim is the waiting request with the least
      salvageable slack); outcomes surface in the serve report.
  preprocessing cache (serving/cache.py)
      partition + fetch order generated once per distinct structure
      (paper Section 3.4.1) and shared across every model in the catalog
      that uses the same prepare transform.

Each serve iteration gathers up to ``slots`` waiting requests from the
chosen group, stacks their bucket-padded tile arrays into ``[R, B, V, N]``
(features into ``[R, rows, bucket.f]``), and runs one vmapped blocked
forward — via the jnp oracle, the unfused Pallas ``block_spmm`` kernel, or
the fused aggregate+combine ``fused_block_spmm`` kernel with
combination-order planning (``backend="pallas_fused"``; interpret mode on
CPU).

Two driving modes share every scheduling/execution code path:

  tick-driven (the original mode, still what tests and closed-loop
      benchmarks use): the caller invokes ``step()``/``drain()``/``run()``
      and nothing happens between calls.
  always-on (``start()``): background serve threads form and execute
      batches continuously while any number of client threads call
      ``submit``/``try_submit``/``submit_nodes`` concurrently; results are
      picked up with the blocking ``result(rid)`` (or non-blocking
      ``take_result``), and ``stop(drain=True)`` closes intake, joins the
      loop, and serves out the remaining queue.  ``step`` and ``run``
      refuse to run while the loop owns batch formation.

Always-on pipeline (``pipeline_depth``): the loop is a two-stage
pipeline, the serving-side analogue of GHOST's vertex/edge stage overlap
(paper Section 4.4).  A *stacker* thread extracts the scheduler-chosen
batch and stacks its bucket-padded tiles into device-shaped numpy arrays;
``pipeline_depth`` *executor* threads (default 2) pull stacked batches
from a bounded handoff queue (``maxsize=pipeline_depth``), run the device
call, and write results back — so host stacking of batch k+1 overlaps
device execution of batch k instead of serializing behind it, and (with
two workers) host readout/record-building of batch k-1 overlaps both.
``pipeline_depth=0`` degenerates to the PR-9 single-thread serial loop
(one thread does extract → stack → execute → writeback in order).

Concurrency model and locking invariants (two-stage pipeline):

  * One *engine lock* guards all queue/result/metric state: the waiting
    groups, ``results``/``records``, admission + shed bookkeeping, the
    service-time EWMAs, and the writeback tickets.  Two condition
    wait-sets share it: ``_cond`` (submit/publish/drain state changes)
    and ``_write_cond`` (notified only when a batch publishes, so
    ticket-waiting workers are not woken by every submit in a storm).
    Batch *extraction* (stacker) and result *writeback* (executor
    workers) run under the lock; the expensive parts — preprocessing,
    host stacking, device calls, readout — run outside it, so
    submitters are never blocked behind a device call.
  * The preprocessing cache carries its own internal lock; the engine
    lock and the cache lock are **never held simultaneously** (cache
    calls happen strictly outside the engine lock, on the submit path
    and in the unlocked part of writeback's hardware costing).
  * Admission decisions are taken inside the same critical section as
    the queue mutation they authorize, so the waiting bound cannot
    overshoot under concurrent submitters — and the service-time
    admission estimate reads queue depth in that same section.
  * The stacker → executor handoff is a bounded ``queue.Queue`` with its
    own internal lock, never held together with the engine lock (puts
    and gets happen outside it).
  * Device execution serializes behind a dedicated *device lock*: one
    device runs one program at a time, and concurrent XLA CPU executions
    additionally thrash the shared intra-op thread pool (measurably
    slower than serial).  Workers therefore overlap only *host* work —
    readout, record building, ordered writeback — around the serialized
    device stage.  The device lock is held with no other lock.
  * Group-ordered writeback: extraction stamps each batch with a
    monotone per-``(model_id, bucket)`` *ticket* (under the engine
    lock); an executor worker publishes its batch only when the group's
    writeback counter reaches its ticket, waiting on the engine
    condition otherwise.  Two workers may therefore *execute* batches of
    the same group concurrently, but they *publish* in extraction order,
    so ``records`` ordering — and everything derived from it — matches
    the serial loop exactly.  Result *values* need no ordering at all:
    outputs are batch-composition-independent (see the numerics note
    below), which is why overlapping execution stays bit-exact.
  * Intake close: ``stop()`` atomically sets the intake-closed flag with
    the loop-stop flag under the engine lock *before* joining threads
    and draining, so a ``try_submit`` racing ``stop(drain=True)`` either
    enqueued in time (and is served by the final drain) or fails fast
    with ``RuntimeError`` — it can never strand a request behind a dead
    serve thread.  ``start()`` reopens intake.

Executor numerics: zero padding tiles, rows, and feature columns are exact
no-ops (see serving/bucketing.py; executors slice features back to the
model's true ``f_in`` inside the trace), so per-request outputs match the
per-model unbatched *jitted* ``model.apply_blocked`` value-for-value at
fp32, for every model in the catalog, *regardless of batch composition* —
which is also why the always-on loop is bit-exact with the tick loop for
an identical request set.  (Eager, un-jitted execution can differ from any
jitted run by 1 ULP in GAT's softmax — XLA fuses the exp/divide chain
differently — so the jitted unbatched forward is the reference; batching
and bucket padding themselves add no drift.)

Service-time model: writeback feeds an EWMA of observed batch service
time (host stacking + device execution) per ``(model_id, bucket)``,
skipping each key's first execution so jit compilation never pollutes the
steady-state estimate.  The model drives three consumers: (a)
*service-time admission* — a request whose SLO cannot be met even if its
group were scheduled immediately (non-preemptible in-flight batches plus
queue-ahead batches times expected service time already overrun the
deadline) is rejected at enqueue instead
of being served late or shed later; (b) the deadline scheduler's urgency
margin (a group whose head slack is inside one expected service time is
urgent); (c) ``EngineRouter`` routes to the replica with the smallest
estimated backlog *time* (queued batches x expected service) instead of
the shortest raw queue.  The EWMAs survive ``reset_metrics`` (they are a
learned model, not a metric) and surface in ``ServeReport``.

Latency accounting uses ``time.perf_counter()`` (monotonic) throughout —
``time.time()`` can step backwards under clock adjustment and produce
negative latencies.  SLO deadlines are absolute perf_counter instants
(``t_submit + slo_ms/1e3``).
"""

from __future__ import annotations

import dataclasses
import math
import queue as queue_mod
import threading
import time
from collections import OrderedDict, deque
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import Graph
from repro.photonic.perf import GhostConfig, OrchFlags, simulate
from repro.serving.admission import AdmissionController, AdmissionStats
from repro.serving.bucketing import (
    Bucket,
    bucket_for,
    next_pow2,
    pad_features_to_bucket,
    pad_partition_to_bucket,
)
from repro.serving.cache import CacheStats, PreprocessCache
from repro.serving.registry import (
    ExecutorPool,
    HostGraphCatalog,
    HostGraphEntry,
    ModelEntry,
    ModelRegistry,
)
from repro.serving.report import RequestRecord, ServeReport, build_report
from repro.serving.sampler import HostGraph, gcn_sample_prepare, sample_khop
from repro.serving.scheduler import GroupState, make_scheduler


def gcn_prepare(graph: Graph):
    """Standard GCN preprocessing: self-loops + symmetric normalization."""
    g = graph.with_self_loops()
    return g, g.gcn_edge_weights()


class QueueFullError(RuntimeError):
    """``submit`` on a full bounded queue under the 'reject' policy."""


# EWMA smoothing for the per-(model, bucket) service-time model: heavy
# enough to track load shifts within a few batches, light enough that one
# outlier batch does not swing admission decisions.
SERVICE_EWMA_ALPHA = 0.25


@dataclasses.dataclass
class _StackedBatch:
    """One extracted batch after host stacking, ready for an executor.

    The handoff unit of the two-stage pipeline: produced by the stacker
    (or inline by the serial path), consumed by an executor worker.
    ``ticket`` orders writeback within the batch's (model_id, bucket)
    group; ``stack_s`` is the host stacking time that feeds the
    service-time EWMA and the pipeline busy gauges.
    """

    key: tuple
    batch: list
    serve_tick: int
    t_extract: float
    ticket: int
    blocks: np.ndarray  # [R, Bp, V, N]
    rows: np.ndarray    # [R, Bp]
    cols: np.ndarray    # [R, Bp]
    feats: np.ndarray   # [R, padded_src, f]
    stack_s: float


@dataclasses.dataclass
class _Pending:
    rid: int
    model_id: str
    graph: Graph
    bucket: Bucket
    cache_key: str
    cache_hit: bool
    blocks: np.ndarray      # [Bp, V, N] bucket-padded tiles
    block_row: np.ndarray   # [Bp]
    block_col: np.ndarray   # [Bp]
    feat: np.ndarray        # [Gs_p * N, bucket.f]
    t_submit: float         # perf_counter at submission
    seq: int                # global submission order (FIFO age)
    submit_tick: int        # serve iteration at submission (legacy age)
    slo_ms: float = 0.0     # model's latency contract (0 = none)
    deadline_s: float = math.inf  # absolute perf_counter deadline
    # Node-query (neighborhood-sampled) requests only:
    seed_rows: Optional[np.ndarray] = None  # local rows to slice results to
    num_seeds: int = 0
    sample_s: float = 0.0
    sampled_nodes: int = 0  # real (non-ghost) vertices in the subgraph
    sampled_edges: int = 0
    fanouts_desc: str = ""


class GnnServeEngine:
    """Continuous batching over blocked GNN forwards for a model catalog.

    Construct, ``register`` one model per catalog entry, then ``submit``
    ``(model_id, graph)`` requests — tick-driven via ``step``/``drain``/
    ``run``, or against the always-on loop between ``start()`` and
    ``stop()``.

    Args:
      cfg: GhostConfig — supplies the (V, N) partition group sizes (shared
        by the whole catalog, so structures are partitioned once) and the
        analytic hardware model's architecture point.
      flags: OrchFlags for the analytic hardware model.
      slots: batch width R; every executor call runs exactly R slots (free
        slots are zero-filled) so each (model, bucket) compiles exactly once.
      backend: "jnp" oracle, "pallas" (unfused block_spmm kernel), or
        "pallas_fused" (fused aggregate+combine epilogue kernel with
        combination-order planning) for SUM/MEAN aggregation (MAX and
        attention always take the jnp path inside the trace).
      scheduler: "fifo" | "occupancy" | "deadline" | a Scheduler instance.
      max_waiting: bound on the waiting queue (None = unbounded).
      admission_policy: "reject" (turn the new request away) or
        "shed-oldest" (drop the waiting request with the least salvageable
        slack — submission order when no model carries an SLO — to make
        room).
      pipeline_depth: executor workers behind the always-on loop's
        stacker stage (and the bound on stacked batches in flight between
        the stages).  Default 2: host stacking of batch k+1 overlaps
        device execution of batch k.  0 = the serial single-thread loop
        (stack and execute never overlap).  Tick-driven ``step``/``run``
        are unaffected — they always serve synchronously.
      service_time_admission: when True (default), a request carrying an
        SLO whose deadline cannot be met even if its group were scheduled
        immediately — per the learned expected-service-time EWMA, the
        non-preemptible in-flight batches, and the queue ahead of it —
        is rejected at enqueue (counted in
        ``AdmissionStats.unmeetable``).  Requests are always admitted
        while the (model, bucket) service time is still unknown.
      cache_capacity: LRU capacity of the preprocessing cache.
      tuner: optional ``kernels.autotune.Autotuner`` (duck-typed: needs
        ``resolve(site)`` + ``live_configs()``); the executor pool resolves
        per-shape-class kernel configs through it at trace-build time,
        warm-started from its persisted cache.
      kernel_config: optional explicit ``KernelConfig``-like object applied
        to every kernel site — a deterministic override that beats the
        tuner (what tests pin).
      mesh: optional 1-D device mesh (see ``launch.mesh.make_data_mesh``).
        When given with >1 device on ``shard_axis``, every executor trace
        runs its fp32 layers under ``core.aggregate.shard_scope``: the
        combine contraction is partitioned along the feature dim with a
        psum over the contracted axis (few-ULP drift vs single-device;
        quantized models stay single-device inside the scope because their
        per-tensor activation scale is a global reduction).  The trace key
        is effectively (model_id, bucket, mesh) — one pool is one mesh.
      shard_axis: mesh axis name the feature partition maps onto.
    """

    def __init__(
        self,
        *,
        cfg: GhostConfig = GhostConfig(),
        flags: OrchFlags = OrchFlags(),
        slots: int = 8,
        backend: str = "jnp",
        scheduler="fifo",
        max_waiting: Optional[int] = None,
        admission_policy: str = "reject",
        pipeline_depth: int = 2,
        service_time_admission: bool = True,
        cache_capacity: int = 256,
        tuner=None,
        kernel_config=None,
        mesh=None,
        shard_axis: str = "data",
    ):
        self.cfg = cfg.validate()
        self.flags = flags.validate()
        self.slots = slots
        self.backend = backend
        if pipeline_depth < 0:
            raise ValueError(
                f"pipeline_depth must be >= 0, got {pipeline_depth}")
        self.pipeline_depth = int(pipeline_depth)
        self.service_time_admission = bool(service_time_admission)
        self.registry = ModelRegistry()
        self.hosts = HostGraphCatalog()
        self.pool = ExecutorPool(slots=slots, backend=backend,  # validates
                                 tuner=tuner, kernel_config=kernel_config,
                                 mesh=mesh, shard_axis=shard_axis)
        self.scheduler = make_scheduler(scheduler)
        self.admission = AdmissionController(max_waiting, admission_policy)
        self.cache = PreprocessCache(cache_capacity)
        self.results: dict[int, np.ndarray] = {}
        self.records: list[RequestRecord] = []
        self.shed_rids: list[int] = []
        self._shed_set: set[int] = set()
        self._groups: "OrderedDict[tuple, deque[_Pending]]" = OrderedDict()
        self._next_rid = 0
        self._seq = 0
        self._tick = 0
        self._num_waiting = 0
        self._inflight = 0
        self._max_dropped_wait_ticks = 0
        self._max_dropped_wait_s = 0.0
        # One lock guards all mutable engine state above, with two wait
        # sets on it: ``_cond`` for queue/result state changes (submit,
        # publish, drain) and ``_write_cond`` notified only when a batch
        # publishes — ticket-waiting executor workers park on the latter
        # so a submit storm does not wake them 1000x/s for nothing.  See
        # the module docstring for what runs inside vs outside the lock.
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        self._write_cond = threading.Condition(self._lock)
        self._thread: Optional[threading.Thread] = None
        self._workers: list[threading.Thread] = []
        self._pipe: Optional["queue_mod.Queue[_StackedBatch]"] = None
        self._stacker_done = True
        self._running = False
        self._intake_closed = False
        self._loop_error: Optional[BaseException] = None
        # Group-ordered writeback: extraction issues tickets, writeback
        # publishes when the group's counter reaches its ticket.  Both
        # dicts only ever grow together, so issued == published holds
        # across start/stop cycles.
        self._group_ticket: dict[tuple, int] = {}
        self._group_write: dict[tuple, int] = {}
        # Service-time model + pipeline busy gauges.  The EWMAs (and the
        # warm set that keeps jit compilation out of them) survive
        # reset_metrics: they are a learned model, not a metric.
        self._service_ewma: dict[tuple, float] = {}
        self._warm_keys: set[tuple] = set()
        self._stack_busy_s = 0.0
        self._exec_busy_s = 0.0
        # One device runs one program at a time: executor workers serialize
        # the jitted call + block_until_ready behind this lock (concurrent
        # XLA CPU executions thrash the shared intra-op thread pool — worse
        # than serial).  Workers overlap everything ELSE: host readout,
        # record building and the ordered writeback of batch k proceed
        # while batch k+1 occupies the device and the stacker forms k+2.
        self._device_lock = threading.Lock()

    # ------------------------------------------------------------------
    # Catalog.
    # ------------------------------------------------------------------

    def register(self, model_id: str, model, params, **kwargs) -> ModelEntry:
        """Add one model to the catalog (see ModelRegistry.register).

        The engine fills in the sampled-serving counterpart of the standard
        GCN prepare automatically: a model registered with
        ``prepare_fn=gcn_prepare`` gets ``gcn_sample_prepare`` (host-degree
        normalization) unless the caller supplies their own.
        """
        if (kwargs.get("prepare_fn") is gcn_prepare
                and kwargs.get("sample_prepare_fn") is None):
            kwargs["sample_prepare_fn"] = gcn_sample_prepare
        return self.registry.register(model_id, model, params, **kwargs)

    def register_host_graph(self, name: str, host: HostGraph, *,
                            fanouts: Sequence[Optional[int]] = (10, 10),
                            rng_seed: int = 0) -> HostGraphEntry:
        """Register one resident graph for node-query serving.

        ``fanouts`` is the default per-layer sampling budget (len = hop
        count, ``None`` entries = take the full neighborhood); ``rng_seed``
        fixes the deterministic sampling policy, which is what lets hot
        query nodes share partition-cache entries.
        """
        return self.hosts.register(name, host, fanouts=fanouts,
                                   rng_seed=rng_seed)

    # ------------------------------------------------------------------
    # Request intake (safe from any number of client threads).
    # ------------------------------------------------------------------

    @property
    def num_waiting(self) -> int:
        with self._cond:
            return self._num_waiting

    @property
    def running(self) -> bool:
        """True while the always-on serve loop owns batch formation."""
        with self._cond:
            return self._running

    def try_submit(self, model_id: str, graph: Graph) -> Optional[int]:
        """Preprocess (cached) and enqueue one request.

        Returns the rid, or None when admission control rejected it.
        Safe to call concurrently from many client threads.
        """
        entry_m = self.registry[model_id]
        f = graph.node_feat.shape[1]
        if f != entry_m.f_in:
            raise ValueError(
                f"model '{model_id}' expects {entry_m.f_in} features, "
                f"request carries {f}")
        # Fast path: a request the full queue will certainly reject should
        # not pay preprocessing first.  The authoritative decision is the
        # decide() inside _enqueue — atomic with the queue mutation.
        with self._cond:
            self._check_intake_open_locked()
            if self.admission.try_reject_early(self._num_waiting):
                return None
        t0 = time.perf_counter()
        return self._enqueue(model_id, graph, t0,
                             transform=entry_m.prepare_fn,
                             salt=entry_m.salt, slo_ms=entry_m.slo_ms)

    def _check_intake_open_locked(self) -> None:
        """Fail fast on submit-after-stop.  Caller holds the engine lock.

        ``stop()`` closes intake atomically with the loop-stop flag, so a
        submitter racing the shutdown either lands before the close (and
        the final drain serves it) or sees this error — never a silently
        stranded request.  ``start()`` reopens intake.
        """
        if self._intake_closed:
            raise RuntimeError(
                "engine is stopped: submit after stop() — intake is "
                "closed (call start() to reopen, or drain()/step() serve "
                "only what was already queued)")

    def _enqueue(self, model_id: str, graph: Graph, t0: float,
                 *, transform, salt: str, extra: bytes = b"",
                 slo_ms: Optional[float] = None,
                 nq: Optional[dict] = None) -> Optional[int]:
        """Preprocess (cached, outside the engine lock), then atomically
        admit + enqueue.  Returns the rid, or None on rejection.

        Preprocessing precedes the admission decision, so a preprocessing
        failure needs no stats rollback and can never cost a waiting
        victim its slot.
        """
        centry, hit = self.cache.get_or_partition(
            graph, self.cfg.v, self.cfg.n,
            transform=transform, salt=salt, extra=extra)
        pg = centry.pg
        shape = centry.extras.get("shape")
        if shape is None:
            # Structural artifacts are feature-width-independent: cache
            # the f=1 bucket + padded tile arrays once per structure and
            # derive the request's full bucket from its feature width.
            # Concurrent submitters may duplicate this work (deterministic,
            # identical values); "padded" is published before "shape" so a
            # reader that observes shape always finds padded.
            shape = bucket_for(pg)
            centry.extras["padded"] = pad_partition_to_bucket(pg, shape)
            centry.extras["shape"] = shape
        bucket = dataclasses.replace(
            shape, f=next_pow2(graph.node_feat.shape[1]))
        blocks, row, col = centry.extras["padded"]
        feat = pad_features_to_bucket(pg, bucket, graph.node_feat)
        deadline = (t0 + slo_ms / 1e3 if slo_ms else math.inf)
        with self._cond:
            self._check_intake_open_locked()
            if slo_ms and self.service_time_admission:
                # Service-time admission (ROADMAP 1b): reject now if the
                # deadline is unmeetable even under *immediate* scheduling.
                # Two terms no scheduler can reorder away: (a) work already
                # extracted into the pipeline or onto the device — EDF
                # preemption happens at batch *formation*, so in-flight
                # batches are non-preemptible; (b) the request's own group
                # queue, forcing ceil((q+1)/slots) batch services before
                # its result lands.  No estimate yet (cold key) -> admit.
                est = self._expected_service_locked((model_id, bucket))
                if est is not None:
                    ewma = self._service_ewma
                    mean = sum(ewma.values()) / len(ewma) if ewma else est
                    inflight_s = math.ceil(self._inflight / self.slots) * mean
                    ahead = len(self._groups.get((model_id, bucket), ()))
                    done = (time.perf_counter() + inflight_s
                            + (ahead // self.slots + 1) * est)
                    if done > deadline:
                        self.admission.reject_unmeetable()
                        return None
            verdict = self.admission.decide(self._num_waiting)
            if verdict == "reject":
                return None
            if verdict == "shed":
                # Shed only now, with the replacement request viable and
                # the queue still at its bound (same critical section).
                self._shed_victim_locked()
            rid = self._next_rid
            self._next_rid += 1
            pending = _Pending(
                rid=rid,
                model_id=model_id,
                graph=graph,
                bucket=bucket,
                cache_key=centry.key,
                cache_hit=hit,
                blocks=blocks,
                block_row=row,
                block_col=col,
                feat=feat,
                t_submit=t0,
                seq=self._seq,
                submit_tick=self._tick,
                slo_ms=float(slo_ms) if slo_ms else 0.0,
                deadline_s=deadline,
                **(nq or {}),
            )
            self._seq += 1
            self._groups.setdefault((model_id, bucket),
                                    deque()).append(pending)
            self._num_waiting += 1
            self._cond.notify_all()
            return rid

    def submit(self, model_id: str, graph: Graph) -> int:
        """Like try_submit, but raises QueueFullError on rejection."""
        rid = self.try_submit(model_id, graph)
        if rid is None:
            raise QueueFullError(
                f"waiting queue full ({self.admission.max_waiting}) and "
                f"admission policy is '{self.admission.policy}'")
        return rid

    def try_submit_nodes(
        self,
        model_id: str,
        seed_ids: Sequence[int],
        *,
        host: Optional[str] = None,
        fanouts: Optional[Sequence[Optional[int]]] = None,
        rng_seed: Optional[int] = None,
    ) -> Optional[int]:
        """Answer a node query: sample the k-hop neighborhood and enqueue.

        The million-node intake path: ``seed_ids`` are vertex ids in the
        registered ``HostGraph`` (``host=`` names it; omit when exactly one
        is registered).  A multi-seed batch is sampled as **one shared
        subgraph** — one partitioning, one executor slot — and the result
        rows come back sliced per seed, in ``seed_ids`` order, bit-exact
        with each seed's solo submission whenever the sampling hops cover
        the model depth (see serving/sampler.py).  The engine samples the
        seeds' k-hop in-neighborhood (``fanouts``/``rng_seed`` default to
        the host entry's policy), runs the sampled subgraph through the
        ordinary cache / bucketing / executor machinery — identical
        samples content-hash to one partition entry, the hot-node fast
        path — and slices the result to the seed rows.

        Returns the rid, or None when admission control rejected it.
        Safe to call concurrently from many client threads.
        """
        entry_m = self.registry[model_id]
        if entry_m.task != "node":
            raise ValueError(
                f"node queries need a node-task model; '{model_id}' serves "
                f"task='{entry_m.task}'")
        if entry_m.prepare_fn is not None and entry_m.sample_prepare_fn is None:
            raise ValueError(
                f"model '{model_id}' has prepare_fn="
                f"{entry_m.salt or entry_m.prepare_fn!r} but no "
                "sample_prepare_fn: its normalization needs host-degree "
                "bookkeeping to stay well-defined on sampled neighborhoods "
                "(register with sample_prepare_fn=, cf. gcn_sample_prepare)")
        hentry = self.hosts[host if host is not None else self.hosts.sole_id]
        hg = hentry.host
        if hg.num_features != entry_m.f_in:
            raise ValueError(
                f"model '{model_id}' expects {entry_m.f_in} features, host "
                f"graph '{hentry.name}' carries {hg.num_features}")
        with self._cond:
            self._check_intake_open_locked()
            if self.admission.try_reject_early(self._num_waiting):
                return None
        t0 = time.perf_counter()
        use_fanouts = (hentry.fanouts if fanouts is None
                       else tuple(fanouts))
        use_seed = (hentry.rng_seed if rng_seed is None
                    else int(rng_seed))
        # lcm(V, N)-aligned local numbering: sampled tiles become
        # bitwise restrictions of the full graph's (module docstring of
        # serving/sampler.py), which is what makes full-fanout samples
        # reproduce the full forward bit-exactly at the seeds.
        sample = sample_khop(hg, seed_ids, use_fanouts, use_seed,
                             align=math.lcm(self.cfg.v, self.cfg.n))
        t_sampled = time.perf_counter()
        spf = entry_m.sample_prepare_fn
        # The transform closes over this sample's host vertices (their host
        # degrees set the edge weights), so the cache key must carry the
        # host-id layout: identical local structures over *different* host
        # vertices must not share a partition.  Without a prepare the
        # partition is structure-only and the extra bytes stay empty.
        transform = (lambda g: spf(sample, hg)) if spf is not None else None
        extra = sample.host_ids.tobytes() if spf is not None else b""
        nq = dict(
            seed_rows=sample.seed_rows,
            num_seeds=int(len(sample.seed_rows)),
            sample_s=t_sampled - t0,
            sampled_nodes=sample.num_sampled_nodes,
            sampled_edges=sample.num_sampled_edges,
            fanouts_desc="x".join("full" if f is None else str(f)
                                  for f in use_fanouts),
        )
        return self._enqueue(
            model_id, sample.graph, t0,
            transform=transform,
            salt=f"{entry_m.sample_salt}:{hg.fingerprint}",
            extra=extra, slo_ms=entry_m.slo_ms, nq=nq)

    def submit_nodes(self, model_id: str, seed_ids: Sequence[int],
                     **kwargs) -> int:
        """Like try_submit_nodes, but raises QueueFullError on rejection."""
        rid = self.try_submit_nodes(model_id, seed_ids, **kwargs)
        if rid is None:
            raise QueueFullError(
                f"waiting queue full ({self.admission.max_waiting}) and "
                f"admission policy is '{self.admission.policy}'")
        return rid

    def _shed_victim_locked(self) -> None:
        """Drop the waiting request with the least salvageable slack.

        Group heads suffice: within one group (one model, so one SLO;
        FIFO arrival) the head has the earliest deadline and the lowest
        seq.  Without SLOs every deadline is infinite and the seq
        tie-break reproduces the historical shed-oldest behavior.
        """
        key, dq = min(self._groups.items(),
                      key=lambda kv: (kv[1][0].deadline_s, kv[1][0].seq))
        victim = dq.popleft()
        if not dq:
            del self._groups[key]
        self._num_waiting -= 1
        self.shed_rids.append(victim.rid)
        self._shed_set.add(victim.rid)
        # The victim's wait counts toward the starvation gauges: a policy
        # that quietly dropped its stalest work must not look starvation-free.
        self._max_dropped_wait_ticks = max(
            self._max_dropped_wait_ticks, self._tick - victim.submit_tick)
        self._max_dropped_wait_s = max(
            self._max_dropped_wait_s,
            time.perf_counter() - victim.t_submit)
        self._cond.notify_all()  # wake any result(victim.rid) waiter

    # ------------------------------------------------------------------
    # Batch formation + execution (shared by both driving modes).
    # ------------------------------------------------------------------

    def _expected_service_locked(self, key: tuple) -> Optional[float]:
        """Expected batch service time (s) for one (model_id, bucket).

        Caller holds the engine lock.  Exact key first; falls back to the
        mean over the model's other warm buckets (a new bucket of a known
        model behaves like its siblings far more than like nothing); None
        when the model has no warm bucket at all.
        """
        v = self._service_ewma.get(key)
        if v is not None:
            return v
        sibs = [s for k, s in self._service_ewma.items() if k[0] == key[0]]
        if sibs:
            return sum(sibs) / len(sibs)
        return None

    def _extract_locked(self):
        """Pop the scheduler-chosen batch.  Caller holds the lock.

        Returns ``(key, batch, serve_tick, t_extract, ticket)`` or None
        when the queue is empty.  The ticket orders this batch's
        writeback within its group (see the module docstring).
        """
        if not self._groups:
            return None
        now = time.perf_counter()
        states = [
            GroupState(key=key, size=len(dq), head_seq=dq[0].seq,
                       head_wait_ticks=self._tick - dq[0].submit_tick,
                       head_age_s=now - dq[0].t_submit,
                       head_deadline_s=dq[0].deadline_s,
                       head_slack_s=dq[0].deadline_s - now,
                       head_est_service_s=(
                           self._expected_service_locked(key) or 0.0))
            for key, dq in self._groups.items()
        ]
        key = self.scheduler.select(states, self.slots)
        dq = self._groups.get(key)
        if dq is None:
            raise RuntimeError(f"scheduler chose unknown group {key!r}")
        batch = [dq.popleft() for _ in range(min(self.slots, len(dq)))]
        if not dq:
            del self._groups[key]
        self._num_waiting -= len(batch)
        self._inflight += len(batch)
        serve_tick = self._tick
        self._tick += 1
        ticket = self._group_ticket.get(key, 0)
        self._group_ticket[key] = ticket + 1
        return key, batch, serve_tick, now, ticket

    def _stack(self, key, batch, serve_tick: int, t_extract: float,
               ticket: int) -> _StackedBatch:
        """Host stage: stack one extracted batch into device-shaped arrays.

        Runs outside every lock (stacker thread, or inline on the serial
        path) — this is the work the pipeline overlaps with device
        execution.
        """
        _, bucket = key
        t0 = time.perf_counter()
        r = self.slots
        bp, v, n = bucket.num_blocks, bucket.v, bucket.n
        blocks = np.zeros((r, bp, v, n), np.float32)
        rows = np.zeros((r, bp), np.int32)
        cols = np.zeros((r, bp), np.int32)
        feats = np.zeros((r, bucket.padded_src, bucket.f), np.float32)
        for i, p in enumerate(batch):
            blocks[i], rows[i], cols[i] = p.blocks, p.block_row, p.block_col
            feats[i] = p.feat
        return _StackedBatch(
            key=key, batch=batch, serve_tick=serve_tick,
            t_extract=t_extract, ticket=ticket,
            blocks=blocks, rows=rows, cols=cols, feats=feats,
            stack_s=time.perf_counter() - t0)

    def _run_stacked(self, sb: _StackedBatch) -> int:
        """Device stage: execute one stacked batch, then publish in group
        ticket order under the engine lock."""
        model_id, bucket = sb.key
        key = sb.key
        batch = sb.batch
        entry = self.registry[model_id]
        was_warm = key in self._warm_keys
        with self._device_lock:
            # exec_s is measured inside the lock: pure device occupancy,
            # not time spent queued behind a peer's execution.
            t_exec0 = time.perf_counter()
            exe = self.pool.executor(entry, bucket)
            out = exe(entry.params, jnp.asarray(sb.blocks),
                      jnp.asarray(sb.rows), jnp.asarray(sb.cols),
                      jnp.asarray(sb.feats))
            out = np.asarray(jax.block_until_ready(out))
            t_done = time.perf_counter()
            exec_s = t_done - t_exec0

        results: dict[int, np.ndarray] = {}
        records: list[RequestRecord] = []
        for i, p in enumerate(batch):
            valid = out[i][: p.graph.num_nodes]
            if entry.task == "node":
                # Node queries answer only their seed rows (in query order);
                # whole-graph requests deliver every row.
                results[p.rid] = (valid if p.seed_rows is None
                                  else valid[p.seed_rows])
            else:
                results[p.rid] = np.asarray(
                    entry.model.readout(entry.params, jnp.asarray(valid)))
            hw_lat, hw_e = self._hardware_cost(entry, p)
            latency = t_done - p.t_submit
            records.append(RequestRecord(
                rid=p.rid,
                model_id=model_id,
                num_nodes=p.graph.num_nodes,
                num_edges=p.graph.num_edges,
                bucket=bucket.describe(),
                cache_hit=p.cache_hit,
                latency_s=latency,
                batch_size=len(batch),
                wait_ticks=sb.serve_tick - p.submit_tick,
                wait_s=sb.t_extract - p.t_submit,
                hw_latency_s=hw_lat,
                hw_energy_j=hw_e,
                slo_ms=p.slo_ms,
                deadline_s=p.deadline_s,
                slo_met=(latency * 1e3 <= p.slo_ms if p.slo_ms else None),
                node_query=p.seed_rows is not None,
                num_seeds=p.num_seeds,
                sample_s=p.sample_s,
                sampled_nodes=p.sampled_nodes,
                sampled_edges=p.sampled_edges,
                fanouts=p.fanouts_desc,
            ))
        with self._cond:
            # Publish in extraction order within the group: concurrent
            # workers may *execute* same-group batches out of order (the
            # values cannot differ — outputs are batch-composition-
            # independent), but records/results land serially.  The wait
            # parks on the publish-only condition so submit-storm
            # notifications never wake a ticket-waiting worker.
            while self._group_write.get(key, 0) != sb.ticket:
                if self._loop_error is not None:
                    return 0  # a peer crashed; the engine is failed anyway
                self._write_cond.wait(timeout=0.05)
            self._group_write[key] = sb.ticket + 1
            self.results.update(results)
            self.records.extend(records)
            self._inflight -= len(batch)
            self._stack_busy_s += sb.stack_s
            self._exec_busy_s += exec_s
            if was_warm:
                # First execution of a key includes jit compilation; keep
                # it out of the steady-state service-time model.
                service = sb.stack_s + exec_s
                prev = self._service_ewma.get(key)
                self._service_ewma[key] = (
                    service if prev is None else
                    SERVICE_EWMA_ALPHA * service
                    + (1.0 - SERVICE_EWMA_ALPHA) * prev)
            else:
                self._warm_keys.add(key)
            self._cond.notify_all()
            self._write_cond.notify_all()
        return len(batch)

    def _execute(self, key, batch, serve_tick: int, t_extract: float,
                 ticket: int) -> int:
        """Serial path: stack then execute one batch, back to back."""
        return self._run_stacked(
            self._stack(key, batch, serve_tick, t_extract, ticket))

    def step(self) -> int:
        """Serve one batch from the scheduler-chosen (model, bucket) group.

        Tick-driven mode only — raises while the always-on loop is
        running (the loop owns batch formation; submit and pick results
        up instead).  Returns the number of requests served (0 when the
        queue is empty).
        """
        with self._cond:
            if self._running:
                raise RuntimeError(
                    "engine loop is running; step() is tick-driven mode — "
                    "submit requests and pick up results instead")
            extracted = self._extract_locked()
        if extracted is None:
            return 0
        return self._execute(*extracted)

    def _fail_loop(self, e: BaseException) -> None:
        """Record the first crash of any serve thread and stop the loop.

        Every waiter (``result``/``drain``/``stop``) re-raises it as
        ``RuntimeError("serve loop failed")``.
        """
        with self._cond:
            if self._loop_error is None:
                self._loop_error = e
            self._running = False
            self._cond.notify_all()
            self._write_cond.notify_all()

    def _serve_loop(self) -> None:
        """pipeline_depth=0: the serial loop — one thread does extract →
        stack → execute → writeback in order (no stage overlap)."""
        try:
            while True:
                with self._cond:
                    while self._running and not self._groups:
                        self._cond.wait(timeout=0.05)
                    if not self._running:
                        return
                    extracted = self._extract_locked()
                if extracted is not None:
                    self._execute(*extracted)
        except BaseException as e:  # noqa: BLE001 — surfaced to clients
            self._fail_loop(e)

    def _pipe_put(self, sb: _StackedBatch) -> bool:
        """Bounded handoff put that stays responsive to a peer crash.

        Returns False (abandoning the batch) only when the engine already
        failed — the loop error reaches every waiter first.
        """
        while True:
            try:
                self._pipe.put(sb, timeout=0.05)
                return True
            except queue_mod.Full:
                with self._cond:
                    if self._loop_error is not None:
                        return False

    def _stacker_loop(self) -> None:
        """Pipeline stage 1: extract the scheduler-chosen batch and stack
        it on the host, then hand off to the executor workers."""
        try:
            while True:
                with self._cond:
                    while self._running and not self._groups:
                        self._cond.wait(timeout=0.05)
                    if not self._running:
                        return
                    extracted = self._extract_locked()
                if extracted is None:
                    continue
                if not self._pipe_put(self._stack(*extracted)):
                    return
        except BaseException as e:  # noqa: BLE001 — surfaced to clients
            self._fail_loop(e)
        finally:
            # Executor workers drain what's queued, then exit on this flag.
            with self._cond:
                self._stacker_done = True
                self._cond.notify_all()

    def _executor_loop(self) -> None:
        """Pipeline stage 2: execute stacked batches and publish results
        in group ticket order.  ``pipeline_depth`` of these run at once."""
        try:
            while True:
                try:
                    sb = self._pipe.get(timeout=0.05)
                except queue_mod.Empty:
                    with self._cond:
                        if self._loop_error is not None:
                            return
                        # _stacker_done is set after the stacker's last
                        # put, so done + empty means no batch can arrive.
                        if self._stacker_done and self._pipe.empty():
                            return
                    continue
                self._run_stacked(sb)
        except BaseException as e:  # noqa: BLE001 — surfaced to clients
            self._fail_loop(e)

    # ------------------------------------------------------------------
    # Always-on loop lifecycle.
    # ------------------------------------------------------------------

    def start(self) -> "GnnServeEngine":
        """Start the background serve threads (idempotent calls raise).

        After start, any number of client threads may submit concurrently;
        batches form and execute continuously.  With ``pipeline_depth >=
        1`` this spawns the stacker plus that many executor workers; with
        0 a single serial serve thread.  Reopens intake after a prior
        ``stop()``.  Pair with ``stop()``.
        """
        with self._cond:
            if self._thread is not None or self._workers:
                raise RuntimeError("serve loop already running")
            self._running = True
            self._intake_closed = False
            self._loop_error = None
            if self.pipeline_depth == 0:
                self._stacker_done = True  # no pipeline stages
                self._thread = threading.Thread(
                    target=self._serve_loop, name="gnn-serve-loop",
                    daemon=True)
                threads = [self._thread]
            else:
                self._stacker_done = False
                self._pipe = queue_mod.Queue(maxsize=self.pipeline_depth)
                self._thread = threading.Thread(
                    target=self._stacker_loop, name="gnn-serve-stack",
                    daemon=True)
                self._workers = [
                    threading.Thread(target=self._executor_loop,
                                     name=f"gnn-serve-exec-{i}", daemon=True)
                    for i in range(self.pipeline_depth)]
                threads = [self._thread, *self._workers]
            for t in threads:
                t.start()
        return self

    def stop(self, drain: bool = True) -> None:
        """Close intake, join the serve threads; by default serve out the
        remaining queue.

        Intake closes atomically with the loop-stop flag (same critical
        section), *before* the final drain pass — a ``try_submit`` racing
        this either enqueued in time (the drain below serves it) or fails
        fast with RuntimeError; it can never strand a request behind a
        dead serve thread.  ``drain=False`` leaves unserved requests
        waiting (a later ``drain()``/``step()``/``start()`` can still
        serve them — but new submissions need ``start()`` to reopen
        intake).  Re-raises a serve-loop crash, if one happened.
        """
        with self._cond:
            self._intake_closed = True
            self._running = False
            self._cond.notify_all()
            t, self._thread = self._thread, None
            workers, self._workers = self._workers, []
        if t is not None:
            t.join()
        for w in workers:
            w.join()
        with self._cond:
            err = self._loop_error
        if err is not None:
            raise RuntimeError("serve loop failed") from err
        if drain:
            self.drain()

    def drain(self) -> int:
        """Serve until the queue is empty; returns requests served.

        With the loop running this blocks until the loop has emptied the
        queue and finished in-flight batches (the loop does the serving);
        tick-driven it serves synchronously.
        """
        with self._cond:
            if self._running:
                before = len(self.records)
                while ((self._num_waiting or self._inflight)
                       and self._running and self._loop_error is None):
                    self._cond.wait(timeout=0.1)
                if self._loop_error is not None:
                    raise RuntimeError(
                        "serve loop failed") from self._loop_error
                return len(self.records) - before
        total = 0
        while True:
            served = self.step()
            if not served:
                return total
            total += served

    # ------------------------------------------------------------------
    # Result pickup.
    # ------------------------------------------------------------------

    def result(self, rid: int, timeout: Optional[float] = None) -> np.ndarray:
        """Blocking pickup: wait for ``rid`` and pop its result.

        Raises KeyError when the request was shed (or, with the loop
        stopped and the queue idle, when the rid is unknown/already
        taken); TimeoutError when ``timeout`` seconds elapse first;
        RuntimeError when the serve loop crashed.  Note an unknown rid
        against a *running* loop waits until the timeout.
        """
        deadline = (None if timeout is None
                    else time.perf_counter() + timeout)
        with self._cond:
            while True:
                if rid in self.results:
                    return self.results.pop(rid)
                if rid in self._shed_set:
                    raise KeyError(
                        f"request {rid} was shed by admission control")
                if self._loop_error is not None:
                    raise RuntimeError(
                        "serve loop failed") from self._loop_error
                if (not self._running and not self._num_waiting
                        and not self._inflight):
                    raise KeyError(rid)
                if deadline is None:
                    self._cond.wait(timeout=0.1)
                else:
                    remaining = deadline - time.perf_counter()
                    if remaining <= 0:
                        raise TimeoutError(
                            f"result {rid} not ready after {timeout}s")
                    self._cond.wait(timeout=min(remaining, 0.1))

    def take_result(self, rid: int) -> np.ndarray:
        """Pop and return one result (KeyError if absent or already taken).

        Non-blocking; see ``result`` for the waiting variant.  Long-running
        servers should reclaim results as they are consumed: ``results``
        and ``records`` otherwise grow with total traffic, and the
        admission bound only caps the *waiting* queue, not delivered
        output retention.
        """
        with self._cond:
            return self.results.pop(rid)

    # ------------------------------------------------------------------
    # Closed-loop driver + accounting.
    # ------------------------------------------------------------------

    def run(self, requests) -> ServeReport:
        """Submit a stream, drain, and build the throughput report.

        Tick-driven mode only (raises while the loop runs).  ``requests``
        yields ``(model_id, graph)`` pairs; bare graphs are accepted when
        exactly one model is registered.  With a bounded queue the engine
        interleaves serving with intake instead of rejecting (closed-loop
        semantics; use try_submit for open-loop).
        """
        if self.running:
            raise RuntimeError(
                "engine loop is running; run() is tick-driven mode")
        t0 = time.perf_counter()
        max_waiting = self.admission.max_waiting
        for item in requests:
            if isinstance(item, Graph):
                model_id, graph = self.registry.sole_id, item
            else:
                model_id, graph = item
            # Drain ahead of the bound so closed-loop intake is never
            # rejected (and the reject/shed stats stay pure open-loop
            # signals).
            while max_waiting is not None and self.num_waiting >= max_waiting:
                self.step()
            self.submit(model_id, graph)
        self.drain()
        return self.report(time.perf_counter() - t0)

    def _hardware_cost(self, entry: ModelEntry,
                       p: _Pending) -> tuple[float, float]:
        if entry.spec is None:
            return 0.0, 0.0
        # peek(touch=True): hardware costing revisits the entry on the
        # *serve* path, so it must refresh LRU recency — a structure served
        # often but submitted rarely stays resident (and stats stay submit-
        # path-only: this is not a cache hit).
        centry = self.cache.peek(p.cache_key)
        hw_key = ("hw", entry.model_id)  # per-model: specs differ per entry
        if centry is not None and hw_key in centry.extras:
            return centry.extras[hw_key]
        if centry is not None:
            graph = centry.extras.get("graph", p.graph)
        elif entry.prepare_fn is not None and p.seed_rows is None:
            # Entry evicted between submit and serve: re-derive the executed
            # structure so the hardware numbers don't depend on cache state.
            # (Sampled requests skip this — their transform closed over the
            # sample; the raw subgraph is a fine analytic-cost stand-in.)
            graph, _ = entry.prepare_fn(p.graph)
        else:
            graph = p.graph
        rep = simulate(entry.spec, graph, self.cfg, self.flags,
                       entry.dataset_name)
        cost = (rep.latency, rep.energy)
        if centry is not None:
            centry.extras[hw_key] = cost
        return cost

    def queue_wait_gauges(self) -> tuple[int, float]:
        """(max wait ticks, max wait seconds) over waiting + shed requests.

        The starvation gauges must see requests still waiting (or already
        shed), not just the served ones — a policy that never serves a
        cold group would otherwise report a low max wait.
        """
        with self._cond:
            now = time.perf_counter()
            waiting_ticks = max(
                (self._tick - dq[0].submit_tick
                 for dq in self._groups.values()), default=0)
            waiting_s = max(
                (now - dq[0].t_submit for dq in self._groups.values()),
                default=0.0)
            return (max(waiting_ticks, self._max_dropped_wait_ticks),
                    max(waiting_s, self._max_dropped_wait_s))

    def service_time_ms(self) -> dict[str, float]:
        """Learned expected batch service time (ms) per warm
        ``"model_id/bucket"`` key — the EWMA that drives service-time
        admission, deadline urgency, and router slack balancing."""
        with self._cond:
            return {f"{mid}/{bucket.describe()}": ewma * 1e3
                    for (mid, bucket), ewma in self._service_ewma.items()}

    def queue_pressure(self) -> tuple[float, int]:
        """(estimated backlog seconds, raw waiting count) — one locked read.

        Backlog = per-group queued batches x expected service time, plus
        the in-flight tail; groups with no estimate use the engine-wide
        mean (0 when nothing is warm yet, which degrades router slack
        ordering to the raw-queue-length tie-break).  ``EngineRouter``
        sorts replicas by exactly this tuple.
        """
        with self._cond:
            ewma = self._service_ewma
            mean = sum(ewma.values()) / len(ewma) if ewma else 0.0
            backlog = 0.0
            for key, dq in self._groups.items():
                est = self._expected_service_locked(key)
                backlog += (math.ceil(len(dq) / self.slots)
                            * (mean if est is None else est))
            if self._inflight:
                backlog += math.ceil(self._inflight / self.slots) * mean
            return backlog, self._num_waiting

    def pipeline_stats(self) -> dict:
        """Configured depth + cumulative per-stage busy seconds (a report
        turns these into busy *fractions* of the measured wall clock;
        exec is device occupancy — the device lock serializes execution —
        so overlap shows as exec near 1.0 with stack-busy nonzero)."""
        with self._cond:
            return {"depth": self.pipeline_depth,
                    "stack_busy_s": self._stack_busy_s,
                    "exec_busy_s": self._exec_busy_s}

    def report(self, wall_s: float) -> ServeReport:
        wait_ticks, wait_s = self.queue_wait_gauges()
        with self._cond:
            records = list(self.records)
        return build_report(records, wall_s, self.cache.stats,
                            self.pool.trace_count, self.backend,
                            scheduler=self.scheduler.name,
                            admission_stats=self.admission.stats,
                            queue_max_wait_ticks=wait_ticks,
                            queue_max_wait_s=wait_s,
                            kernel_configs=self.pool.kernel_configs(),
                            topology=self.pool.topology(),
                            service_time_ms=self.service_time_ms(),
                            pipeline=self.pipeline_stats())

    def reset_metrics(self) -> None:
        """Zero serving metrics while keeping compiled executors, cache
        entries, and the service-time EWMAs (a learned model, not a
        metric) — so benchmarks can warm up and then measure steady
        state."""
        with self._cond:
            self.results.clear()
            self.records.clear()
            self.shed_rids.clear()
            self._shed_set.clear()
            self._max_dropped_wait_ticks = 0
            self._max_dropped_wait_s = 0.0
            self._stack_busy_s = 0.0
            self._exec_busy_s = 0.0
            self.cache.stats = CacheStats()
            self.admission.stats = AdmissionStats()
