"""Pluggable batch-formation policies for the multi-model serving engine.

The engine keeps its waiting requests grouped by ``(model_id, bucket)`` —
only members of one group can ride the same vmapped executor call.  Each
tick the engine summarizes every non-empty group as a ``GroupState`` and
asks the active ``Scheduler`` which group to serve next:

  * ``FifoScheduler`` — head-of-line: serve the group holding the globally
    oldest request.  Fair, but under a heterogeneous catalog the oldest
    group is often nearly empty, so batch occupancy (and therefore
    throughput) suffers.
  * ``OccupancyScheduler`` — serve the fullest group (capped at ``slots``:
    a group deeper than one batch is no fuller, effectively), which
    maximizes per-call occupancy.  Raw greedy occupancy starves cold
    groups under sustained load, so an age bound overrides it: once any
    group's head request has waited ``starvation_ticks`` engine ticks (or
    ``starvation_age_s`` wall seconds, if set), the oldest starved group is
    served first.  The bound makes the maximum request age finite — a cold
    request waits at most ``starvation_ticks + (#groups - 1)`` ticks.

Policies are deliberately host-side and stateless: they look only at the
queue summary, never at the arrays, so adding one (deadline-aware,
weighted-fair, ...) means implementing one method.
"""

from __future__ import annotations

import dataclasses
from typing import Hashable, Protocol, Sequence, runtime_checkable

GroupKey = Hashable  # in the engine: (model_id, Bucket)


@dataclasses.dataclass(frozen=True)
class GroupState:
    """One waiting ``(model_id, bucket)`` group, summarized for a policy."""

    key: GroupKey
    size: int             # requests waiting in this group
    head_seq: int         # global submission sequence of its oldest request
    head_wait_ticks: int  # engine ticks the oldest request has waited
    head_age_s: float     # wall seconds the oldest request has waited


@runtime_checkable
class Scheduler(Protocol):
    """Batch-formation policy: pick the next group to serve."""

    name: str

    def select(self, groups: Sequence[GroupState], slots: int) -> GroupKey:
        """Return the key of the group to serve (``groups`` is non-empty)."""
        ...


class FifoScheduler:
    """Head-of-line: always the group holding the globally oldest request."""

    name = "fifo"

    def select(self, groups: Sequence[GroupState], slots: int) -> GroupKey:
        return min(groups, key=lambda g: g.head_seq).key


class OccupancyScheduler:
    """Fullest-group-first with an age-based anti-starvation bound."""

    name = "occupancy"

    def __init__(self, starvation_ticks: int = 32,
                 starvation_age_s: float | None = None):
        if starvation_ticks < 1:
            raise ValueError("starvation_ticks must be >= 1")
        if starvation_age_s is not None and starvation_age_s <= 0:
            raise ValueError("starvation_age_s must be positive")
        self.starvation_ticks = starvation_ticks
        self.starvation_age_s = starvation_age_s

    def _starved(self, g: GroupState) -> bool:
        if g.head_wait_ticks >= self.starvation_ticks:
            return True
        return (self.starvation_age_s is not None
                and g.head_age_s >= self.starvation_age_s)

    def select(self, groups: Sequence[GroupState], slots: int) -> GroupKey:
        starved = [g for g in groups if self._starved(g)]
        if starved:
            return min(starved, key=lambda g: g.head_seq).key
        # Effective occupancy saturates at the batch width; among equally
        # full groups prefer the one whose head has waited longest.
        return max(groups,
                   key=lambda g: (min(g.size, slots), -g.head_seq)).key


SCHEDULERS = ("fifo", "occupancy")


def make_scheduler(policy, **kwargs) -> Scheduler:
    """Resolve a policy name (or pass through a Scheduler instance)."""
    if isinstance(policy, str):
        if policy == "fifo":
            return FifoScheduler(**kwargs)
        if policy == "occupancy":
            return OccupancyScheduler(**kwargs)
        raise ValueError(
            f"unknown scheduler '{policy}'; expected one of {SCHEDULERS}")
    if isinstance(policy, Scheduler):
        return policy
    raise TypeError(f"not a Scheduler: {policy!r}")
