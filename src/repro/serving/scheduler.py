"""Pluggable batch-formation policies for the multi-model serving engine.

The engine keeps its waiting requests grouped by ``(model_id, bucket)`` —
only members of one group can ride the same vmapped executor call.  Each
serve iteration the engine summarizes every non-empty group as a
``GroupState`` and asks the active ``Scheduler`` which group to serve next:

  * ``FifoScheduler`` — head-of-line: serve the group holding the globally
    oldest request.  Fair, but under a heterogeneous catalog the oldest
    group is often nearly empty, so batch occupancy (and therefore
    throughput) suffers.
  * ``OccupancyScheduler`` — serve the fullest group (capped at ``slots``:
    a group deeper than one batch is no fuller, effectively), which
    maximizes per-call occupancy.  Raw greedy occupancy starves cold
    groups under sustained load, so an anti-starvation bound overrides
    it.  The **primary bound is wall-clock** (``starvation_age_s``): under
    the always-on serve loop the iteration rate varies with load (an idle
    engine parks on a condition variable; a loaded one serves
    back-to-back), so "N ticks" is not a latency promise — 32 ticks is
    milliseconds under light load and unbounded seconds under bursty
    arrival gaps.  ``starvation_ticks`` is kept as a **legacy knob**
    (``None`` by default) for tick-driven harnesses that step the engine
    manually and want a deterministic, clock-free bound; when set, either
    bound trips the override.
  * ``DeadlineScheduler`` — SLO-aware batch formation for catalogs whose
    models carry ``slo_ms`` deadlines.  Occupancy-greedy while every
    group has slack, but the moment any group's head deadline is *at
    risk* (wall-clock slack at or below ``urgent_slack_s``) it preempts:
    the urgent group with the earliest deadline is served first (EDF;
    least slack and earliest deadline coincide at the head because slack
    is deadline minus now).  Requests with no SLO have infinite slack, so
    a pure-EDF policy would starve them; the wall-clock ``max_age_s``
    bound marks any group urgent once its head has waited that long —
    the anti-starvation role the tick bound used to play, now expressed
    in the only unit the serve loop actually guarantees.

Policies are deliberately host-side and stateless: they look only at the
queue summary, never at the arrays, so adding one (weighted-fair,
cost-model-driven, ...) means implementing one method.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Hashable, Optional, Protocol, Sequence, runtime_checkable

GroupKey = Hashable  # in the engine: (model_id, Bucket)


@dataclasses.dataclass(frozen=True)
class GroupState:
    """One waiting ``(model_id, bucket)`` group, summarized for a policy."""

    key: GroupKey
    size: int             # requests waiting in this group
    head_seq: int         # global submission sequence of its oldest request
    head_wait_ticks: int  # serve iterations the oldest request has waited
    head_age_s: float     # wall seconds the oldest request has waited
    # SLO contract of the oldest request (the head has the earliest
    # deadline in its group: one group is one model, so one SLO, and
    # arrival order is submission order).  inf = no deadline.
    head_deadline_s: float = math.inf   # absolute perf_counter deadline
    head_slack_s: float = math.inf      # deadline minus now (< 0 = blown)
    # Learned expected batch service time for this group (the engine's
    # per-(model, bucket) EWMA); 0.0 while the key is cold.  Lets the
    # deadline policy scale its urgency margin to how long this group's
    # batches actually take instead of one engine-wide constant.
    head_est_service_s: float = 0.0


@runtime_checkable
class Scheduler(Protocol):
    """Batch-formation policy: pick the next group to serve."""

    name: str

    def select(self, groups: Sequence[GroupState], slots: int) -> GroupKey:
        """Return the key of the group to serve (``groups`` is non-empty)."""
        ...


class FifoScheduler:
    """Head-of-line: always the group holding the globally oldest request."""

    name = "fifo"

    def select(self, groups: Sequence[GroupState], slots: int) -> GroupKey:
        return min(groups, key=lambda g: g.head_seq).key


class OccupancyScheduler:
    """Fullest-group-first with a wall-clock anti-starvation bound.

    ``starvation_age_s`` (primary, default 0.5 s) marks a group starved
    once its head request has waited that many wall seconds;
    ``starvation_ticks`` (legacy, default off) additionally marks a group
    starved after that many serve iterations — only meaningful to
    harnesses that drive ``step()`` at a known cadence.  Starved groups
    preempt occupancy greed, oldest head first.
    """

    name = "occupancy"

    def __init__(self, starvation_age_s: Optional[float] = 0.5,
                 starvation_ticks: Optional[int] = None):
        if starvation_age_s is not None and starvation_age_s <= 0:
            raise ValueError("starvation_age_s must be positive")
        if starvation_ticks is not None and starvation_ticks < 1:
            raise ValueError("starvation_ticks must be >= 1")
        self.starvation_age_s = starvation_age_s
        self.starvation_ticks = starvation_ticks

    def _starved(self, g: GroupState) -> bool:
        if (self.starvation_age_s is not None
                and g.head_age_s >= self.starvation_age_s):
            return True
        return (self.starvation_ticks is not None
                and g.head_wait_ticks >= self.starvation_ticks)

    def select(self, groups: Sequence[GroupState], slots: int) -> GroupKey:
        starved = [g for g in groups if self._starved(g)]
        if starved:
            return min(starved, key=lambda g: g.head_seq).key
        # Effective occupancy saturates at the batch width; among equally
        # full groups prefer the one whose head has waited longest.
        return max(groups,
                   key=lambda g: (min(g.size, slots), -g.head_seq)).key


class DeadlineScheduler:
    """EDF / least-slack batch formation with an occupancy fallback.

    Two regimes:

      relaxed — no group is at risk: serve the fullest group (occupancy
        greed, throughput mode); among equally full groups prefer the
        earliest head deadline, then the oldest head.
      urgent — some group's head slack is at or below ``urgent_slack_s``
        (its deadline is closer than the margin reserved for service
        time), or its head has waited ``max_age_s`` wall seconds (the
        anti-starvation bound for no-SLO traffic, whose slack is
        infinite): serve the urgent group with the earliest deadline
        (ties: oldest head) even if it forms a nearly empty batch.

    ``urgent_slack_s`` should cover roughly one batch service time plus
    result materialization — the point past which waiting one more
    iteration turns a meetable deadline into a miss.  When the engine has
    a learned service-time estimate for a group
    (``GroupState.head_est_service_s``), the urgency margin is the *max*
    of the static knob and that estimate: a group whose head slack is
    inside one expected batch service is at risk by definition, however
    the knob was tuned (cold groups fall back to the knob alone).
    """

    name = "deadline"

    def __init__(self, urgent_slack_s: float = 0.01,
                 max_age_s: Optional[float] = 0.5):
        if urgent_slack_s < 0:
            raise ValueError("urgent_slack_s must be >= 0")
        if max_age_s is not None and max_age_s <= 0:
            raise ValueError("max_age_s must be positive")
        self.urgent_slack_s = urgent_slack_s
        self.max_age_s = max_age_s

    def _urgent(self, g: GroupState) -> bool:
        if g.head_slack_s <= max(self.urgent_slack_s, g.head_est_service_s):
            return True
        return self.max_age_s is not None and g.head_age_s >= self.max_age_s

    def select(self, groups: Sequence[GroupState], slots: int) -> GroupKey:
        urgent = [g for g in groups if self._urgent(g)]
        if urgent:
            return min(urgent,
                       key=lambda g: (g.head_deadline_s, g.head_seq)).key
        return max(groups, key=lambda g: (min(g.size, slots),
                                          -g.head_deadline_s,
                                          -g.head_seq)).key


SCHEDULERS = ("fifo", "occupancy", "deadline")


def make_scheduler(policy, **kwargs) -> Scheduler:
    """Resolve a policy name (or pass through a Scheduler instance)."""
    if isinstance(policy, str):
        if policy == "fifo":
            return FifoScheduler(**kwargs)
        if policy == "occupancy":
            return OccupancyScheduler(**kwargs)
        if policy == "deadline":
            return DeadlineScheduler(**kwargs)
        raise ValueError(
            f"unknown scheduler '{policy}'; expected one of {SCHEDULERS}")
    if isinstance(policy, Scheduler):
        return policy
    raise TypeError(f"not a Scheduler: {policy!r}")
