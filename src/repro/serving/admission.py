"""Admission control: bound the waiting queue so overload degrades cleanly.

An unbounded intake queue turns overload into unbounded memory growth and
unbounded tail latency.  The controller caps the number of waiting requests
(``max_waiting``; ``None`` = unbounded, the drain-style default) and decides
what happens at the cap:

  * ``"reject"``      — the new request is turned away (the caller sees
                        ``try_submit(...) -> None`` or ``QueueFullError``
                        from ``submit``); backpressure lands on the newest
                        traffic.
  * ``"shed-oldest"`` — the oldest waiting request is dropped to make room;
                        the new request is admitted.  Sheds load from the
                        stalest work instead (its rid never produces a
                        result; the engine lists it in ``shed_rids``).

``AdmissionStats`` (admitted / rejected / shed) is folded into the serve
report so reject and shed rates are first-class serving metrics.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

ADMISSION_POLICIES = ("reject", "shed-oldest")


@dataclasses.dataclass
class AdmissionStats:
    admitted: int = 0
    rejected: int = 0
    shed: int = 0

    @property
    def offered(self) -> int:
        return self.admitted + self.rejected

    @property
    def reject_rate(self) -> float:
        return self.rejected / self.offered if self.offered else 0.0


class AdmissionController:
    """Bounded-queue gatekeeper; ``decide`` also maintains the stats."""

    def __init__(self, max_waiting: Optional[int] = None,
                 policy: str = "reject"):
        if max_waiting is not None and max_waiting < 1:
            raise ValueError("max_waiting must be >= 1 (or None = unbounded)")
        if policy not in ADMISSION_POLICIES:
            raise ValueError(f"unknown admission policy '{policy}'; "
                             f"expected one of {ADMISSION_POLICIES}")
        self.max_waiting = max_waiting
        self.policy = policy
        self.stats = AdmissionStats()

    def decide(self, queued: int) -> str:
        """'admit' | 'reject' | 'shed' for one offered request.

        'shed' means: admit the new request after the caller drops the
        oldest waiting one (both counters move).
        """
        if self.max_waiting is None or queued < self.max_waiting:
            self.stats.admitted += 1
            return "admit"
        if self.policy == "shed-oldest":
            self.stats.admitted += 1
            self.stats.shed += 1
            return "shed"
        self.stats.rejected += 1
        return "reject"
