"""Admission control: bound the waiting queue so overload degrades cleanly.

An unbounded intake queue turns overload into unbounded memory growth and
unbounded tail latency.  The controller caps the number of waiting requests
(``max_waiting``; ``None`` = unbounded, the drain-style default) and decides
what happens at the cap:

  * ``"reject"``      — the new request is turned away (the caller sees
                        ``try_submit(...) -> None`` or ``QueueFullError``
                        from ``submit``); backpressure lands on the newest
                        traffic.
  * ``"shed-oldest"`` — one waiting request is dropped to make room and
                        the new request is admitted (its rid never
                        produces a result; the engine lists it in
                        ``shed_rids``).  The engine picks the victim with
                        the **least salvageable slack**: the waiting
                        request whose SLO deadline is nearest (or most
                        blown) loses — dropping it forfeits the least
                        remaining chance of an on-time answer.  In a
                        catalog without SLOs every deadline is infinite
                        and the tie-break is submission order, i.e. the
                        historical shed-oldest behavior, which is what
                        the policy name still records.

Concurrency: the controller itself holds no lock — ``decide`` mutates
``stats`` in place.  The engine serializes every call under its intake
lock, *in the same critical section as the queue mutation it gates*, so
the admitted count can never overshoot ``max_waiting`` when many client
threads submit concurrently.  ``try_reject_early`` exists so the reject
fast path can turn a request away before the engine pays preprocessing
for it; the authoritative decision is still the later ``decide`` call
(the queue may have filled — or drained — in between).

``AdmissionStats`` (admitted / rejected / shed) is folded into the serve
report so reject and shed rates are first-class serving metrics.

Service-time admission (``unmeetable``): the engine may also reject a
request *below* the queue bound when its SLO deadline cannot be met even
under immediate scheduling — the learned expected service time of its
(model, bucket) group, times the batches already queued ahead of it,
overruns the deadline.  Serving such a request wastes device time on a
guaranteed miss and steals it from meetable work; rejecting at enqueue is
the cheapest point to say no.  Those rejections are counted both in
``rejected`` (they are refusals the client sees) and separately in
``unmeetable`` so overload reports can distinguish "queue full" from
"deadline infeasible".  The controller only *counts* them — the estimate
and the decision live in the engine, which owns the service-time model.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

ADMISSION_POLICIES = ("reject", "shed-oldest")


@dataclasses.dataclass
class AdmissionStats:
    admitted: int = 0
    rejected: int = 0
    shed: int = 0
    # Subset of ``rejected``: refused because the SLO deadline was
    # infeasible per the engine's service-time model, not because the
    # queue was full.
    unmeetable: int = 0

    @property
    def offered(self) -> int:
        return self.admitted + self.rejected

    @property
    def reject_rate(self) -> float:
        return self.rejected / self.offered if self.offered else 0.0


class AdmissionController:
    """Bounded-queue gatekeeper; ``decide`` also maintains the stats.

    Not internally locked: callers (the engine) must serialize ``decide``
    with the queue mutation it authorizes.
    """

    def __init__(self, max_waiting: Optional[int] = None,
                 policy: str = "reject"):
        if max_waiting is not None and max_waiting < 1:
            raise ValueError("max_waiting must be >= 1 (or None = unbounded)")
        if policy not in ADMISSION_POLICIES:
            raise ValueError(f"unknown admission policy '{policy}'; "
                             f"expected one of {ADMISSION_POLICIES}")
        self.max_waiting = max_waiting
        self.policy = policy
        self.stats = AdmissionStats()

    def try_reject_early(self, queued: int) -> bool:
        """Reject-and-count when the queue is full under the reject policy.

        The preprocessing fast-out: a request the queue has no room for
        should not pay partitioning first.  Returns True (and counts the
        rejection) only when ``decide`` would certainly reject right now;
        shed policies never reject, so they never take this path.
        """
        if (self.max_waiting is not None and self.policy == "reject"
                and queued >= self.max_waiting):
            self.stats.rejected += 1
            return True
        return False

    def reject_unmeetable(self) -> None:
        """Count one SLO-infeasible rejection (engine-decided: expected
        service time says the deadline cannot be met even if scheduled
        immediately).  Same critical section as ``decide`` would be."""
        self.stats.rejected += 1
        self.stats.unmeetable += 1

    def decide(self, queued: int) -> str:
        """'admit' | 'reject' | 'shed' for one offered request.

        'shed' means: admit the new request after the caller drops one
        waiting victim (both counters move).  Must be called in the same
        critical section as the enqueue it authorizes.
        """
        if self.max_waiting is None or queued < self.max_waiting:
            self.stats.admitted += 1
            return "admit"
        if self.policy == "shed-oldest":
            self.stats.admitted += 1
            self.stats.shed += 1
            return "shed"
        self.stats.rejected += 1
        return "reject"
