"""GHOST analytic performance & energy simulator (paper Section 4.1).

Reproduces the paper's evaluation methodology: an analytic (not
discrete-event) model that combines

  * the Table-1 optoelectronic device latencies/powers (photonic/devices.py),
  * the partition-matrix occupancy of the input graph (core/partition.py),
  * the per-model execution order and pipelining schedule (core/pipeline.py),
  * buffer + HBM energies (CACTI/DRAMsim3-derived constants), and
  * the laser-power link budget (Eq. 13)

into per-block latency/energy, total power, GOPS, and EPB for a given
[N, V, R_r, R_c, T_r] architecture configuration and orchestration flags
(BP / PP / DAC-sharing / WB — Section 3.4, Fig. 8).

Conventions
-----------
* 8-bit values everywhere (Section 4.1: 8-bit quantized models).
* One "mapping" = one tile of work on an optical unit:
    reduce unit    R_r features x R_c neighbors per mapping
    transform unit R_r inputs   x T_r outputs   per mapping
* ops are MACs counted as 2 ops (mul + add), the usual GOPS convention.
* EPB = total energy / total data bits processed (bits = MAC operands x 8).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

import numpy as np

from repro.common.utils import cdiv
from repro.core.graph import Graph
from repro.core.pipeline import StageLoad, grouped_latency
from repro.photonic import devices as dev
from repro.photonic.mrbank import COHERENT_BANK_LIMIT, NONCOHERENT_WDM_LIMIT
from repro.photonic.devices import LinkLoss, bank_waveguide_cm, dbm_to_watts, laser_power_dbm

BYTES_PER_VALUE = 1  # 8-bit

# --- calibrated duty/overhead factors (documented deviations; DESIGN.md §6) --
# Thermal (TO) trimming: fraction of MRs needing active thermal bias at any
# time after TED optimization (Section 3.1), times the average trim distance
# as a fraction of one FSR.  Post-TED, ~30% of rings hold a ~5%-FSR trim
# against fabrication offsets — calibrated so total accelerator power at the
# optimal config lands at the paper's reported ~18 W.
TO_TRIM_DUTY = 0.30
TO_TRIM_FSR_FRACTION = 0.05
# Static ECU/control overhead (sequencers, clocking, misc digital): watts.
ECU_STATIC_POWER = 1.2


@dataclasses.dataclass(frozen=True)
class GhostConfig:
    """The five architectural parameters (Section 4.3)."""

    n: int = 20   # edge-control units / input-group size
    v: int = 20   # execution lanes / output-group size
    rr: int = 18  # reduce-unit rows  = wavelengths into each transform row
    rc: int = 7   # reduce-unit cols  = neighbors per coherent mapping
    tr: int = 17  # transform-unit rows = output features per mapping

    def validate(self) -> "GhostConfig":
        if self.rc + 1 > COHERENT_BANK_LIMIT:  # +1 for the accumulation MR
            raise ValueError(
                f"R_c={self.rc} exceeds coherent bank limit {COHERENT_BANK_LIMIT - 1}"
            )
        if self.rr > NONCOHERENT_WDM_LIMIT:
            raise ValueError(
                f"R_r={self.rr} exceeds WDM limit {NONCOHERENT_WDM_LIMIT}"
            )
        if min(self.n, self.v, self.rr, self.rc, self.tr) < 1:
            raise ValueError("all architecture parameters must be >= 1")
        return self

    # ---- device inventory (drives idle power + DAC counts) ----
    @property
    def reduce_mrs(self) -> int:
        return self.v * self.rr * (self.rc + 1)

    @property
    def transform_mrs(self) -> int:
        return self.v * self.tr * self.rr

    @property
    def bn_mrs(self) -> int:
        return self.v * self.tr

    @property
    def total_mrs(self) -> int:
        return self.reduce_mrs + self.transform_mrs + self.bn_mrs

    @property
    def vcsels(self) -> int:
        return self.v * self.rr + self.v * self.tr  # reduce rows + update drive

    @property
    def pds(self) -> int:
        return self.v * self.rr + self.v * self.tr  # reduce-row PDs + BPD pairs

    @property
    def soas(self) -> int:
        return self.v * self.tr


@dataclasses.dataclass(frozen=True)
class OrchFlags:
    """Orchestration & scheduling optimizations (Section 3.4)."""

    bp: bool = True           # graph buffering & partitioning (zero-block skip)
    pp: bool = True           # two-level execution pipelining
    dac_sharing: bool = True  # weight DAC sharing across transform units
    wb: bool = False          # workload balancing (paper: used only w/ BP+PP,
                              # and incompatible with DAC sharing)

    def validate(self) -> "OrchFlags":
        if self.wb and self.dac_sharing:
            raise ValueError(
                "workload balancing requires per-lane rates and cannot be "
                "combined with weight-DAC sharing (Section 4.4)"
            )
        if self.wb and not self.bp:
            raise ValueError("workload balancing requires buffer-and-partition")
        return self


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    f_in: int
    f_out: int
    reduce: str = "sum"              # sum | mean | max
    activation: str = "relu"
    heads: int = 1                   # GAT attention heads
    order: str = "aggregate_first"   # or transform_first (GAT)
    mlp_layers: int = 1              # GIN: combine is an MLP


@dataclasses.dataclass(frozen=True)
class GnnModelSpec:
    name: str
    layers: tuple
    readout: bool = False            # graph classification: sum-pool + classify

    @staticmethod
    def gcn(f_in: int, hidden: int, classes: int) -> "GnnModelSpec":
        return GnnModelSpec("GCN", (
            LayerSpec(f_in, hidden, "sum", "relu"),
            LayerSpec(hidden, classes, "sum", "softmax"),
        ))

    @staticmethod
    def graphsage(f_in: int, hidden: int, classes: int) -> "GnnModelSpec":
        return GnnModelSpec("GraphSAGE", (
            LayerSpec(f_in, hidden, "mean", "relu"),
            LayerSpec(hidden, classes, "mean", "softmax"),
        ))

    @staticmethod
    def gin(f_in: int, hidden: int, classes: int, mlp_layers: int = 8) -> "GnnModelSpec":
        return GnnModelSpec("GIN", (
            LayerSpec(f_in, hidden, "sum", "relu", mlp_layers=mlp_layers),
            LayerSpec(hidden, classes, "sum", "relu"),
        ), readout=True)

    @staticmethod
    def gat(f_in: int, hidden: int, classes: int, heads: int = 8) -> "GnnModelSpec":
        return GnnModelSpec("GAT", (
            LayerSpec(f_in, hidden, "sum", "leaky_relu", heads=heads,
                      order="transform_first"),
            LayerSpec(hidden * heads, classes, "sum", "softmax", heads=1,
                      order="transform_first"),
        ))


@dataclasses.dataclass
class GroupProfile:
    """Per-output-group occupancy for one graph at one (V, N)."""

    tiles_per_group: np.ndarray   # [G_dst] non-zero source tiles
    max_deg_per_group: np.ndarray  # [G_dst] max in-degree within group
    mean_deg_per_group: np.ndarray
    edges_per_group: np.ndarray    # [G_dst] edges terminating in group
    distinct_srcs_per_group: np.ndarray  # [G_dst] unique source vertices
    num_nodes: int
    num_edges: int
    num_dst_groups: int
    num_src_groups: int
    nonzero_tiles: int
    total_tiles: int


_PROFILE_CACHE: dict = {}


def profile_graph(graph: Graph, v: int, n: int) -> GroupProfile:
    # Keyed by id() with a strong reference to the graph kept in the value:
    # the reference pins the object so its id can never be recycled onto a
    # different graph (id-reuse after GC returned stale profiles otherwise).
    key = (id(graph), v, n)
    hit = _PROFILE_CACHE.get(key)
    if hit is not None and hit[0] is graph:
        return hit[1]
    nv = graph.num_nodes
    g_dst = max(1, cdiv(nv, v))
    g_src = max(1, cdiv(nv, n))
    deg = graph.in_degrees()

    # Non-zero tiles per destination group (unique (dstgroup, srcgroup) pairs).
    tr = graph.edge_dst // v
    tc = graph.edge_src // n
    tile_id = tr.astype(np.int64) * g_src + tc.astype(np.int64)
    uniq = np.unique(tile_id)
    tiles = np.zeros(g_dst, dtype=np.int64)
    np.add.at(tiles, (uniq // g_src).astype(np.int64), 1)

    edges_g = np.zeros(g_dst, dtype=np.int64)
    np.add.at(edges_g, tr.astype(np.int64), 1)

    # Unique (dst_group, src_vertex) pairs -> prefetch bytes per group (the
    # ECU's offline fetch list only pulls occupied source vertices once).
    pair_id = tr.astype(np.int64) * nv + graph.edge_src.astype(np.int64)
    uniq_pairs = np.unique(pair_id)
    distinct = np.zeros(g_dst, dtype=np.int64)
    np.add.at(distinct, (uniq_pairs // nv).astype(np.int64), 1)

    pad = g_dst * v - nv
    deg_p = np.concatenate([deg, np.zeros(pad, np.int64)]) if pad else deg
    deg_g = deg_p.reshape(g_dst, v)
    prof = GroupProfile(
        tiles_per_group=tiles,
        max_deg_per_group=deg_g.max(axis=1),
        mean_deg_per_group=deg_g.mean(axis=1),
        edges_per_group=edges_g,
        distinct_srcs_per_group=distinct,
        num_nodes=nv,
        num_edges=graph.num_edges,
        num_dst_groups=g_dst,
        num_src_groups=g_src,
        nonzero_tiles=int(len(uniq)),
        total_tiles=g_dst * g_src,
    )
    _PROFILE_CACHE[key] = (graph, prof)
    return prof


# ---------------------------------------------------------------------------
# Per-mapping optical timings.
# ---------------------------------------------------------------------------


def _reduce_mapping_time() -> float:
    """One reduce-unit mapping: DAC-tune neighbor values, light them,
    interfere, detect, then retune the accumulation MR with the partial sum
    (Fig. 5a's PD -> last-MR feedback path) before the next mapping can
    interfere against it.  Two serialized EO tunings dominate."""
    return (dev.DAC_LATENCY + dev.EO_TUNING_LATENCY + dev.VCSEL_LATENCY
            + dev.PD_LATENCY + dev.EO_TUNING_LATENCY)


def _transform_mapping_time(extra_adc: bool) -> float:
    """One transform-unit mapping: imprint inputs (optical, from reduce),
    weights already tuned (weight-stationary within a mapping), detect at the
    BPD; +ADC when the partial must be digitized for accumulation."""
    t = dev.DAC_LATENCY + dev.EO_TUNING_LATENCY + dev.PD_LATENCY
    if extra_adc:
        t += dev.ADC_LATENCY
    return t


def _update_value_time(activation: str) -> float:
    if activation == "softmax":
        return 1.0 / dev.SOFTMAX_UNIT_FREQ
    return dev.SOA_LATENCY + dev.VCSEL_LATENCY


# ---------------------------------------------------------------------------
# Laser link budgets.
# ---------------------------------------------------------------------------


def _reduce_laser_watts(cfg: GhostConfig) -> float:
    """Optical wall-plug power for all reduce rows while aggregating."""
    loss = LinkLoss(
        waveguide_cm=bank_waveguide_cm(cfg.rc + 1),
        splitters=max(int(math.ceil(math.log2(max(cfg.rc, 1)))), 1),
        combiners=cfg.rc,           # interference junctions along the row
        mrs_passed=cfg.rc + 1,
        mrs_modulating=1,
    )
    p_dbm = laser_power_dbm(loss.total_db, 1)  # coherent row: single wavelength
    per_row = dbm_to_watts(p_dbm) / dev.LASER_EFFICIENCY
    return per_row * cfg.v * cfg.rr


def _transform_laser_watts(cfg: GhostConfig) -> float:
    """Optical wall-plug power for all transform rows while combining."""
    loss = LinkLoss(
        waveguide_cm=bank_waveguide_cm(cfg.rr),
        splitters=1,
        combiners=1,
        mrs_passed=cfg.rr,
        mrs_modulating=2,           # input imprint + weight imprint
    )
    p_dbm = laser_power_dbm(loss.total_db, cfg.rr)  # WDM comb of R_r lambdas
    per_row = dbm_to_watts(p_dbm) / dev.LASER_EFFICIENCY
    return per_row * cfg.v * cfg.tr


# ---------------------------------------------------------------------------
# Phase models.  Each returns (per-group tile counts, per-tile time,
# energy per tile, digital bytes moved per tile).
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class PhaseCost:
    name: str
    latency: float = 0.0
    energy: float = 0.0

    def add(self, other: "PhaseCost"):
        self.latency += other.latency
        self.energy += other.energy


@dataclasses.dataclass
class PerfReport:
    model: str
    dataset: str
    latency: float           # seconds, whole-graph inference
    energy: float            # joules
    power: float             # average watts
    total_ops: float
    gops: float
    epb: float               # J per bit
    epb_per_gops: float
    breakdown: dict          # phase -> PhaseCost
    config: GhostConfig
    flags: OrchFlags

    def pretty(self) -> str:
        bd = ", ".join(
            f"{k}: {v.latency * 1e6:.1f}us/{v.energy * 1e3:.2f}mJ"
            for k, v in self.breakdown.items()
        )
        return (
            f"[{self.model}/{self.dataset}] lat={self.latency * 1e6:.1f}us "
            f"E={self.energy * 1e3:.3f}mJ P={self.power:.1f}W "
            f"GOPS={self.gops:.1f} EPB={self.epb * 1e12:.2f}pJ/b ({bd})"
        )


def _dac_counts(cfg: GhostConfig, flags: OrchFlags) -> tuple[int, int]:
    """(#weight DACs, #vertex-data DACs).

    Without sharing: one DAC per transform-unit MR (paper: 'normally one DAC
    device would be needed for each MR').  With sharing: the V transform
    units share each weight DAC -> count / V (Section 3.4.3).
    """
    weight = cfg.transform_mrs
    if flags.dac_sharing:
        weight = cfg.transform_mrs // cfg.v
    vertex = cfg.v * cfg.rr * cfg.rc  # gather-unit DACs feeding reduce banks
    return weight, vertex


def _aggregate_group_stage(
    deg: float,
    tiles: int,
    distinct_srcs: int,
    group_edges: int,
    f_in: int,
    num_nodes: int,
    cfg: GhostConfig,
    flags: OrchFlags,
) -> tuple[StageLoad, float, float, float]:
    """Reduce-stage load for one output group.

    Returns (stage, hbm_bytes, sram_bytes, hbm_requests) for the group's
    neighbor traffic.  With BP the ECU's offline fetch list prefetches each
    occupied source vertex once per group, overlapped with compute (stage
    time = max(compute, fetch)); without BP each gather lane issues
    sequential on-demand per-neighbor requests (no dedup, per-request stall).
    """
    neighbor_chunks = cdiv(max(int(deg), 1), cfg.rc)
    feature_chunks = cdiv(f_in, cfg.rr)
    mappings = neighbor_chunks * feature_chunks
    t_tile = _reduce_mapping_time()

    feat_matrix_bytes = num_nodes * f_in * BYTES_PER_VALUE
    streams_from_hbm = feat_matrix_bytes > dev.ECU_BUFFERS_KB["input_vertices"] * 1024
    bw = dev.HBM_BANDWIDTH if streams_from_hbm else dev.SRAM_BANDWIDTH

    if flags.bp:
        fetch_bytes = distinct_srcs * f_in * BYTES_PER_VALUE
        fetch_time = fetch_bytes / bw + tiles * dev.SRAM_LATENCY  # + tile metadata
        # Prefetch overlap: the group is bound by the slower of optics/fetch.
        eff_tile = max(t_tile, fetch_time / max(mappings, 1))
        stage = StageLoad("reduce", mappings, eff_tile)
        requests = float(tiles)
    else:
        # On-demand per-neighbor requests: latency + transfer, serialized
        # per lane; the slowest (max-degree) lane bounds the group.
        per_neighbor = (
            (dev.HBM_LATENCY if streams_from_hbm else dev.SRAM_LATENCY)
            + f_in * BYTES_PER_VALUE / bw
        )
        fetch_bytes = group_edges * f_in * BYTES_PER_VALUE  # no dedup
        fetch = deg * per_neighbor
        stage = StageLoad("reduce", mappings, t_tile + fetch / max(mappings, 1))
        requests = float(group_edges)
    hbm_b = fetch_bytes if streams_from_hbm else 0.0
    sram_b = fetch_bytes if not streams_from_hbm else 0.0
    return stage, hbm_b, sram_b, (requests if streams_from_hbm else 0.0)


def simulate_layer(
    spec: LayerSpec,
    prof: GroupProfile,
    cfg: GhostConfig,
    flags: OrchFlags,
    first_layer: bool,
) -> tuple[float, dict, float]:
    """Latency (s), {phase: PhaseCost}, ops for one GNN layer over one graph."""
    g = prof.num_dst_groups
    deg_src = prof.max_deg_per_group if not flags.wb else prof.mean_deg_per_group

    # ---- per-group stage loads ----
    per_group: list[list[StageLoad]] = []
    fo_head = spec.f_out * spec.heads
    combine_maps_per_vertex = (
        cdiv(spec.f_in, cfg.rr) * cdiv(spec.f_out, cfg.tr) * spec.heads
        * spec.mlp_layers
    )
    needs_adc = spec.f_in > cfg.rr
    t_comb = _transform_mapping_time(needs_adc)
    t_upd = _update_value_time(spec.activation)
    upd_values = cdiv(fo_head, cfg.tr)  # T_r SOAs in parallel per lane

    hbm_fetch = 0.0
    sram_fetch = 0.0
    hbm_requests = 0.0
    for i in range(g):
        if spec.order == "aggregate_first":
            reduce_stage, hb, sb, rq = _aggregate_group_stage(
                float(deg_src[i]), int(prof.tiles_per_group[i]),
                int(prof.distinct_srcs_per_group[i]),
                int(prof.edges_per_group[i]), spec.f_in,
                prof.num_nodes, cfg, flags
            )
            stages = [
                reduce_stage,
                StageLoad("transform", combine_maps_per_vertex, t_comb),
                StageLoad("update", upd_values, t_upd),
            ]
        else:
            # GAT (Fig. 6b): transform W.h -> attention MVM + leakyReLU ->
            # softmax (digital) -> weighted reduce at the end.
            attn_maps = cdiv(spec.f_out, cfg.rr) * spec.heads
            softmax_vals = max(int(deg_src[i]), 1) * spec.heads
            red, hb, sb, rq = _aggregate_group_stage(
                float(deg_src[i]), int(prof.tiles_per_group[i]),
                int(prof.distinct_srcs_per_group[i]),
                int(prof.edges_per_group[i]), fo_head,
                prof.num_nodes, cfg, flags
            )
            stages = [
                StageLoad("transform", combine_maps_per_vertex, t_comb),
                StageLoad("attention", attn_maps, t_comb),
                StageLoad("softmax", softmax_vals, 1.0 / dev.SOFTMAX_UNIT_FREQ),
                StageLoad("reduce", red.tiles, red.tile_time),
                StageLoad("update", upd_values, dev.SOA_LATENCY + dev.VCSEL_LATENCY),
            ]
        hbm_fetch += hb
        sram_fetch += sb
        hbm_requests += rq
        per_group.append(stages)

    latency = grouped_latency(per_group, pipeline_within=flags.pp,
                              pipeline_across=flags.pp)

    # ---- energy ----
    costs = {k: PhaseCost(k) for k in ("aggregate", "combine", "update", "memory", "laser", "static")}

    total_reduce_maps = sum(s.tiles for sg in per_group for s in sg if s.name == "reduce")
    total_comb_maps = sum(s.tiles for sg in per_group for s in sg
                          if s.name in ("transform", "attention"))
    total_upd_vals = sum(s.tiles for sg in per_group for s in sg
                         if s.name in ("update", "softmax"))

    w_dacs, v_dacs = _dac_counts(cfg, flags)
    t_red_map = _reduce_mapping_time()

    # Aggregate: EO tuning on active reduce MRs + VCSELs + PDs + vertex DACs + ADC out.
    eo_power = dev.EO_TUNING_POWER_PER_NM * 0.5  # ~half-FWHM average excursion
    agg_time = total_reduce_maps * t_red_map
    agg_devices = (
        cfg.reduce_mrs * eo_power
        + cfg.v * cfg.rr * (dev.VCSEL_POWER + dev.PD_POWER)
        + v_dacs * dev.DAC_POWER
    )
    # Devices are only powered while their phase runs.
    costs["aggregate"].energy = agg_devices * agg_time if total_reduce_maps else 0.0
    costs["aggregate"].energy += total_reduce_maps * cfg.rr * dev.ADC_POWER * dev.ADC_LATENCY
    costs["aggregate"].latency = agg_time

    # Combine: weight DACs + EO on transform MRs + BPDs (+BN MRs).
    comb_time = total_comb_maps * t_comb
    comb_devices = (
        (cfg.transform_mrs + cfg.bn_mrs) * eo_power
        + cfg.v * cfg.tr * dev.PD_POWER
        + w_dacs * dev.DAC_POWER
    )
    costs["combine"].energy = comb_devices * comb_time if total_comb_maps else 0.0
    if needs_adc:
        costs["combine"].energy += total_comb_maps * cfg.tr * dev.ADC_POWER * dev.ADC_LATENCY
    costs["combine"].latency = comb_time

    # Update: SOAs or digital softmax.
    upd_time = sum(s.total for sg in per_group for s in sg
                   if s.name in ("update", "softmax"))
    upd_devices = cfg.soas * dev.SOA_POWER + cfg.v * dev.SOFTMAX_UNIT_POWER * (
        1.0 if spec.activation == "softmax" or spec.order == "transform_first" else 0.0
    )
    costs["update"].energy = upd_devices * upd_time if total_upd_vals else 0.0
    costs["update"].latency = upd_time

    # Memory: neighbor-tile traffic (from the aggregate stage model above),
    # edge/partition metadata, weights, and intermediate writes.
    edge_bytes = prof.num_edges * 8  # src,dst int32 pairs
    weight_bytes = spec.f_in * fo_head * BYTES_PER_VALUE * spec.mlp_layers
    hbm_bytes = hbm_fetch + (edge_bytes if first_layer else 0.0)
    sram_bytes = sram_fetch + weight_bytes
    costs["memory"].energy = (
        sram_bytes * dev.SRAM_READ_ENERGY_PER_BYTE
        + prof.num_nodes * fo_head * BYTES_PER_VALUE * dev.SRAM_WRITE_ENERGY_PER_BYTE
        + hbm_bytes * dev.HBM_ENERGY_PER_BYTE
        + hbm_requests * dev.HBM_REQUEST_ENERGY
    )
    costs["memory"].latency = 0.0  # overlapped with compute when BP is on
    if not flags.bp:
        costs["memory"].latency = hbm_bytes / dev.HBM_BANDWIDTH

    # Laser: powered during its phase.
    costs["laser"].energy = (
        _reduce_laser_watts(cfg) * agg_time + _transform_laser_watts(cfg) * comb_time
    )

    # Static: TO trimming + ECU + buffer leakage, over the layer makespan.
    leak = sum(dev.ECU_BUFFERS_KB.values()) * dev.SRAM_LEAKAGE_POWER_PER_KB
    static_power = (
        cfg.total_mrs * TO_TRIM_DUTY * dev.TO_TUNING_POWER_PER_FSR
        * TO_TRIM_FSR_FRACTION
        + ECU_STATIC_POWER + leak
    )
    costs["static"].energy = static_power * latency
    costs["static"].latency = 0.0

    # ---- op count ----
    agg_ops = 2.0 * prof.num_edges * spec.f_in
    comb_ops = 2.0 * prof.num_nodes * spec.f_in * fo_head * spec.mlp_layers
    upd_ops = prof.num_nodes * fo_head
    if spec.order == "transform_first":
        agg_ops = 2.0 * prof.num_edges * fo_head          # weighted reduce on W.h
        comb_ops += 2.0 * prof.num_nodes * fo_head        # attention vector MVM
        upd_ops += prof.num_edges * spec.heads            # softmax values
    ops = agg_ops + comb_ops + upd_ops

    return latency, costs, ops


def simulate(
    model: GnnModelSpec,
    graphs: Graph | Sequence[Graph],
    cfg: GhostConfig = GhostConfig(),
    flags: OrchFlags = OrchFlags(),
    dataset_name: str = "dataset",
) -> PerfReport:
    """Whole-dataset inference cost (sum over graphs, as the paper's
    graph-classification datasets are processed graph-by-graph)."""
    cfg = cfg.validate()
    flags = flags.validate()
    graph_list = [graphs] if isinstance(graphs, Graph) else list(graphs)

    latency = 0.0
    ops = 0.0
    breakdown = {k: PhaseCost(k) for k in
                 ("aggregate", "combine", "update", "memory", "laser", "static")}

    for graph in graph_list:
        for li, layer in enumerate(model.layers):
            prof = profile_graph(graph, cfg.v, cfg.n)
            lat, costs, layer_ops = simulate_layer(layer, prof, cfg, flags,
                                                   first_layer=(li == 0))
            latency += lat + costs["memory"].latency
            ops += layer_ops
            for k, c in costs.items():
                breakdown[k].add(c)
        if model.readout:
            # Sum-pool + linear classify: one extra tiny combine pass.
            f = model.layers[-1].f_out
            t = _transform_mapping_time(False) * cdiv(f, cfg.rr)
            latency += t
            breakdown["combine"].add(PhaseCost("combine", t,
                                               t * cfg.tr * dev.PD_POWER))

    energy = sum(c.energy for c in breakdown.values())
    power = energy / latency if latency > 0 else 0.0
    bits = ops * 8.0
    gops = ops / latency / 1e9 if latency > 0 else 0.0
    epb = energy / bits if bits else 0.0
    return PerfReport(
        model=model.name,
        dataset=dataset_name,
        latency=latency,
        energy=energy,
        power=power,
        total_ops=ops,
        gops=gops,
        epb=epb,
        epb_per_gops=(epb / gops if gops else float("inf")),
        breakdown=breakdown,
        config=cfg,
        flags=flags,
    )
