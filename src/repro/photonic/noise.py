"""Photonic crosstalk noise models (paper Section 3.2, Eqs. 2-13).

Three analog noise sources and their mitigation, as modeled by the paper:

* thermal crosstalk   — cancelled by TED tuning (Section 3.1); we reproduce
                        TED as the linear eigen-decomposition it is
                        (``ted_drive_levels``), so the residual thermal term
                        is zero when TED is on, matching the paper's
                        assumption that rho excludes thermal phase errors.
* heterodyne (inter-channel) crosstalk — spectral leakage between WDM
                        channels in non-coherent MR banks (Eqs. 2-3).
* homodyne (coherent) crosstalk — same-wavelength leakage through a bank of
                        coherent-summation MRs (Eq. 6).

Calibration note (honest-deviation ledger, DESIGN.md Section 6): the paper
obtains its coupling coefficients Phi and X_MR from Ansys Lumerical
multiphysics sweeps we cannot run offline.  We therefore model the MR power
response as a generalized Lorentzian of order ``filter_order`` and the
coherent per-MR leakage with a coupling-dispersion minimum, and calibrate the
three free parameters (filter_order, group_index, coherent leak) so the model
reproduces the paper's *reported* device-level results exactly:

  - required SNR = 21.2 dB for N_levels = 2^7 at the chosen design (Eq. 12),
  - non-coherent banks: 18 wavelengths (36 MRs), 1550-1568 nm @ 1 nm spacing,
    Q = 3100 (Fig. 7b),
  - coherent banks: 20 MRs max at lambda = 1520 nm (Fig. 7a).

Every downstream consumer (MR-bank DSE, the perf model's bank sizes, the
noise-faithful inference mode) reads these models, so the calibration is a
single point of provenance.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.photonic.devices import MR_THROUGH_LOSS_DB


@dataclasses.dataclass(frozen=True)
class MRDesign:
    """The MR design point selected by the paper's device DSE (Section 4.2)."""

    q_factor: float = 3100.0
    radius_um: float = 10.0
    gap_nm: float = 300.0
    waveguide_width_nm: float = 450.0
    # --- calibrated model parameters (see module docstring) ---
    filter_order: float = 2.1        # generalized-Lorentzian order
    group_index: float = 2.1         # sets FSR = lambda^2 / (n_g * 2 pi R)
    coherent_leak_base: float = 3.9e-4   # per-MR leakage at the optimum
    coherent_leak_dispersion: float = 0.02  # 1/nm^2 coupling-mismatch penalty
    coherent_opt_wavelength_nm: float = 1520.0


def fwhm_nm(wavelength_nm: float, q_factor: float) -> float:
    """Eq. 5: FWHM = lambda_res / Q."""
    return wavelength_nm / q_factor


def tunable_range_nm(wavelength_nm: float, q_factor: float) -> float:
    """The paper's R_tune = 2 x FWHM (Section 3.2)."""
    return 2.0 * fwhm_nm(wavelength_nm, q_factor)


def fsr_nm(wavelength_nm: float, design: MRDesign) -> float:
    """Free spectral range of the ring: FSR = lambda^2 / (n_g L)."""
    circumference_nm = 2.0 * math.pi * design.radius_um * 1e3
    return wavelength_nm ** 2 / (design.group_index * circumference_nm)


def spectral_overlap(
    lambda_i_nm: float, lambda_j_nm: float, q_factor: float, filter_order: float
) -> float:
    """Crosstalk coupling factor Phi(lambda_i, lambda_j, Q) (Eqs. 2-3).

    Generalized-Lorentzian power response of MR_i evaluated at lambda_j:
    Phi = 1 / (1 + (2 Q dlambda / lambda)^(2 m)).  Phi(i, i) = 1.
    """
    detune = 2.0 * q_factor * abs(lambda_i_nm - lambda_j_nm) / lambda_i_nm
    return 1.0 / (1.0 + detune ** (2.0 * filter_order))


def heterodyne_noise_fraction(
    wavelengths_nm: np.ndarray, q_factor: float, filter_order: float
) -> float:
    """Worst-channel P_het_noise / P_signal for a WDM bank (Eq. 3 / Eq. 2).

    Each channel i receives sum_{j != i} Phi(lambda_i, lambda_j) of leaked
    power (relative to the per-channel signal power); the worst channel
    bounds the bank.
    """
    lam = np.asarray(wavelengths_nm, dtype=np.float64)
    if lam.size < 2:
        return 0.0
    d = np.abs(lam[:, None] - lam[None, :])
    detune = 2.0 * q_factor * d / lam[:, None]
    phi = 1.0 / (1.0 + detune ** (2.0 * filter_order))
    np.fill_diagonal(phi, 0.0)
    return float(phi.sum(axis=1).max())


def coherent_mr_leak(wavelength_nm: float, design: MRDesign) -> float:
    """Per-MR homodyne leakage X_MR at worst-case phase rho = 0 (Eq. 6).

    The coupling-dispersion term penalizes operating away from the
    gap/width-matched design wavelength — this is what makes 1520 nm the
    coherent-bank optimum in Fig. 7a.
    """
    dl = wavelength_nm - design.coherent_opt_wavelength_nm
    return design.coherent_leak_base * (1.0 + design.coherent_leak_dispersion * dl * dl)


def homodyne_noise_fraction(
    num_mrs: int, wavelength_nm: float, design: MRDesign, rho: float = 0.0
) -> float:
    """P_hom_noise / P_in for a coherent bank of ``num_mrs`` MRs (Eq. 6).

    P_hom = sum_i P_in X_MR^i(rho) L_p^(n-i); the leaked field interferes
    with phase rho (worst case rho = 0, fully constructive).  L_p is the
    per-MR through (passing) loss.
    """
    if num_mrs <= 0:
        return 0.0
    x = coherent_mr_leak(wavelength_nm, design) * 0.5 * (1.0 + math.cos(rho))
    lp = 10.0 ** (-MR_THROUGH_LOSS_DB / 10.0)  # linear passing transmission
    powers = lp ** np.arange(num_mrs)[::-1]    # L_p^(n-i), i = 1..n
    return float(x * powers.sum())


def snr_db(noise_fraction: float) -> float:
    """Eq. 4: SNR = 10 log10(P_signal / P_noise) with P_signal normalized."""
    return 10.0 * math.log10(1.0 / max(noise_fraction, 1e-30))


def required_snr_db(n_levels: int, wavelength_nm: float, q_factor: float) -> float:
    """Eq. 12: 10 log10(N_levels / R_tune) < SNR  (R_tune in nm, as the paper
    evaluates it — yields the reported 21.3 dB for N_levels=2^7, Q=3100)."""
    r_tune = tunable_range_nm(wavelength_nm, q_factor)
    return 10.0 * math.log10(n_levels / r_tune)


def q_factor_from_coupling(
    kappa: float, attenuation: float, wavelength_nm: float, design: MRDesign
) -> float:
    """Eq. 7: Q = pi n_g L sqrt((1-kappa^2) a) / (lambda (1 - a (1-kappa^2)))."""
    circumference_nm = 2.0 * math.pi * design.radius_um * 1e3
    t2a = (1.0 - kappa ** 2) * attenuation
    if t2a >= 1.0:
        raise ValueError("lossless over-coupled ring: Q diverges")
    num = math.pi * design.group_index * circumference_nm * math.sqrt(t2a)
    den = wavelength_nm * (1.0 - t2a)
    return num / den


# ---------------------------------------------------------------------------
# Feasibility / DSE primitives (consumed by photonic/mrbank.py).
# ---------------------------------------------------------------------------


def coherent_bank_feasible(
    num_mrs: int, wavelength_nm: float, design: MRDesign, n_levels: int = 128
) -> bool:
    noise = homodyne_noise_fraction(num_mrs, wavelength_nm, design)
    return snr_db(noise) >= required_snr_db(n_levels, wavelength_nm, design.q_factor)


def max_coherent_mrs(
    wavelength_nm: float, design: MRDesign = MRDesign(), n_levels: int = 128,
    hard_cap: int = 64,
) -> int:
    n = 0
    while n < hard_cap and coherent_bank_feasible(n + 1, wavelength_nm, design, n_levels):
        n += 1
    return n


def noncoherent_bank_feasible(
    num_wavelengths: int,
    design: MRDesign = MRDesign(),
    start_wavelength_nm: float = 1550.0,
    channel_spacing_nm: float = 1.0,
    n_levels: int = 128,
) -> bool:
    """A WDM bank is feasible iff (a) worst-channel SNR clears Eq. 12 and
    (b) the channel comb fits inside one FSR (no aliasing onto the next
    resonance order)."""
    if num_wavelengths < 1:
        return False
    lam = start_wavelength_nm + channel_spacing_nm * np.arange(num_wavelengths)
    span = channel_spacing_nm * num_wavelengths  # comb width incl. guard channel
    if span > fsr_nm(float(lam.mean()), design):
        return False
    noise = heterodyne_noise_fraction(lam, design.q_factor, design.filter_order)
    worst_required = max(
        required_snr_db(n_levels, float(l), design.q_factor) for l in lam
    )
    return snr_db(noise) >= worst_required


def max_noncoherent_wavelengths(
    design: MRDesign = MRDesign(),
    start_wavelength_nm: float = 1550.0,
    channel_spacing_nm: float = 1.0,
    n_levels: int = 128,
    hard_cap: int = 64,
) -> int:
    n = 0
    while n < hard_cap and noncoherent_bank_feasible(
        n + 1, design, start_wavelength_nm, channel_spacing_nm, n_levels
    ):
        n += 1
    return n


# ---------------------------------------------------------------------------
# TED — thermal eigenmode decomposition (Section 3.1, [32]).
# ---------------------------------------------------------------------------


def ted_drive_levels(coupling: np.ndarray, targets: np.ndarray) -> np.ndarray:
    """Solve heater drive levels so that achieved phase shifts == targets.

    ``coupling`` is the symmetric thermal-interference matrix K (K[i, j] =
    phase shift induced on MR i per unit drive on heater j; diagonally
    dominant).  TED diagonalizes K and drives in the eigenbasis; numerically
    this is exactly solving K d = t, which is what we do.  Raises if K is
    singular (physically: heaters too strongly coupled to be decomposed).
    """
    coupling = np.asarray(coupling, dtype=np.float64)
    targets = np.asarray(targets, dtype=np.float64)
    w, v = np.linalg.eigh(coupling)
    if np.min(np.abs(w)) < 1e-12:
        raise ValueError("thermal coupling matrix is singular; TED infeasible")
    return v @ ((v.T @ targets) / w)


def thermal_crosstalk_error(coupling: np.ndarray, targets: np.ndarray,
                            use_ted: bool) -> float:
    """Max |achieved - target| phase error with naive vs TED driving."""
    coupling = np.asarray(coupling, dtype=np.float64)
    targets = np.asarray(targets, dtype=np.float64)
    if use_ted:
        drives = ted_drive_levels(coupling, targets)
    else:
        drives = targets / np.diag(coupling)  # naive: ignore off-diagonal
    achieved = coupling @ drives
    return float(np.max(np.abs(achieved - targets)))
