"""Optoelectronic device & circuit constants (paper Table 1 + Section 4.1).

Every constant is taken from the paper (with its cited source) or, where the
paper relied on an external simulator we cannot run offline (CACTI,
DRAMsim3), from the nominal numbers the paper quotes for the same components,
with provenance noted inline.  The analytic performance model
(photonic/perf.py) consumes these.

Units: seconds, watts, joules, dB, meters unless suffixed otherwise.
"""

from __future__ import annotations

import dataclasses
import math

# ---------------------------------------------------------------------------
# Table 1 — device latencies and powers.
# ---------------------------------------------------------------------------

EO_TUNING_LATENCY = 20e-9        # 20 ns       [29]
EO_TUNING_POWER_PER_NM = 4e-6    # 4 uW/nm     [29]
TO_TUNING_LATENCY = 4e-6         # 4 us        [28]
TO_TUNING_POWER_PER_FSR = 27.5e-3  # 27.5 mW/FSR [28]
VCSEL_LATENCY = 0.07e-9          # 0.07 ns     [10]
VCSEL_POWER = 1.3e-3             # 1.3 mW      [10]
PD_LATENCY = 5.8e-12             # 5.8 ps      [10]
PD_POWER = 2.8e-3                # 2.8 mW      [10]
SOA_LATENCY = 0.3e-9             # 0.3 ns      [10]
SOA_POWER = 2.2e-3               # 2.2 mW      [10]
DAC_LATENCY = 0.29e-9            # 0.29 ns, 8 bit [46]
DAC_POWER = 3e-3                 # 3 mW        [46]
ADC_LATENCY = 0.82e-9            # 0.82 ns, 8 bit [47]
ADC_POWER = 3.1e-3               # 3.1 mW      [47]

# ---------------------------------------------------------------------------
# Section 4.1 — photonic losses (dB) and laser model.
# ---------------------------------------------------------------------------

WAVEGUIDE_PROP_LOSS_DB_PER_CM = 1.0   # 1 dB/cm
SPLITTER_LOSS_DB = 0.13               # [42]
COMBINER_LOSS_DB = 0.9                # [42]
MR_THROUGH_LOSS_DB = 0.02             # [44]
MR_MODULATION_LOSS_DB = 0.72          # [45]
EO_TUNING_LOSS_DB_PER_CM = 6.0        # [29]

PD_SENSITIVITY_DBM = -20.0            # typical Ge PD sensitivity (per [10]-class
                                      # links; the paper uses S_detector in Eq. 13
                                      # without quoting the number — -20 dBm is the
                                      # value its VCSEL/PD sources assume)
LASER_EFFICIENCY = 0.25               # wall-plug efficiency of VCSEL sources

MR_PITCH_UM = 20.0                    # MR center-to-center pitch along a waveguide
                                      # (10 um radius rings, Section 4.2, + routing)

# ---------------------------------------------------------------------------
# Digital side: buffers (CACTI @7 nm per [38]+[40]) and HBM2 ([41], DRAMsim3).
# ---------------------------------------------------------------------------

# CACTI 20nm values scaled to 7nm with [40]'s scaling relations; the paper
# does exactly this.  Energy-per-byte for the SRAM buffer sizes used by the
# ECU (128-256 KB, 64 B lines):
SRAM_READ_ENERGY_PER_BYTE = 0.24e-12   # J/B
SRAM_WRITE_ENERGY_PER_BYTE = 0.30e-12  # J/B
SRAM_LATENCY = 0.8e-9                  # s per access (pipelined)
SRAM_BANDWIDTH = 64e9                  # B/s (64 B line per ns-class cycle)
SRAM_LEAKAGE_POWER_PER_KB = 6e-6       # W/KB

# HBM2 (8 GB stack, 256 GB/s peak — paper Section 4.1; access energy ~3.9 pJ/bit
# is the standard HBM2 figure the DRAMsim3 config family uses).
HBM_BANDWIDTH = 256e9                  # B/s
HBM_ENERGY_PER_BYTE = 31.2e-12         # J/B  (3.9 pJ/bit)
HBM_LATENCY = 100e-9                   # s, first-word
HBM_REQUEST_ENERGY = 0.5e-9            # J per individual request (row activate
                                       # + command overhead for small bursts)

# ECU buffer sizes (Section 4.1).
ECU_BUFFERS_KB = {
    "input_vertices": 128,
    "output_vertices": 128,
    "edges": 256,
    "weights": 128,
}

# Digital softmax unit for GAT ([37]): LUT design, 294 MHz.
SOFTMAX_UNIT_FREQ = 294e6              # Hz -> one value per cycle
SOFTMAX_UNIT_POWER = 4.0e-3            # W (LUT + add/sub datapath of [37])

# ---------------------------------------------------------------------------
# Laser power model (paper Eq. 13 — the second "Eq. 13" in Section 4.1).
# ---------------------------------------------------------------------------


def laser_power_dbm(photonic_loss_db: float, num_wavelengths: int,
                    sensitivity_dbm: float = PD_SENSITIVITY_DBM) -> float:
    """P_laser(dBm) >= S_detector + P_photo_loss + 10 log10(N_lambda)."""
    if num_wavelengths < 1:
        raise ValueError("need at least one wavelength")
    return sensitivity_dbm + photonic_loss_db + 10.0 * math.log10(num_wavelengths)


def dbm_to_watts(dbm: float) -> float:
    return 1e-3 * 10.0 ** (dbm / 10.0)


def watts_to_dbm(w: float) -> float:
    return 10.0 * math.log10(max(w, 1e-30) / 1e-3)


@dataclasses.dataclass(frozen=True)
class LinkLoss:
    """Accumulate optical losses along a path (all dB)."""

    waveguide_cm: float = 0.0
    splitters: int = 0
    combiners: int = 0
    mrs_passed: int = 0       # through-port passes
    mrs_modulating: int = 1   # active modulation events

    @property
    def total_db(self) -> float:
        return (
            self.waveguide_cm * WAVEGUIDE_PROP_LOSS_DB_PER_CM
            + self.splitters * SPLITTER_LOSS_DB
            + self.combiners * COMBINER_LOSS_DB
            + self.mrs_passed * MR_THROUGH_LOSS_DB
            + self.mrs_modulating * MR_MODULATION_LOSS_DB
        )


def bank_waveguide_cm(num_mrs: int, pitch_um: float = MR_PITCH_UM) -> float:
    """Waveguide length (cm) through a bank of ``num_mrs`` MRs."""
    return num_mrs * pitch_um * 1e-4
