"""Architecture design-space exploration (paper Section 4.3, Fig. 7c).

Sweeps [N, V, R_r, R_c, T_r] under the device-level feasibility limits
(R_c + 1 <= 20 coherent MRs, R_r <= 18 WDM channels) and ranks configurations
by mean EPB/GOPS across a suite of (model, dataset) pairs — the paper's
objective.  The paper reports the optimum [20, 20, 18, 7, 17].
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Sequence

from repro.core.graph import Graph
from repro.photonic.mrbank import COHERENT_BANK_LIMIT, NONCOHERENT_WDM_LIMIT
from repro.photonic.perf import GhostConfig, GnnModelSpec, OrchFlags, simulate


@dataclasses.dataclass
class DseResult:
    config: GhostConfig
    mean_epb_per_gops: float
    mean_epb: float
    mean_gops: float


def default_grid() -> dict:
    """A grid bracketing the paper's optimum while honoring device limits."""
    return {
        "n": (8, 12, 16, 20, 24),
        "v": (8, 12, 16, 20, 24),
        "rr": (6, 10, 14, 18),                      # <= 18 WDM channels
        "rc": (3, 5, 7, 9, 11, 15, 19),             # +1 acc MR <= 20
        "tr": (5, 9, 13, 17, 20),
    }


def explore(
    workloads: Sequence[tuple[GnnModelSpec, Graph | Sequence[Graph], str]],
    grid: dict | None = None,
    flags: OrchFlags = OrchFlags(),
    top_k: int = 10,
) -> list[DseResult]:
    grid = grid or default_grid()
    results: list[DseResult] = []
    for n, v, rr, rc, tr in itertools.product(
        grid["n"], grid["v"], grid["rr"], grid["rc"], grid["tr"]
    ):
        if rc + 1 > COHERENT_BANK_LIMIT or rr > NONCOHERENT_WDM_LIMIT:
            continue
        cfg = GhostConfig(n=n, v=v, rr=rr, rc=rc, tr=tr)
        epbgops, epbs, gopss = [], [], []
        for model, graphs, ds in workloads:
            r = simulate(model, graphs, cfg, flags, dataset_name=ds)
            epbgops.append(r.epb_per_gops)
            epbs.append(r.epb)
            gopss.append(r.gops)
        results.append(DseResult(
            config=cfg,
            mean_epb_per_gops=sum(epbgops) / len(epbgops),
            mean_epb=sum(epbs) / len(epbs),
            mean_gops=sum(gopss) / len(gopss),
        ))
    results.sort(key=lambda r: r.mean_epb_per_gops)
    return results[:top_k]
