"""MR-bank design-space exploration (paper Section 4.2, Fig. 7a/b).

Produces the coherent and non-coherent feasibility surfaces the paper uses to
size GHOST's reduce units (coherent summation banks) and transform units
(non-coherent WDM multiply banks), and exports the selected design limits the
architecture DSE must respect:

  COHERENT_BANK_LIMIT      = 20 MRs   (at 1520 nm)
  NONCOHERENT_WDM_LIMIT    = 18 wavelengths (36 MRs across the two banks)
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.photonic.noise import (
    MRDesign,
    heterodyne_noise_fraction,
    homodyne_noise_fraction,
    max_coherent_mrs,
    max_noncoherent_wavelengths,
    fsr_nm,
    required_snr_db,
    snr_db,
)


@dataclasses.dataclass
class DsePoint:
    wavelength_nm: float
    num_elements: int  # MRs (coherent) or wavelengths (non-coherent)
    snr_db: float
    required_snr_db: float
    feasible: bool


def coherent_surface(
    wavelengths_nm: Sequence[float],
    mr_counts: Sequence[int],
    design: MRDesign = MRDesign(),
    n_levels: int = 128,
) -> list[DsePoint]:
    """Fig. 7a: SNR over (wavelength, #MRs) for coherent summation banks."""
    out = []
    for lam in wavelengths_nm:
        req = required_snr_db(n_levels, lam, design.q_factor)
        for n in mr_counts:
            s = snr_db(homodyne_noise_fraction(n, lam, design))
            out.append(DsePoint(lam, n, s, req, s >= req))
    return out


def noncoherent_surface(
    num_wavelengths: Sequence[int],
    design: MRDesign = MRDesign(),
    start_wavelength_nm: float = 1550.0,
    channel_spacing_nm: float = 1.0,
    n_levels: int = 128,
) -> list[DsePoint]:
    """Fig. 7b: SNR over #wavelengths for WDM multiply banks (x-axis in the
    paper is #rings = 2 x #wavelengths)."""
    out = []
    for n in num_wavelengths:
        lam = start_wavelength_nm + channel_spacing_nm * np.arange(n)
        mid = float(lam.mean())
        s = snr_db(heterodyne_noise_fraction(lam, design.q_factor, design.filter_order))
        req = max(required_snr_db(n_levels, float(l), design.q_factor) for l in lam)
        fits = channel_spacing_nm * n <= fsr_nm(mid, design)
        out.append(DsePoint(mid, n, s, req, (s >= req) and fits))
    return out


def selected_design(design: MRDesign = MRDesign(), n_levels: int = 128):
    """The design limits GHOST adopts (Section 4.2 conclusions)."""
    lam_sweep = np.arange(1500.0, 1581.0, 5.0)
    best_lam, best_n = max(
        ((lam, max_coherent_mrs(lam, design, n_levels)) for lam in lam_sweep),
        key=lambda t: t[1],
    )
    return {
        "coherent_wavelength_nm": float(best_lam),
        "coherent_bank_limit": int(best_n),
        "noncoherent_wdm_limit": int(max_noncoherent_wavelengths(design, n_levels=n_levels)),
        "q_factor": design.q_factor,
        "required_snr_db": required_snr_db(n_levels, best_lam, design.q_factor),
    }


# The limits adopted throughout the architecture (match paper Section 4.2).
COHERENT_BANK_LIMIT = 20
NONCOHERENT_WDM_LIMIT = 18
