"""8-bit sign-split quantization (paper Sections 3.2, 4.1; Table 3).

GHOST represents positive and negative parameter values *separately* (the
balanced photodetector subtracts the two polarities), so each polarity uses
N_levels = 2^(n-1) = 2^7 amplitude levels for n = 8-bit parameters — this is
the N_levels that enters the SNR constraint (Eq. 12/13) and the MR-bank DSE.

On TPU the same scheme is symmetric int8 quantization with an int32
accumulator: q in [-127, 127], sign-split into pos = max(q, 0) and
neg = max(-q, 0) (each 7-bit), with (pos - neg) recovering q exactly — the
BPD subtraction.  ``quantized_matmul`` is the serving fast path used by the
combine block; the Pallas kernel in ``repro.kernels.quant_matmul`` computes
the identical contraction with explicit MXU tiling, and this module is its
oracle.

A straight-through-estimator fake-quant is provided for quantization-aware
evaluation/training experiments (Table 3 reproduces post-training quant).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

INT8_LEVELS = 127  # per-polarity 2^7 - 1 amplitude levels


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    bits: int = 8
    per_channel_weights: bool = True   # one scale per output channel
    per_tensor_activations: bool = True
    stochastic: bool = False           # stochastic rounding (training experiments)

    @property
    def qmax(self) -> int:
        return 2 ** (self.bits - 1) - 1  # 127 for 8-bit

    @property
    def n_levels(self) -> int:
        """Per-polarity amplitude levels — the paper's N_levels = 2^(n-1)."""
        return 2 ** (self.bits - 1)


def compute_scale(x: jax.Array, axis=None, qmax: int = INT8_LEVELS) -> jax.Array:
    """Symmetric scale: s = max|x| / qmax (never zero)."""
    amax = jnp.max(jnp.abs(x), axis=axis, keepdims=axis is not None)
    return jnp.maximum(amax, 1e-12) / qmax


def quantize(x: jax.Array, scale: jax.Array, qmax: int = INT8_LEVELS,
             key: jax.Array | None = None) -> jax.Array:
    """Quantize to signed integers in [-qmax, qmax] (round-to-nearest-even,
    or stochastic rounding when a PRNG key is supplied)."""
    y = x / scale
    if key is not None:
        floor = jnp.floor(y)
        p = y - floor
        y = floor + (jax.random.uniform(key, y.shape) < p)
    else:
        y = jnp.round(y)
    return jnp.clip(y, -qmax, qmax).astype(jnp.int8)


def sign_split(q: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Split signed int8 into the two photonic polarities (each in [0, 127])."""
    pos = jnp.maximum(q, 0).astype(jnp.int8)
    neg = jnp.maximum(-q.astype(jnp.int16), 0).astype(jnp.int8)
    return pos, neg


def sign_merge(pos: jax.Array, neg: jax.Array) -> jax.Array:
    """Balanced-photodetector recombination: q = pos - neg."""
    return (pos.astype(jnp.int16) - neg.astype(jnp.int16)).astype(jnp.int8)


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(scale.dtype) * scale


def fake_quant(x: jax.Array, cfg: QuantConfig = QuantConfig(), axis=None) -> jax.Array:
    """Quantize-dequantize (post-training quantization emulation)."""
    s = compute_scale(x, axis=axis, qmax=cfg.qmax)
    return dequantize(quantize(x, s, cfg.qmax), s)


@jax.custom_vjp
def fake_quant_ste(x: jax.Array) -> jax.Array:
    s = compute_scale(x, qmax=INT8_LEVELS)
    return dequantize(quantize(x, s, INT8_LEVELS), s)


def _fq_fwd(x):
    return fake_quant_ste(x), None


def _fq_bwd(_, g):
    return (g,)  # straight-through


fake_quant_ste.defvjp(_fq_fwd, _fq_bwd)


def quantize_weights(w: jax.Array, cfg: QuantConfig = QuantConfig()):
    """Quantize a weight matrix [F_in, F_out] -> (q_int8, scale [1, F_out])."""
    axis = 0 if cfg.per_channel_weights else None
    s = compute_scale(w, axis=axis, qmax=cfg.qmax)
    q = quantize(w, s, cfg.qmax)
    return q, jnp.asarray(s, w.dtype)


def quantized_matmul(
    x: jax.Array, w: jax.Array, cfg: QuantConfig = QuantConfig()
) -> jax.Array:
    """Photonic combine-block MVM: int8 x int8 -> int32 -> dequantized float.

    Both operands are quantized on the fly (activations per-tensor, weights
    per output channel), multiplied in the integer domain exactly as the MR
    banks multiply amplitude levels, accumulated in int32 (the photodetector
    current sum), and rescaled — functionally identical to the sign-split
    pos/neg decomposition since (p_x - n_x)(p_w - n_w) = q_x q_w.
    """
    sx = compute_scale(x, axis=None, qmax=cfg.qmax)
    qx = quantize(x, sx, cfg.qmax)
    qw, sw = quantize_weights(w, cfg)
    acc = jax.lax.dot_general(
        qx, qw,
        (((qx.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    return acc.astype(w.dtype) * sx * sw
