from repro.photonic.quant import (
    QuantConfig,
    compute_scale,
    dequantize,
    fake_quant,
    fake_quant_ste,
    quantize,
    quantize_weights,
    quantized_matmul,
    sign_merge,
    sign_split,
)
from repro.photonic.noise import MRDesign
from repro.photonic.mrbank import COHERENT_BANK_LIMIT, NONCOHERENT_WDM_LIMIT
from repro.photonic.perf import (
    GhostConfig,
    GnnModelSpec,
    LayerSpec,
    OrchFlags,
    PerfReport,
    simulate,
)
