"""Update block — non-linear activation units (paper Section 3.3.3).

SOA-implementable activations (relu / sigmoid / tanh / leaky_relu) run in the
optical domain in GHOST; softmax falls back to the digital LUT unit of [37]
(294 MHz).  Functionally these are the exact nonlinearities; the *cost*
difference (optical vs digital) lives in the analytic perf model.

``soa_transfer`` models the SOA gain curve used by the noise-faithful
inference mode: a saturating amplifier whose gain ~1 regime approximates ReLU
(per [36]); it lets tests quantify the activation-approximation error the
paper implicitly accepts.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# Activations GHOST computes optically (SOA-based, [36]).
OPTICAL_ACTIVATIONS = ("relu", "leaky_relu", "sigmoid", "tanh", "identity")
# Activations GHOST computes in the digital LUT unit ([37]).
DIGITAL_ACTIVATIONS = ("softmax", "elu", "gelu")


def get_activation(name: str):
    table = {
        "relu": jax.nn.relu,
        "leaky_relu": lambda x: jax.nn.leaky_relu(x, 0.2),
        "sigmoid": jax.nn.sigmoid,
        "tanh": jnp.tanh,
        "identity": lambda x: x,
        "elu": jax.nn.elu,
        "gelu": jax.nn.gelu,
        "softmax": lambda x: jax.nn.softmax(x, axis=-1),
    }
    if name not in table:
        raise ValueError(f"unknown activation '{name}'")
    return table[name]


def is_optical(name: str) -> bool:
    return name in OPTICAL_ACTIVATIONS


def soa_transfer(x: jax.Array, gain: float = 1.0, p_sat: float = 4.0) -> jax.Array:
    """Saturating SOA transfer curve: g(x) = gain * x / (1 + |x| / p_sat), x>=0.

    Negative optical powers don't exist; the balanced-photodetector front-end
    clips at zero, so the composite behaves like a soft ReLU whose linear
    regime (|x| << p_sat, gain ~ 1) matches ReLU (per [36]).
    """
    pos = jnp.maximum(x, 0.0)
    return gain * pos / (1.0 + pos / p_sat)
