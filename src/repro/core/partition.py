"""GHOST graph buffering & partitioning (paper Section 3.4.1).

The paper's key dataflow optimization: the adjacency matrix is blocked into
V x N tiles — V output (destination) vertices per execution-lane group and N
input (source) vertices per edge-control-unit group.  Tiles that contain no
edge ("all-zero blocks") are *skipped entirely*: they are never fetched and
never scheduled.  The partition matrix and fetch order are generated once,
offline.

On TPU this becomes a block-CSR sparse format.  The JAX-visible arrays are
padded/static so the downstream compute (jnp reference in
``repro.core.aggregate`` and the Pallas kernel in
``repro.kernels.block_spmm``) is shape-stable:

  blocks      [B, V, N]   dense tile values (edge weights; 0 = no edge)
  block_row   [B]         destination-group index of each tile
  block_col   [B]         source-group index of each tile
  row_ptr     [G_dst+1]   CSR row pointers over tiles (tiles sorted by row)

where B is the number of *non-zero* tiles only.  ``PartitionStats`` carries
the occupancy numbers the analytic performance model (photonic/perf.py)
consumes — they determine aggregate-phase latency and skipped-fetch savings.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.common.utils import cdiv
from repro.core.graph import Graph


@dataclasses.dataclass
class PartitionStats:
    """Occupancy statistics consumed by the analytic perf model."""

    num_nodes: int
    num_edges: int
    v: int  # output-group size (execution lanes)
    n: int  # input-group size (edge-control units)
    num_dst_groups: int
    num_src_groups: int
    total_tiles: int  # num_dst_groups * num_src_groups
    nonzero_tiles: int
    skipped_fraction: float  # fraction of tiles skipped (all-zero)
    max_tiles_per_row: int
    mean_tiles_per_row: float
    max_neighbors: int  # max in-degree (drives lane latency)
    mean_neighbors: float
    tile_density: float  # mean nnz fraction inside non-zero tiles


@dataclasses.dataclass
class PartitionedGraph:
    """Block-CSR adjacency + per-node data, ready for blocked aggregation.

    All arrays are numpy; convert to jnp at the call site.  Node features are
    padded to a multiple of the group sizes so tile loads are static-shape.
    """

    blocks: np.ndarray      # [B, V, N] float32 tile values
    block_row: np.ndarray   # [B] int32
    block_col: np.ndarray   # [B] int32
    row_ptr: np.ndarray     # [G_dst + 1] int32
    v: int
    n: int
    num_nodes: int          # true (unpadded) node count
    num_dst_groups: int
    num_src_groups: int
    stats: PartitionStats

    @property
    def padded_dst(self) -> int:
        return self.num_dst_groups * self.v

    @property
    def padded_src(self) -> int:
        return self.num_src_groups * self.n

    def pad_features(self, feat: np.ndarray) -> np.ndarray:
        """Pad [Nv, F] node features to [padded_src, F] for source-side loads."""
        pad = self.padded_src - feat.shape[0]
        if pad < 0:
            raise ValueError("feature matrix larger than padded node count")
        if pad == 0:
            return feat
        return np.concatenate([feat, np.zeros((pad, feat.shape[1]), feat.dtype)], axis=0)

    def reconstruct_dense(self) -> np.ndarray:
        """Rebuild the [padded_dst, padded_src] dense adjacency (for tests)."""
        a = np.zeros((self.padded_dst, self.padded_src), dtype=np.float32)
        for b in range(self.blocks.shape[0]):
            r, c = int(self.block_row[b]), int(self.block_col[b])
            a[r * self.v:(r + 1) * self.v, c * self.n:(c + 1) * self.n] = self.blocks[b]
        return a


def partition_graph(
    graph: Graph,
    v: int,
    n: int,
    edge_weights: Optional[np.ndarray] = None,
    sort_rows: bool = True,
) -> PartitionedGraph:
    """Build the GHOST V x N partition matrix for ``graph``.

    Args:
      graph: input graph (A[dst, src] convention).
      v: output-vertex group size (number of execution lanes, paper's V).
      n: input-vertex group size (number of edge-control units, paper's N).
      edge_weights: optional [E] per-edge values (e.g. GCN normalization);
        defaults to 1.0 (plain adjacency).
      sort_rows: keep tiles in CSR row order (the paper's offline fetch-order
        generation).

    Returns:
      PartitionedGraph with only the non-zero tiles materialized.
    """
    if v <= 0 or n <= 0:
        raise ValueError(f"group sizes must be positive, got v={v} n={n}")
    nv = graph.num_nodes
    g_dst = max(1, cdiv(nv, v))
    g_src = max(1, cdiv(nv, n))

    w = edge_weights if edge_weights is not None else np.ones(graph.num_edges, np.float32)
    if w.shape[0] != graph.num_edges:
        raise ValueError("edge_weights length mismatch")

    # Tile id of each edge.
    tr = graph.edge_dst // v
    tc = graph.edge_src // n
    tile_id = tr.astype(np.int64) * g_src + tc.astype(np.int64)

    # Unique non-zero tiles, in (row, col) order — this IS the offline fetch order.
    uniq, inverse = np.unique(tile_id, return_inverse=True)
    num_blocks = len(uniq)
    block_row = (uniq // g_src).astype(np.int32)
    block_col = (uniq % g_src).astype(np.int32)

    blocks = np.zeros((max(num_blocks, 1), v, n), dtype=np.float32)
    if graph.num_edges:
        lr = (graph.edge_dst % v).astype(np.int64)
        lc = (graph.edge_src % n).astype(np.int64)
        # Accumulate (duplicate edges sum, matching segment-sum semantics).
        np.add.at(blocks, (inverse, lr, lc), w.astype(np.float32))

    # CSR row pointers over tiles (uniq is already row-major sorted).
    row_ptr = np.zeros(g_dst + 1, dtype=np.int32)
    np.add.at(row_ptr, block_row + 1, 1)
    row_ptr = np.cumsum(row_ptr).astype(np.int32)

    tiles_per_row = np.diff(row_ptr)
    deg = graph.in_degrees()
    nnz_inside = (
        float((blocks != 0).sum()) / (num_blocks * v * n) if num_blocks else 0.0
    )
    stats = PartitionStats(
        num_nodes=nv,
        num_edges=graph.num_edges,
        v=v,
        n=n,
        num_dst_groups=g_dst,
        num_src_groups=g_src,
        total_tiles=g_dst * g_src,
        nonzero_tiles=num_blocks,
        skipped_fraction=1.0 - (num_blocks / (g_dst * g_src)),
        max_tiles_per_row=int(tiles_per_row.max()) if len(tiles_per_row) else 0,
        mean_tiles_per_row=float(tiles_per_row.mean()) if len(tiles_per_row) else 0.0,
        max_neighbors=int(deg.max()) if nv else 0,
        mean_neighbors=float(deg.mean()) if nv else 0.0,
        tile_density=nnz_inside,
    )
    if num_blocks == 0:
        # Zero-edge graph: ``blocks`` already holds one all-zero placeholder
        # tile; give it matching (row, col) coordinates so the array triple
        # stays shape-consistent for the blocked backends (row_ptr and the
        # occupancy stats still report zero non-zero tiles).
        block_row = np.zeros(1, dtype=np.int32)
        block_col = np.zeros(1, dtype=np.int32)

    if not sort_rows:
        # Degree-descending schedule (workload-balancing experiments).
        order = np.argsort(-tiles_per_row[block_row], kind="stable")
        blocks, block_row, block_col = blocks[order], block_row[order], block_col[order]

    return PartitionedGraph(
        blocks=blocks,
        block_row=block_row,
        block_col=block_col,
        row_ptr=row_ptr,
        v=v,
        n=n,
        num_nodes=nv,
        num_dst_groups=g_dst,
        num_src_groups=g_src,
        stats=stats,
    )


def partition_cost_table(graph: Graph, v_values, n_values) -> list[PartitionStats]:
    """Sweep (V, N) and return occupancy stats for the architecture DSE."""
    out = []
    for v in v_values:
        for n in n_values:
            out.append(partition_graph(graph, v, n).stats)
    return out
