"""Aggregate phase — the paper's reduce-unit dataflow, in JAX.

Two numerically-equivalent backends:

* ``aggregate_edges``   — edge-list reference (segment ops).  Used for
  training and as the oracle in tests.
* ``aggregate_blocked`` — the GHOST V x N blocked dataflow (Section 3.3.1 +
  3.4.1): only non-zero adjacency tiles are touched; each tile contributes a
  dense (V x N) @ (N x F) product — exactly what the coherent-summation MR
  array computes per mapping, and exactly what the MXU wants.  The Pallas
  kernel in ``repro.kernels.block_spmm`` implements the same contraction with
  explicit VMEM tiling; this jnp version is its oracle and the CPU fallback.

Reduce ops: SUM / MEAN / MAX, matching the paper's reduce-unit modes (plain
coherent summation, the trailing 1/n MR, and the optical comparator).
"""

from __future__ import annotations

import contextlib
import enum
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.partition import PartitionedGraph


class ReduceOp(str, enum.Enum):
    SUM = "sum"
    MEAN = "mean"
    MAX = "max"


# ---------------------------------------------------------------------------
# Backend selection: "jnp" (einsum/segment ops, the oracle) or "pallas" (the
# block_spmm kernel in repro.kernels; interpret mode on CPU).  The serving
# engine flips this per-executor; layers and models stay backend-agnostic.
# ---------------------------------------------------------------------------

_BACKEND_STACK: list[str] = ["jnp"]
AGGREGATE_BACKENDS = ("jnp", "pallas")


def active_aggregate_backend() -> str:
    return _BACKEND_STACK[-1]


@contextlib.contextmanager
def aggregate_backend(name: str):
    """Route ``aggregate_blocked`` SUM/MEAN through the chosen backend.

    The selection is read at trace time, so wrapping a jit'd call site routes
    every blocked aggregation inside that trace.  MAX always uses the jnp
    path (the Pallas kernel is an SpMM; the optical comparator has no MXU
    analogue).
    """
    if name not in AGGREGATE_BACKENDS:
        raise ValueError(f"unknown aggregate backend '{name}'; "
                         f"expected one of {AGGREGATE_BACKENDS}")
    _BACKEND_STACK.append(name)
    try:
        yield
    finally:
        _BACKEND_STACK.pop()


class BlockedGraph(NamedTuple):
    """Device-resident view of a PartitionedGraph (static shapes)."""

    blocks: jax.Array      # [B, V, N]
    block_row: jax.Array   # [B]
    block_col: jax.Array   # [B]
    num_dst_groups: int
    num_src_groups: int
    v: int
    n: int
    num_nodes: int


def to_blocked(pg: PartitionedGraph) -> BlockedGraph:
    return BlockedGraph(
        blocks=jnp.asarray(pg.blocks),
        block_row=jnp.asarray(pg.block_row),
        block_col=jnp.asarray(pg.block_col),
        num_dst_groups=pg.num_dst_groups,
        num_src_groups=pg.num_src_groups,
        v=pg.v,
        n=pg.n,
        num_nodes=pg.num_nodes,
    )


# ---------------------------------------------------------------------------
# Edge-list reference backend.
# ---------------------------------------------------------------------------

def aggregate_edges(
    edge_src: jax.Array,
    edge_dst: jax.Array,
    feat: jax.Array,
    num_nodes: int,
    reduce: ReduceOp = ReduceOp.SUM,
    edge_weights: jax.Array | None = None,
) -> jax.Array:
    """Edge-list aggregation: out[v] = reduce_{(u,v) in E} w_uv * feat[u]."""
    msgs = feat[edge_src]
    if edge_weights is not None:
        msgs = msgs * edge_weights[:, None]
    if reduce in (ReduceOp.SUM, ReduceOp.MEAN):
        out = jax.ops.segment_sum(msgs, edge_dst, num_segments=num_nodes)
        if reduce == ReduceOp.MEAN:
            deg = jax.ops.segment_sum(
                jnp.ones_like(edge_dst, feat.dtype), edge_dst, num_segments=num_nodes
            )
            out = out / jnp.maximum(deg, 1.0)[:, None]
        return out
    if reduce == ReduceOp.MAX:
        out = jax.ops.segment_max(msgs, edge_dst, num_segments=num_nodes)
        # Isolated vertices get -inf from segment_max; zero them like the
        # hardware comparator (no inputs -> no output).
        return jnp.where(jnp.isfinite(out), out, 0.0)
    raise ValueError(f"unknown reduce {reduce}")


# ---------------------------------------------------------------------------
# Blocked (GHOST) backend.
# ---------------------------------------------------------------------------

def aggregate_blocked(
    bg: BlockedGraph,
    feat_padded: jax.Array,
    reduce: ReduceOp = ReduceOp.SUM,
) -> jax.Array:
    """Blocked aggregation over non-zero tiles only.

    Args:
      bg: blocked adjacency (non-zero tiles).
      feat_padded: [G_src * N, F] source features, padded (see
        PartitionedGraph.pad_features).
      reduce: SUM / MEAN / MAX.

    Returns:
      [G_dst * V, F] aggregated features (padded rows included).
    """
    f = feat_padded.shape[-1]

    def mean_normalize(out):
        # Degree = sum of tile entries: multiplicities of duplicate edges
        # were accumulated into the tile values at partition time, so this
        # matches the edge-list backend's per-edge count exactly.  Shared by
        # both backends — their MEAN semantics must never drift apart.
        deg_partial = bg.blocks.sum(axis=2).astype(out.dtype)  # [B,V]
        deg = jax.ops.segment_sum(deg_partial, bg.block_row,
                                  num_segments=bg.num_dst_groups)
        deg = deg.reshape(bg.num_dst_groups * bg.v)
        return out / jnp.maximum(deg, 1.0)[:, None]

    if active_aggregate_backend() == "pallas" and reduce in (ReduceOp.SUM,
                                                             ReduceOp.MEAN):
        # Lazy import: kernels.ops imports core.partition; importing it at
        # module scope would cycle through core/__init__.
        from repro.kernels.ops import block_spmm_padded

        out = block_spmm_padded(bg.blocks, bg.block_row, bg.block_col,
                                feat_padded, bg.num_dst_groups)
        if reduce == ReduceOp.MEAN:
            out = mean_normalize(out)
        return out.astype(feat_padded.dtype)

    src_tiles = feat_padded.reshape(bg.num_src_groups, bg.n, f)[bg.block_col]  # [B,N,F]

    if reduce in (ReduceOp.SUM, ReduceOp.MEAN):
        partial = jnp.einsum(
            "bvn,bnf->bvf", bg.blocks, src_tiles.astype(bg.blocks.dtype)
        )
        out = jax.ops.segment_sum(partial, bg.block_row, num_segments=bg.num_dst_groups)
        out = out.reshape(bg.num_dst_groups * bg.v, f)
        if reduce == ReduceOp.MEAN:
            out = mean_normalize(out)
        return out.astype(feat_padded.dtype)

    if reduce == ReduceOp.MAX:
        mask = (bg.blocks != 0)[..., None]                          # [B,V,N,1]
        neg = jnp.asarray(-jnp.inf, feat_padded.dtype)
        cand = jnp.where(mask, src_tiles[:, None, :, :], neg)       # [B,V,N,F]
        partial = cand.max(axis=2)                                  # [B,V,F]
        out = jax.ops.segment_max(partial, bg.block_row, num_segments=bg.num_dst_groups)
        out = out.reshape(bg.num_dst_groups * bg.v, f)
        return jnp.where(jnp.isfinite(out), out, 0.0)

    raise ValueError(f"unknown reduce {reduce}")


def attention_aggregate_blocked(
    bg: BlockedGraph,
    values_padded: jax.Array,   # [G_src*N, H, F]  (already W-transformed, per head)
    src_scores: jax.Array,      # [G_src*N, H]     a_src . (W h_u)
    dst_scores: jax.Array,      # [G_dst*V, H]     a_dst . (W h_v)
    negative_slope: float = 0.2,
) -> jax.Array:
    """GAT-style masked-softmax aggregation on the blocked adjacency.

    Computes, per head h:  out[v] = sum_u softmax_u(leaky_relu(e_uv)) val[u]
    with e_uv = dst_scores[v] + src_scores[u], masked to edges, using a
    numerically-stable two-pass (segment-max then segment-sum) over tiles —
    the blocked analogue of GHOST's GAT pipeline (Section 3.4.2, Fig. 6b).

    Returns [G_dst*V, H, F].
    """
    heads = values_padded.shape[1]
    f = values_padded.shape[2]
    mask = bg.blocks != 0                                              # [B,V,N]

    s_src = src_scores.reshape(bg.num_src_groups, bg.n, heads)[bg.block_col]   # [B,N,H]
    s_dst = dst_scores.reshape(bg.num_dst_groups, bg.v, heads)[bg.block_row]   # [B,V,H]
    logits = s_dst[:, :, None, :] + s_src[:, None, :, :]               # [B,V,N,H]
    logits = jax.nn.leaky_relu(logits, negative_slope)
    neg = jnp.asarray(-1e30, logits.dtype)
    logits = jnp.where(mask[..., None], logits, neg)

    # Pass 1: per-destination-row running max across tiles.
    tile_max = logits.max(axis=2)                                      # [B,V,H]
    row_max = jax.ops.segment_max(tile_max, bg.block_row, num_segments=bg.num_dst_groups)
    row_max = jnp.maximum(row_max, -1e30)                              # isolated rows
    m = row_max[bg.block_row][:, :, None, :]                           # [B,V,1,H]

    # Pass 2: exp-sum and weighted value sum.  Tile values carry edge
    # multiplicity (duplicate edges accumulate at partition time), so p is
    # scaled by them — matching the edge-list softmax on multigraphs.
    mult = bg.blocks[..., None]                                        # [B,V,N,1]
    p = jnp.where(mask[..., None], mult * jnp.exp(logits - m), 0.0)    # [B,V,N,H]
    denom_partial = p.sum(axis=2)                                      # [B,V,H]
    denom = jax.ops.segment_sum(denom_partial, bg.block_row, num_segments=bg.num_dst_groups)

    vals = values_padded.reshape(bg.num_src_groups, bg.n, heads, f)[bg.block_col]  # [B,N,H,F]
    num_partial = jnp.einsum("bvnh,bnhf->bvhf", p, vals)               # [B,V,H,F]
    num = jax.ops.segment_sum(num_partial, bg.block_row, num_segments=bg.num_dst_groups)

    out = num / jnp.maximum(denom, 1e-30)[..., None]
    return out.reshape(bg.num_dst_groups * bg.v, heads, f)
