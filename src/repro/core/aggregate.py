"""Aggregate phase — the paper's reduce-unit dataflow, in JAX.

Two numerically-equivalent backends:

* ``aggregate_edges``   — edge-list reference (segment ops).  Used for
  training and as the oracle in tests.
* ``aggregate_blocked`` — the GHOST V x N blocked dataflow (Section 3.3.1 +
  3.4.1): only non-zero adjacency tiles are touched; each tile contributes a
  dense (V x N) @ (N x F) product — exactly what the coherent-summation MR
  array computes per mapping, and exactly what the MXU wants.  The Pallas
  kernel in ``repro.kernels.block_spmm`` implements the same contraction with
  explicit VMEM tiling; this jnp version is its oracle and the CPU fallback.

On top of the plain aggregation, ``aggregate_combine_blocked`` runs a whole
aggregate+combine stage pair (the GReTA reduce->transform step) through a
static **order planner**: it picks aggregate-first vs combine-first from the
tile FLOP counts (combine-first shrinks the SpMM width whenever
``F_out < F_in`` — GHOST's own transform-first GAT ordering, applied
cost-wise to every layer), and on the ``pallas_fused`` backend it lowers the
aggregate-first order to the fused SpMM+combine epilogue kernel in
``repro.kernels.fused_block_spmm`` so the aggregated intermediate never
round-trips through HBM.

Reduce ops: SUM / MEAN / MAX, matching the paper's reduce-unit modes (plain
coherent summation, the trailing 1/n MR, and the optical comparator).
MEAN degrees are graph-static: ``to_blocked`` precomputes them once per
graph (``BlockedGraph.deg``) so no forward pass re-reduces the tiles.
"""

from __future__ import annotations

import contextlib
import enum
import threading
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.partition import PartitionedGraph


class ReduceOp(str, enum.Enum):
    SUM = "sum"
    MEAN = "mean"
    MAX = "max"


# ---------------------------------------------------------------------------
# Backend selection: "jnp" (einsum/segment ops, the oracle), "pallas" (the
# unfused block_spmm kernel; interpret mode on CPU), or "pallas_fused"
# (block_spmm for plain aggregations + the fused aggregate+combine kernel
# inside aggregate_combine_blocked).  The serving engine flips this
# per-executor; layers and models stay backend-agnostic.  The stack is
# thread-local so a threaded intake path can never race one executor's
# selection against another's.
# ---------------------------------------------------------------------------

_BACKEND_TLS = threading.local()
AGGREGATE_BACKENDS = ("jnp", "pallas", "pallas_fused")
_PALLAS_BACKENDS = ("pallas", "pallas_fused")


def _backend_stack() -> list:
    stack = getattr(_BACKEND_TLS, "stack", None)
    if stack is None:
        stack = _BACKEND_TLS.stack = ["jnp"]
    return stack


def active_aggregate_backend() -> str:
    return _backend_stack()[-1]


@contextlib.contextmanager
def aggregate_backend(name: str):
    """Route blocked SUM/MEAN aggregation through the chosen backend.

    The selection is read at trace time, so wrapping a jit'd call site routes
    every blocked aggregation inside that trace.  MAX always uses the jnp
    path (the Pallas kernel is an SpMM; the optical comparator has no MXU
    analogue).  Selections are per-thread: pushing a backend in one thread
    is invisible to every other thread.
    """
    if name not in AGGREGATE_BACKENDS:
        raise ValueError(f"unknown aggregate backend '{name}'; "
                         f"expected one of {AGGREGATE_BACKENDS}")
    stack = _backend_stack()
    stack.append(name)
    try:
        yield
    finally:
        stack.pop()


# ---------------------------------------------------------------------------
# Kernel-config resolution: an optional thread-local hook that lets a tuner
# (repro.kernels.autotune) or an explicit test override steer *how* each
# aggregate+combine site lowers — fused vs unfused, execution order, and the
# kernel tile widths — without the layers knowing anything about it.  Like
# the backend selection above, the resolver is consulted at trace time, so
# wrapping a jit'd call site bakes the chosen configs into that trace.
# ---------------------------------------------------------------------------


class KernelSite(NamedTuple):
    """Static (trace-time) description of one aggregate+combine call site.

    Everything here is a Python constant at trace time — tile geometry,
    feature widths, reduce mode, dtype, quantization, and the active
    backend — i.e. exactly the inputs a shape-class autotuner keys on.
    """

    num_blocks: int
    num_dst_groups: int
    num_src_groups: int
    v: int
    n: int
    f_in: int
    f_out: int
    reduce: str
    dtype: str
    quantized: bool
    backend: str


_RESOLVER_TLS = threading.local()


def _resolver_stack() -> list:
    stack = getattr(_RESOLVER_TLS, "stack", None)
    if stack is None:
        stack = _RESOLVER_TLS.stack = [None]
    return stack


def active_kernel_resolver():
    return _resolver_stack()[-1]


@contextlib.contextmanager
def kernel_config_scope(resolver):
    """Install a kernel-config resolver for aggregate_combine_blocked.

    ``resolver(site: KernelSite)`` returns a config object or None (None =
    keep the defaults).  The config is duck-typed; the attributes read are

      * ``fused``   — Optional[bool]: force the fused epilogue kernel on or
        off (honored only on the ``pallas_fused`` backend, where fusion is
        the default; ``pallas``/``jnp`` keep their meaning).
      * ``order``   — Optional[str]: combination order, consulted only when
        the call site asked for ``"auto"`` (explicit order and the
        nonlinear-stage pinning always win).
      * ``block_f`` — Optional[int]: feature tile width of the unfused
        SpMM kernel.
      * ``lane``    — Optional[int]: lane padding of the fused kernel.
      * ``shard``   — Optional[str]: consulted only under an active
        ``shard_scope``; ``"none"`` pins the site to the single-device
        lowering, anything else keeps the scope's strategy.

    Scopes nest and are per-thread, mirroring ``aggregate_backend``.
    """
    stack = _resolver_stack()
    stack.append(resolver)
    try:
        yield
    finally:
        stack.pop()


# ---------------------------------------------------------------------------
# Shard scope: an optional thread-local (mesh, axis) selection that routes
# aggregate_combine_blocked through the multi-device feature-dim partition
# (see the "Sharded execution" section below).  Like the backend stack and
# the kernel-config resolver, the scope is consulted at trace time and is
# per-thread, so one executor's mesh never leaks into another thread's
# traces.
# ---------------------------------------------------------------------------


class ShardContext(NamedTuple):
    """Active mesh selection for sharded aggregate+combine lowering."""

    mesh: object        # jax.sharding.Mesh
    axis: str           # 1-D partition axis name (conventionally "data")

    @property
    def num_shards(self) -> int:
        return int(self.mesh.shape[self.axis])


_SHARD_TLS = threading.local()


def _shard_stack() -> list:
    stack = getattr(_SHARD_TLS, "stack", None)
    if stack is None:
        stack = _SHARD_TLS.stack = [None]
    return stack


def active_shard_context() -> Optional[ShardContext]:
    return _shard_stack()[-1]


@contextlib.contextmanager
def shard_scope(mesh, axis: str = "data"):
    """Route blocked aggregate+combine stages across a device mesh.

    Inside the scope, ``aggregate_combine_blocked`` lowers SUM/MEAN/MAX
    non-quantized stages through the feature-dim partition
    (``aggregate_combine_sharded``'s "feature" strategy): each device owns
    an F_in slice of the SpMM and the matching combine-weight rows, and one
    ``psum`` over the contracted dimension rebuilds the output.  This is
    the strategy that needs no host-side graph resharding, so it drops into
    existing jit traces (including vmapped serving executors) untouched.

    ``mesh=None`` suppresses any enclosing scope — the sharded kernels use
    it so their per-device bodies never recurse into the router.  Scopes
    nest and are per-thread, mirroring ``aggregate_backend``.
    """
    ctx = None
    if mesh is not None:
        if axis not in mesh.axis_names:
            raise ValueError(f"mesh has no axis '{axis}'; "
                             f"axes are {tuple(mesh.axis_names)}")
        ctx = ShardContext(mesh=mesh, axis=axis)
    stack = _shard_stack()
    stack.append(ctx)
    try:
        yield
    finally:
        stack.pop()


class BlockedGraph(NamedTuple):
    """Device-resident view of a PartitionedGraph (static shapes).

    ``deg`` holds the per-destination-row MEAN-reduce degrees.  Degree is
    graph-static, so it is computed once (``to_blocked`` for host graphs;
    ``with_degrees`` inside a serving trace) and reused by every layer and
    backend instead of being re-reduced from the tiles on each forward.
    """

    blocks: jax.Array      # [B, V, N]
    block_row: jax.Array   # [B]
    block_col: jax.Array   # [B]
    num_dst_groups: int
    num_src_groups: int
    v: int
    n: int
    num_nodes: int
    deg: Optional[jax.Array] = None  # [G_dst * V] or None (derive on demand)


def to_blocked(pg: PartitionedGraph) -> BlockedGraph:
    # Degree = sum of tile entries: multiplicities of duplicate edges were
    # accumulated into the tile values at partition time, so this matches
    # the edge-list backend's per-edge count exactly.  Hoisted here (once
    # per graph) because it is structure-only data.
    deg = np.zeros((pg.num_dst_groups, pg.v), np.float32)
    np.add.at(deg, pg.block_row, pg.blocks.sum(axis=2, dtype=np.float32))
    return BlockedGraph(
        blocks=jnp.asarray(pg.blocks),
        block_row=jnp.asarray(pg.block_row),
        block_col=jnp.asarray(pg.block_col),
        num_dst_groups=pg.num_dst_groups,
        num_src_groups=pg.num_src_groups,
        v=pg.v,
        n=pg.n,
        num_nodes=pg.num_nodes,
        deg=jnp.asarray(deg.reshape(-1)),
    )


def blocked_degrees(bg: BlockedGraph) -> jax.Array:
    """Per-destination-row degrees [G_dst * V] (precomputed or derived)."""
    if bg.deg is not None:
        return bg.deg
    deg_partial = bg.blocks.sum(axis=2)                        # [B, V]
    deg = jax.ops.segment_sum(deg_partial, bg.block_row,
                              num_segments=bg.num_dst_groups)
    return deg.reshape(bg.num_dst_groups * bg.v)


def with_degrees(bg: BlockedGraph) -> BlockedGraph:
    """Attach the degree vector so downstream layers share one reduction.

    Used by serving executors whose BlockedGraphs are built from batched
    device arrays (no host PartitionedGraph to hoist from): calling this
    once at trace entry makes every MEAN layer in the model reuse a single
    segment-sum instead of re-deriving degrees per layer.
    """
    if bg.deg is not None:
        return bg
    return bg._replace(deg=blocked_degrees(bg))


# ---------------------------------------------------------------------------
# Edge-list reference backend.
# ---------------------------------------------------------------------------

def aggregate_edges(
    edge_src: jax.Array,
    edge_dst: jax.Array,
    feat: jax.Array,
    num_nodes: int,
    reduce: ReduceOp = ReduceOp.SUM,
    edge_weights: jax.Array | None = None,
) -> jax.Array:
    """Edge-list aggregation: out[v] = reduce_{(u,v) in E} w_uv * feat[u]."""
    msgs = feat[edge_src]
    if edge_weights is not None:
        msgs = msgs * edge_weights[:, None]
    if reduce in (ReduceOp.SUM, ReduceOp.MEAN):
        out = jax.ops.segment_sum(msgs, edge_dst, num_segments=num_nodes)
        if reduce == ReduceOp.MEAN:
            deg = jax.ops.segment_sum(
                jnp.ones_like(edge_dst, feat.dtype), edge_dst, num_segments=num_nodes
            )
            out = out / jnp.maximum(deg, 1.0)[:, None]
        return out
    if reduce == ReduceOp.MAX:
        out = jax.ops.segment_max(msgs, edge_dst, num_segments=num_nodes)
        # Isolated vertices get -inf from segment_max; zero them like the
        # hardware comparator (no inputs -> no output).
        return jnp.where(jnp.isfinite(out), out, 0.0)
    raise ValueError(f"unknown reduce {reduce}")


# ---------------------------------------------------------------------------
# Blocked (GHOST) backend.
# ---------------------------------------------------------------------------

def aggregate_blocked(
    bg: BlockedGraph,
    feat_padded: jax.Array,
    reduce: ReduceOp = ReduceOp.SUM,
    block_f: Optional[int] = None,
) -> jax.Array:
    """Blocked aggregation over non-zero tiles only.

    Args:
      bg: blocked adjacency (non-zero tiles).
      feat_padded: [G_src * N, F] source features, padded (see
        PartitionedGraph.pad_features).
      reduce: SUM / MEAN / MAX.
      block_f: feature tile width of the Pallas SpMM kernel (autotuner
        knob; None = the kernel's 128-lane default, ignored on jnp).

    Returns:
      [G_dst * V, F] aggregated features (padded rows included).
    """
    f = feat_padded.shape[-1]

    def mean_normalize(out):
        # Shared by all backends — their MEAN semantics must never drift
        # apart.  Degrees come precomputed with the graph when available
        # (structure-static; see BlockedGraph.deg).  Normalization is an
        # explicit reciprocal-multiply, NOT a divide: when deg is a trace
        # constant XLA rewrites x/deg into x*(1/deg) anyway, so writing the
        # multiply keeps constant-deg and traced-deg programs (serving
        # reference vs executor) bit-identical, and matches the fused
        # kernel's epilogue exactly.
        deg = blocked_degrees(bg).astype(out.dtype)
        inv = 1.0 / jnp.maximum(deg, 1.0)
        return out * inv[:, None]

    if active_aggregate_backend() in _PALLAS_BACKENDS and reduce in (
            ReduceOp.SUM, ReduceOp.MEAN):
        # Lazy import: kernels.ops imports core.partition; importing it at
        # module scope would cycle through core/__init__.
        from repro.kernels.ops import block_spmm_padded

        out = block_spmm_padded(bg.blocks, bg.block_row, bg.block_col,
                                feat_padded, bg.num_dst_groups,
                                block_f=block_f or 128)
        if reduce == ReduceOp.MEAN:
            out = mean_normalize(out)
        return out.astype(feat_padded.dtype)

    src_tiles = feat_padded.reshape(bg.num_src_groups, bg.n, f)[bg.block_col]  # [B,N,F]

    if reduce in (ReduceOp.SUM, ReduceOp.MEAN):
        partial = jnp.einsum(
            "bvn,bnf->bvf", bg.blocks, src_tiles.astype(bg.blocks.dtype)
        )
        out = jax.ops.segment_sum(partial, bg.block_row, num_segments=bg.num_dst_groups)
        out = out.reshape(bg.num_dst_groups * bg.v, f)
        if reduce == ReduceOp.MEAN:
            out = mean_normalize(out)
        return out.astype(feat_padded.dtype)

    if reduce == ReduceOp.MAX:
        mask = (bg.blocks != 0)[..., None]                          # [B,V,N,1]
        neg = jnp.asarray(-jnp.inf, feat_padded.dtype)
        cand = jnp.where(mask, src_tiles[:, None, :, :], neg)       # [B,V,N,F]
        partial = cand.max(axis=2)                                  # [B,V,F]
        out = jax.ops.segment_max(partial, bg.block_row, num_segments=bg.num_dst_groups)
        out = out.reshape(bg.num_dst_groups * bg.v, f)
        return jnp.where(jnp.isfinite(out), out, 0.0)

    raise ValueError(f"unknown reduce {reduce}")


# ---------------------------------------------------------------------------
# Fused aggregate+combine with combination-order planning.
#
# The GReTA reduce->transform pair admits two execution orders (paper
# Section 3.4.2 applies it to GAT; the FLOP argument applies everywhere):
#
#   aggregate_first:  (A X) W    SpMM over F_in,  dense combine to F_out
#   combine_first:    A (X W)    dense combine to F_out, SpMM over F_out
#
# Linearity makes them mathematically identical for SUM, and for MEAN too
# (D^-1 A (X W) == (D^-1 A X) W — the degree scale is per-row).  The planner
# picks the cheaper order from static tile counts; the serving engine's
# "pallas_fused" backend additionally lowers the aggregate-first order onto
# the fused epilogue kernel so the [G_dst*V, F_in] intermediate never
# touches HBM.
# ---------------------------------------------------------------------------

COMBINE_ORDERS = ("auto", "aggregate_first", "combine_first")


class CombinePlan(NamedTuple):
    """Static cost breakdown behind one order decision (roofline inputs)."""

    order: str                     # "aggregate_first" | "combine_first"
    flops_aggregate_first: int     # 2*B*V*N*F_in + 2*G_dst*V*F_in*F_out
    flops_combine_first: int       # 2*G_src*N*F_in*F_out + 2*B*V*N*F_out
    fused_hbm_bytes_saved: int     # the [G_dst*V, F_in] fp32 write+read the
                                   # fused epilogue eliminates (agg-first)

    def to_dict(self) -> dict:
        return dict(self._asdict())


_PLAN_TLS = threading.local()


def _plan_log() -> dict:
    log = getattr(_PLAN_TLS, "log", None)
    if log is None:
        log = _PLAN_TLS.log = {}
    return log


def planner_decisions() -> list:
    """Order decisions recorded at trace time, one dict per distinct
    (tile geometry, F_in, F_out, reduce, backend) site — benchmark/report
    fodder, deduplicated so jit retraces don't grow it."""
    return [
        {"blocks": k[0], "v": k[1], "n": k[2], "g_dst": k[3], "g_src": k[4],
         "f_in": k[5], "f_out": k[6], "reduce": k[7], "backend": k[8],
         "quantized": k[9], **plan.to_dict()}
        for k, plan in _plan_log().items()
    ]


def clear_planner_log() -> None:
    _plan_log().clear()


def _order_flops(b: int, v: int, n: int, g_dst: int, g_src: int,
                 f_in: int, f_out: int) -> tuple[int, int]:
    """(aggregate_first, combine_first) FLOP totals for one stage pair."""
    agg_first = 2 * b * v * n * f_in + 2 * g_dst * v * f_in * f_out
    comb_first = 2 * g_src * n * f_in * f_out + 2 * b * v * n * f_out
    return agg_first, comb_first


def _plan_order_from_geom(b: int, v: int, n: int, g_dst: int, g_src: int,
                          f_in: int, f_out: int) -> str:
    """The auto order decision from raw geometry — used by the sharded
    forward, which must plan on GLOBAL tile counts (a per-shard plan could
    flip the choice and break bit-exactness vs the single-device run)."""
    agg_first, comb_first = _order_flops(b, v, n, g_dst, g_src, f_in, f_out)
    return "aggregate_first" if agg_first <= comb_first else "combine_first"


def plan_combine_order(bg: BlockedGraph, f_in: int, f_out: int,
                       order: str = "auto") -> CombinePlan:
    """Choose the aggregate/combine execution order from static FLOPs.

    All inputs are trace-time constants (tile counts and feature widths),
    so the decision is static per jit trace — no data-dependent control
    flow enters the compiled program.  ``order`` overrides the choice.
    """
    if order not in COMBINE_ORDERS:
        raise ValueError(f"unknown combine order '{order}'; "
                         f"expected one of {COMBINE_ORDERS}")
    b = int(bg.blocks.shape[0])
    agg_first, comb_first = _order_flops(
        b, bg.v, bg.n, bg.num_dst_groups, bg.num_src_groups, f_in, f_out)
    if order == "auto":
        order = "aggregate_first" if agg_first <= comb_first else "combine_first"
    return CombinePlan(
        order=order,
        flops_aggregate_first=agg_first,
        flops_combine_first=comb_first,
        fused_hbm_bytes_saved=2 * bg.num_dst_groups * bg.v * f_in * 4,
    )


def _record_plan(bg: BlockedGraph, f_in: int, f_out: int, reduce: ReduceOp,
                 backend: str, plan: CombinePlan,
                 quantized: bool = False) -> None:
    key = (int(bg.blocks.shape[0]), bg.v, bg.n, bg.num_dst_groups,
           bg.num_src_groups, f_in, f_out, str(reduce.value), backend,
           bool(quantized))
    _plan_log()[key] = plan


# The one epilogue-activation vocabulary, shared with the fused kernel
# (repro.kernels.fused_block_spmm imports this table): every name here must
# be implemented identically by _apply_activation below (XLA path) and by
# apply_epilogue_activation in the kernel (in-kernel path), so backend
# choice can never change the supported or computed activation set.
EPILOGUE_ACTIVATIONS = ("none", "relu", "elu")


def _apply_activation(y: jax.Array, activation: Optional[str]) -> jax.Array:
    if activation in (None, "none"):
        return y
    if activation == "relu":
        return jax.nn.relu(y)
    if activation == "elu":
        return jax.nn.elu(y)
    raise ValueError(f"unknown activation '{activation}'; "
                     f"expected one of {EPILOGUE_ACTIVATIONS}")


def dense_combine(x: jax.Array, w: jax.Array, bias: Optional[jax.Array] = None,
                  activation: Optional[str] = None,
                  quantized: bool = False) -> jax.Array:
    """The combine-block transform: act(x @ w + bias).

    The one combine implementation every layer path shares — the fused
    kernel's epilogue, the combine-first projection (including GAT's
    transform-first W h), and the unfused fallback all reduce to this map.
    ``quantized`` routes through the photonic 8-bit sign-split MVM.
    """
    if quantized:
        from repro.photonic.quant import QuantConfig, quantized_matmul

        y = quantized_matmul(x, w, QuantConfig())
    else:
        y = x @ w
    if bias is not None:
        y = y + bias
    return _apply_activation(y, activation)


def aggregate_combine_blocked(
    bg: BlockedGraph,
    feat_padded: jax.Array,        # [G_src * N, F_in]
    w: jax.Array,                  # [F_in, F_out]
    bias: Optional[jax.Array] = None,
    reduce: ReduceOp = ReduceOp.SUM,
    activation: Optional[str] = None,
    order: str = "auto",
    quantized: bool = False,
) -> jax.Array:
    """One aggregate+combine stage pair with order planning and fusion.

    Computes ``act(reduce_agg(bg, feat) @ w + bias)`` — the body of every
    aggregate-first GNN layer — choosing the execution order statically
    (see ``plan_combine_order``) and, on the ``pallas_fused`` backend,
    running the aggregate-first order through the fused Pallas kernel.
    An installed kernel-config resolver (``kernel_config_scope``; the
    autotuner's hook) can additionally steer fused-vs-unfused, the auto
    order decision, and the kernel tile widths per shape class.

    Nonlinear stages pin the execution order to aggregate-first (the
    combine cannot be hoisted through them) but no longer force the slow
    path:
      * MAX reduce — lowered onto the fused kernel's comparator mode on
        ``pallas_fused`` (jnp comparator + dense combine elsewhere).
      * ``quantized`` — the int8 sign-split MVM runs as the fused kernel's
        quantized epilogue on ``pallas_fused`` (per-row-block activation
        scales; see the kernel's documented int8 tolerance vs the
        per-tensor oracle), and as the unfused
        ``photonic.quant.quantized_matmul`` elsewhere.

    Returns [G_dst * V, F_out].
    """
    f_in = int(feat_padded.shape[-1])
    f_out = int(w.shape[-1])
    backend = active_aggregate_backend()

    cfg = None
    resolver = active_kernel_resolver()
    if resolver is not None:
        cfg = resolver(KernelSite(
            num_blocks=int(bg.blocks.shape[0]),
            num_dst_groups=bg.num_dst_groups,
            num_src_groups=bg.num_src_groups,
            v=bg.v, n=bg.n, f_in=f_in, f_out=f_out,
            reduce=str(reduce.value), dtype=str(feat_padded.dtype),
            quantized=bool(quantized), backend=backend))

    # Shard routing (see shard_scope): the feature-dim partition applies to
    # every stage whose epilogue is linear in the aggregated features — the
    # int8 MVM's per-tensor activation scale is a global max, so quantized
    # sites stay on the single-device lowering (the dst_block strategy of
    # aggregate_combine_sharded shards those exactly).  A kernel config can
    # veto with shard="none".
    ctx = active_shard_context()
    shard_override = getattr(cfg, "shard", None) if cfg is not None else None
    use_shard = (ctx is not None and ctx.num_shards > 1 and not quantized
                 and shard_override != "none")

    # MAX and the int8 MVM are nonlinear: the combine cannot move through
    # them, so the order is pinned regardless of request or tuner choice.
    # The feature partition likewise pins aggregate-first — it splits the
    # SpMM width and the combine contraction together.
    pinned = reduce == ReduceOp.MAX or quantized or use_shard
    if pinned:
        order = "aggregate_first"
    elif order == "auto" and cfg is not None and getattr(
            cfg, "order", None) in ("aggregate_first", "combine_first"):
        order = cfg.order
    plan = plan_combine_order(bg, f_in, f_out, order)
    _record_plan(bg, f_in, f_out, reduce, backend, plan, quantized)

    block_f = getattr(cfg, "block_f", None) if cfg is not None else None

    if use_shard:
        return _feature_sharded(bg, feat_padded, w, bias, reduce, activation,
                                ctx, block_f)

    if plan.order == "combine_first":
        # Narrow the SpMM width first; the blocked aggregation then runs on
        # whichever backend is active (incl. the unfused Pallas kernel).
        xw = dense_combine(feat_padded, w)
        h = aggregate_blocked(bg, xw, reduce, block_f=block_f)
        if bias is not None:
            h = h + bias
        return _apply_activation(h, activation)

    use_fused = backend == "pallas_fused"
    if use_fused and cfg is not None and getattr(cfg, "fused", None) is not None:
        use_fused = bool(cfg.fused)

    if use_fused:
        # Lazy import: kernels.ops imports core.partition (cycle guard).
        from repro.kernels.ops import fused_block_spmm_padded

        inv_deg = None
        if reduce == ReduceOp.MEAN:
            deg = blocked_degrees(bg).astype(feat_padded.dtype)
            inv_deg = 1.0 / jnp.maximum(deg, 1.0)
        lane = getattr(cfg, "lane", None) if cfg is not None else None
        out = fused_block_spmm_padded(
            bg.blocks, bg.block_row, bg.block_col, feat_padded, w, bias,
            inv_deg, bg.num_dst_groups,
            activation=activation if activation else "none",
            reduce="max" if reduce == ReduceOp.MAX else "sum",
            quantized=bool(quantized),
            lane=lane or 128,
        )
        return out.astype(feat_padded.dtype)

    h = aggregate_blocked(bg, feat_padded, reduce, block_f=block_f)
    return dense_combine(h, w, bias, activation, quantized)


def attention_aggregate_blocked(
    bg: BlockedGraph,
    values_padded: jax.Array,   # [G_src*N, H, F]  (already W-transformed, per head)
    src_scores: jax.Array,      # [G_src*N, H]     a_src . (W h_u)
    dst_scores: jax.Array,      # [G_dst*V, H]     a_dst . (W h_v)
    negative_slope: float = 0.2,
) -> jax.Array:
    """GAT-style masked-softmax aggregation on the blocked adjacency.

    Computes, per head h:  out[v] = sum_u softmax_u(leaky_relu(e_uv)) val[u]
    with e_uv = dst_scores[v] + src_scores[u], masked to edges, using a
    numerically-stable two-pass (segment-max then segment-sum) over tiles —
    the blocked analogue of GHOST's GAT pipeline (Section 3.4.2, Fig. 6b).

    Returns [G_dst*V, H, F].
    """
    heads = values_padded.shape[1]
    f = values_padded.shape[2]
    mask = bg.blocks != 0                                              # [B,V,N]

    s_src = src_scores.reshape(bg.num_src_groups, bg.n, heads)[bg.block_col]   # [B,N,H]
    s_dst = dst_scores.reshape(bg.num_dst_groups, bg.v, heads)[bg.block_row]   # [B,V,H]
    logits = s_dst[:, :, None, :] + s_src[:, None, :, :]               # [B,V,N,H]
    logits = jax.nn.leaky_relu(logits, negative_slope)
    neg = jnp.asarray(-1e30, logits.dtype)
    logits = jnp.where(mask[..., None], logits, neg)

    # Pass 1: per-destination-row running max across tiles.
    tile_max = logits.max(axis=2)                                      # [B,V,H]
    row_max = jax.ops.segment_max(tile_max, bg.block_row, num_segments=bg.num_dst_groups)
    row_max = jnp.maximum(row_max, -1e30)                              # isolated rows
    m = row_max[bg.block_row][:, :, None, :]                           # [B,V,1,H]

    # Pass 2: exp-sum and weighted value sum.  Tile values carry edge
    # multiplicity (duplicate edges accumulate at partition time), so p is
    # scaled by them — matching the edge-list softmax on multigraphs.
    mult = bg.blocks[..., None]                                        # [B,V,N,1]
    p = jnp.where(mask[..., None], mult * jnp.exp(logits - m), 0.0)    # [B,V,N,H]
    denom_partial = p.sum(axis=2)                                      # [B,V,H]
    denom = jax.ops.segment_sum(denom_partial, bg.block_row, num_segments=bg.num_dst_groups)

    vals = values_padded.reshape(bg.num_src_groups, bg.n, heads, f)[bg.block_col]  # [B,N,H,F]
    num_partial = jnp.einsum("bvnh,bnhf->bvhf", p, vals)               # [B,V,H,F]
    num = jax.ops.segment_sum(num_partial, bg.block_row, num_segments=bg.num_dst_groups)

    out = num / jnp.maximum(denom, 1e-30)[..., None]
    return out.reshape(bg.num_dst_groups * bg.v, heads, f)


# ---------------------------------------------------------------------------
# Sharded execution: one blocked aggregate+combine stage across a 1-D device
# mesh.  Two partition strategies, mirroring the two dimensions of the fused
# SpMM (the scaling lever both GNN-acceleration surveys in PAPERS.md name —
# partition-parallel execution across compute units):
#
#   "dst_block" — partition by destination block-row.  Each device owns a
#       contiguous slice of destination groups plus the CSR-sorted edge
#       tiles targeting them (owner-exclusive: block_row is non-decreasing,
#       so one host-side pass splits the tile list).  No cross-device
#       collective is needed for SUM/MEAN/MAX — every destination row is
#       reduced entirely on its owner — and per-device tile order equals
#       the single-device order, so outputs are BIT-EXACT vs the unsharded
#       forward on every backend (the one exception: quantized sites whose
#       per-device lowering is the *unfused* per-tensor int8 combine — its
#       activation scale spans all rows; the fused per-row-block epilogue
#       shards exactly).  Requires host-side prep (``shard_blocked``).
#
#   "feature" — partition the combine contraction.  Each device owns an
#       F_in slice of the features and the matching combine-weight rows;
#       the SpMM runs at F_in/D width per device and one ``psum`` over the
#       contracted dimension rebuilds [G_dst*V, F_out].  Association order
#       of the psum differs from the single-device matmul, so outputs agree
#       to a few ULP (documented tolerance).  Needs no graph resharding, so
#       it drops into existing traces — including vmapped serving
#       executors — via ``shard_scope``.
# ---------------------------------------------------------------------------

SHARD_STRATEGIES = ("auto", "dst_block", "feature")


class ShardedBlockedGraph(NamedTuple):
    """A BlockedGraph re-tiled for a D-way destination-block partition.

    Device d owns destination groups [d*local, (d+1)*local) of a group
    space padded up to ``num_shards * local_dst_groups`` (the pad groups
    receive no tiles and their output rows are sliced off again).  Tile
    slots are padded per shard to ``tile_cap`` with all-zero tiles — exact
    no-ops for every reduce mode — and ``block_row`` is rebased to
    device-LOCAL group ids (still non-decreasing per shard, preserving the
    CSR-sortedness the Pallas kernels require).  ``block_col`` stays
    global: source features are replicated.
    """

    blocks: jax.Array       # [D, Bcap, V, N]
    block_row: jax.Array    # [D, Bcap] int32, device-local dst groups
    block_col: jax.Array    # [D, Bcap] int32, global src groups
    deg: jax.Array          # [D, local_dst_groups * V] MEAN degrees
    num_shards: int
    local_dst_groups: int
    num_dst_groups: int     # global, unpadded
    num_src_groups: int
    v: int
    n: int
    num_nodes: int
    num_blocks: int         # global, unpadded tile count (order planning)

    @property
    def tile_cap(self) -> int:
        return int(self.blocks.shape[1])


def shard_blocked(bg: BlockedGraph, num_shards: int,
                  tile_cap: Optional[int] = None) -> ShardedBlockedGraph:
    """Host-side destination-block partition of a BlockedGraph.

    Splits the CSR-sorted tile list by destination-group owner (a
    contiguous slice per shard), pads every shard to ``tile_cap`` tiles
    (default: the busiest shard's count) with zero tiles, and rebases
    ``block_row`` to device-local ids.  Pure numpy — this is preprocessing,
    the sharded analogue of ``serving.bucketing.pad_partition_to_bucket``.
    """
    if num_shards < 1:
        raise ValueError("num_shards must be >= 1")
    blocks = np.asarray(bg.blocks)
    row = np.asarray(bg.block_row)
    col = np.asarray(bg.block_col)
    gd = bg.num_dst_groups
    local = -(-gd // num_shards)          # ceil: pad groups, never drop any
    owner = np.minimum(row // local, num_shards - 1)
    counts = np.bincount(owner, minlength=num_shards)
    need = max(int(counts.max()), 1)
    if tile_cap is None:
        tile_cap = need
    elif tile_cap < need:
        raise ValueError(f"tile_cap {tile_cap} < busiest shard ({need} tiles)")
    sb = np.zeros((num_shards, tile_cap) + blocks.shape[1:], blocks.dtype)
    # Padding tiles keep per-shard block_row non-decreasing (last local
    # group) and block_col in range; all-zero tiles contribute nothing.
    sr = np.full((num_shards, tile_cap), local - 1, np.int32)
    sc = np.full((num_shards, tile_cap), bg.num_src_groups - 1, np.int32)
    for d in range(num_shards):
        sel = owner == d
        k = int(counts[d])
        sb[d, :k] = blocks[sel]
        sr[d, :k] = row[sel] - d * local
        sc[d, :k] = col[sel]
    deg = np.zeros((num_shards * local * bg.v,), np.float32)
    deg[: gd * bg.v] = np.asarray(blocked_degrees(bg))
    return ShardedBlockedGraph(
        blocks=jnp.asarray(sb),
        block_row=jnp.asarray(sr),
        block_col=jnp.asarray(sc),
        deg=jnp.asarray(deg.reshape(num_shards, local * bg.v)),
        num_shards=num_shards,
        local_dst_groups=local,
        num_dst_groups=gd,
        num_src_groups=bg.num_src_groups,
        v=bg.v,
        n=bg.n,
        num_nodes=bg.num_nodes,
        num_blocks=int(blocks.shape[0]),
    )


class ShardPlan(NamedTuple):
    """Static cost sketch behind one strategy decision (roofline inputs)."""

    strategy: str            # "dst_block" | "feature"
    num_shards: int
    psum_bytes: int          # collective traffic per stage (0 = none)
    bit_exact: bool          # vs the single-device blocked forward

    def to_dict(self) -> dict:
        return dict(self._asdict())


def plan_shard_strategy(num_dst_groups: int, v: int, f_out: int,
                        num_shards: int, *, reduce: ReduceOp = ReduceOp.SUM,
                        quantized: bool = False,
                        sharded_graph: bool = False,
                        strategy: str = "auto") -> ShardPlan:
    """Choose the partition strategy from static shape facts.

    The destination-block partition wins whenever host-prepped tiles are
    available (``sharded_graph``): it moves no bytes between devices and is
    bit-exact.  The feature partition is the fallback that needs no prep
    but pays one fp32 ``psum`` of the [G_dst*V, F_out] output per stage.
    Quantized stages only shard destination-wise (the per-tensor int8
    activation scale does not decompose over feature slices).
    """
    if strategy not in SHARD_STRATEGIES:
        raise ValueError(f"unknown shard strategy '{strategy}'; "
                         f"expected one of {SHARD_STRATEGIES}")
    if strategy == "auto":
        strategy = "dst_block" if (sharded_graph or quantized) else "feature"
    if strategy == "feature" and quantized:
        raise ValueError("quantized stages cannot use the feature partition "
                         "(per-tensor int8 scale is a global reduction); "
                         "prep a ShardedBlockedGraph for dst_block instead")
    psum = (0 if strategy == "dst_block"
            else num_dst_groups * v * f_out * 4 * max(num_shards - 1, 0))
    return ShardPlan(strategy=strategy, num_shards=num_shards,
                     psum_bytes=psum,
                     bit_exact=strategy == "dst_block" and not quantized)


def _feature_sharded(bg: BlockedGraph, feat_padded: jax.Array, w: jax.Array,
                     bias: Optional[jax.Array], reduce: ReduceOp,
                     activation: Optional[str], ctx: ShardContext,
                     block_f: Optional[int]) -> jax.Array:
    """Feature-dim partition: SpMM over an F_in slice per device, psum over
    the contracted combine dimension.  Works under vmap/jit (all operands
    are explicit shard_map arguments, so outer batching rules apply)."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    d = ctx.num_shards
    f_in = int(feat_padded.shape[-1])
    f_out = int(w.shape[-1])
    pad = (-f_in) % d
    # Zero feature columns x zero weight rows are exact no-ops for SUM/MEAN
    # (0 contribution) and for MAX (all-zero columns aggregate to 0, then
    # meet zero weight rows), so padding F_in to a shard multiple is safe.
    featp = jnp.pad(feat_padded, ((0, 0), (0, pad)))
    wp = jnp.pad(w.astype(feat_padded.dtype), ((0, pad), (0, 0)))
    bias_row = (jnp.zeros((f_out,), feat_padded.dtype) if bias is None
                else bias.astype(feat_padded.dtype))
    deg = blocked_degrees(bg).astype(feat_padded.dtype)
    axis = ctx.axis

    def body(blocks, row, col, dg, featl, wl, bias_l):
        lbg = BlockedGraph(
            blocks=blocks, block_row=row, block_col=col,
            num_dst_groups=bg.num_dst_groups,
            num_src_groups=bg.num_src_groups,
            v=bg.v, n=bg.n, num_nodes=bg.num_nodes, deg=dg)
        # MEAN normalizes per destination row — exact on a column slice.
        agg = aggregate_blocked(lbg, featl, reduce, block_f=block_f)
        partial = agg.astype(jnp.float32) @ wl.astype(jnp.float32)
        out = jax.lax.psum(partial, axis)
        # Bias and activation apply once, after the contraction completes;
        # every device computes the same replicated value.
        return _apply_activation(out + bias_l.astype(out.dtype), activation)

    fn = shard_map(
        body, ctx.mesh,
        in_specs=(P(), P(), P(), P(),            # graph replicated
                  P(None, axis),                 # feature columns split
                  P(axis, None),                 # matching weight rows
                  P()),
        out_specs=P(),
        check_rep=False)
    out = fn(bg.blocks, bg.block_row, bg.block_col, deg, featp, wp, bias_row)
    return out.astype(feat_padded.dtype)


def aggregate_combine_sharded(
    graph,                          # ShardedBlockedGraph | BlockedGraph
    feat_padded: jax.Array,         # [G_src * N, F_in] (replicated)
    w: jax.Array,                   # [F_in, F_out]
    bias: Optional[jax.Array] = None,
    *,
    mesh,
    axis: str = "data",
    reduce: ReduceOp = ReduceOp.SUM,
    activation: Optional[str] = None,
    order: str = "auto",
    quantized: bool = False,
    strategy: str = "auto",
) -> jax.Array:
    """One aggregate+combine stage partitioned across a 1-D device mesh.

    ``graph`` selects the partition: a ``ShardedBlockedGraph`` (from
    ``shard_blocked``) runs the destination-block strategy — owner-exclusive
    destination rows, no collectives, bit-exact vs the single-device
    ``aggregate_combine_blocked`` on every backend; a plain ``BlockedGraph``
    runs the feature-dim strategy (psum over the contracted combine
    dimension, few-ULP tolerance).  The active aggregate backend and any
    installed kernel-config resolver apply inside each device's local
    lowering, so the fused epilogue kernel and tuned tile widths carry over
    per shard unchanged.

    Returns [G_dst * V, F_out] (global, padding groups sliced off).
    """
    sharded = isinstance(graph, ShardedBlockedGraph)
    plan = plan_shard_strategy(
        graph.num_dst_groups, graph.v, int(w.shape[-1]),
        int(mesh.shape[axis]), reduce=reduce, quantized=quantized,
        sharded_graph=sharded, strategy=strategy)
    if plan.strategy == "feature":
        if sharded:
            raise ValueError("feature strategy takes a plain BlockedGraph "
                             "(source features are partitioned, not tiles)")
        ctx = ShardContext(mesh=mesh, axis=axis)
        if ctx.num_shards == 1:
            return aggregate_combine_blocked(
                graph, feat_padded, w, bias, reduce=reduce,
                activation=activation, order=order, quantized=quantized)
        return _feature_sharded(graph, feat_padded, w, bias, reduce,
                                activation, ctx, None)
    if not sharded:
        raise ValueError("dst_block strategy needs a ShardedBlockedGraph "
                         "(host-side prep: shard_blocked(bg, num_shards))")
    return _dst_block_sharded(graph, feat_padded, w, bias, reduce,
                              activation, order, quantized, mesh, axis)


def _dst_block_sharded(sbg: ShardedBlockedGraph, feat_padded: jax.Array,
                       w: jax.Array, bias: Optional[jax.Array],
                       reduce: ReduceOp, activation: Optional[str],
                       order: str, quantized: bool, mesh, axis) -> jax.Array:
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    d = int(mesh.shape[axis])
    if d != sbg.num_shards:
        raise ValueError(f"graph was sharded {sbg.num_shards}-way but mesh "
                         f"axis '{axis}' has {d} devices")
    f_in = int(feat_padded.shape[-1])
    f_out = int(w.shape[-1])
    # Resolve the execution order from the GLOBAL geometry, so every
    # device lowers the same order the single-device forward would pick —
    # a per-shard FLOP plan could flip the decision and break bit-exactness.
    if reduce == ReduceOp.MAX or quantized:
        order = "aggregate_first"
    elif order == "auto":
        order = _plan_order_from_geom(
            sbg.num_blocks, sbg.v, sbg.n, sbg.num_dst_groups,
            sbg.num_src_groups, f_in, f_out)
    local = sbg.local_dst_groups
    bias_arg = [] if bias is None else [bias]

    def body(blocks, row, col, dg, featl, wl, *bias_l):
        lbg = BlockedGraph(
            blocks=blocks[0], block_row=row[0], block_col=col[0],
            num_dst_groups=local, num_src_groups=sbg.num_src_groups,
            v=sbg.v, n=sbg.n, num_nodes=local * sbg.v, deg=dg[0])
        # Suppress any enclosing shard_scope: the per-device body IS the
        # sharded lowering; recursing into the feature router would nest
        # shard_maps.
        with shard_scope(None):
            return aggregate_combine_blocked(
                lbg, featl, wl, bias_l[0] if bias_l else None,
                reduce=reduce, activation=activation, order=order,
                quantized=quantized)

    fn = shard_map(
        body, mesh,
        in_specs=(P(axis), P(axis), P(axis), P(axis),   # owner-split graph
                  P(), P()) + tuple(P() for _ in bias_arg),
        out_specs=P(axis),
        check_rep=False)
    out = fn(sbg.blocks, sbg.block_row, sbg.block_col, sbg.deg,
             feat_padded, w, *bias_arg)
    # Padding destination groups (group-count rounding) are sliced off.
    return out[: sbg.num_dst_groups * sbg.v]
