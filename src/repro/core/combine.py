"""Combine block — the transform-unit linear map (paper Section 3.3.2).

The photonic combine block is an MR-bank-array MVM with optional optical
batch-norm and balanced-photodetector accumulation of sign-split values.  On
TPU the same stage is either a bf16/f32 matmul (training) or the int8
sign-split quantized matmul (serving fast path; see
``repro.photonic.quant`` + ``repro.kernels.quant_matmul``).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.photonic.quant import QuantConfig, quantized_matmul


@dataclasses.dataclass(frozen=True)
class CombineConfig:
    quantized: bool = False           # use the photonic 8-bit sign-split path
    quant: QuantConfig = dataclasses.field(default_factory=QuantConfig)
    batch_norm: bool = False          # optical BN via broadband MRs


def linear(x: jax.Array, w: jax.Array, b: Optional[jax.Array] = None) -> jax.Array:
    y = x @ w
    if b is not None:
        y = y + b
    return y


def combine(
    h_agg: jax.Array,
    params: dict,
    cfg: CombineConfig = CombineConfig(),
) -> jax.Array:
    """Apply the combine-block transform: y = h_agg @ W (+ b) (+ BN).

    params: {"w": [F_in, F_out], optional "b": [F_out],
             optional "bn_scale"/"bn_bias": [F_out]}
    """
    w = params["w"]
    if cfg.quantized:
        y = quantized_matmul(h_agg, w, cfg.quant)
    else:
        y = h_agg @ w
    if "b" in params and params["b"] is not None:
        y = y + params["b"]
    if cfg.batch_norm and "bn_scale" in params:
        # Inference-time BN folded to scale/bias (the broadband-MR tuning).
        y = y * params["bn_scale"] + params["bn_bias"]
    return y
