"""Graph container used throughout the GNN half of the framework.

A deliberately simple, numpy-backed structure: GHOST's preprocessing
(partitioning, fetch-order generation) is an *offline* step in the paper
(Section 3.4.1), so it runs in numpy; only the per-layer compute runs in JAX.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass
class Graph:
    """A single graph.

    Attributes:
      edge_src: [E] int32 source (input) vertex of each edge.
      edge_dst: [E] int32 destination (output) vertex of each edge.
      node_feat: [Nv, F] float32 vertex feature matrix.
      edge_feat: optional [E, Fe] float32 edge features.
      labels: optional [Nv] int32 node labels (node classification) or
        scalar graph label (graph classification).
      train_mask / val_mask / test_mask: optional [Nv] bool masks.
      graph_label: optional int for graph-classification datasets.
    """

    edge_src: np.ndarray
    edge_dst: np.ndarray
    node_feat: np.ndarray
    edge_feat: Optional[np.ndarray] = None
    labels: Optional[np.ndarray] = None
    train_mask: Optional[np.ndarray] = None
    val_mask: Optional[np.ndarray] = None
    test_mask: Optional[np.ndarray] = None
    graph_label: Optional[int] = None
    name: str = "graph"

    @property
    def num_nodes(self) -> int:
        return int(self.node_feat.shape[0])

    @property
    def num_edges(self) -> int:
        return int(self.edge_src.shape[0])

    @property
    def num_features(self) -> int:
        return int(self.node_feat.shape[1])

    def validate(self) -> "Graph":
        if self.edge_src.shape != self.edge_dst.shape:
            raise ValueError("edge_src/edge_dst shape mismatch")
        if self.num_edges and (
            self.edge_src.max() >= self.num_nodes or self.edge_dst.max() >= self.num_nodes
        ):
            raise ValueError("edge endpoint out of range")
        if self.edge_src.dtype != np.int32:
            self.edge_src = self.edge_src.astype(np.int32)
            self.edge_dst = self.edge_dst.astype(np.int32)
        return self

    def with_self_loops(self) -> "Graph":
        """Return a copy with self loops added for every vertex (dedup'd).

        GCN-style aggregation includes the vertex itself (h_v in the paper's
        reduce output h_v + sum_u h_u).
        """
        # Vectorized membership: a vertex needs a loop added iff no existing
        # edge is already (i, i).  Appended loop order (ascending vertex id)
        # matches the old python-set scan exactly, so partitions — and
        # therefore content-hash cache keys — are unchanged.
        has_loop = np.zeros(self.num_nodes, dtype=bool)
        self_edges = self.edge_src == self.edge_dst
        has_loop[self.edge_dst[self_edges]] = True
        keep = np.flatnonzero(~has_loop).astype(np.int32)
        return dataclasses.replace(
            self,
            edge_src=np.concatenate([self.edge_src, keep]),
            edge_dst=np.concatenate([self.edge_dst, keep]),
            edge_feat=None if self.edge_feat is None else np.concatenate(
                [self.edge_feat, np.zeros((len(keep), self.edge_feat.shape[1]), self.edge_feat.dtype)]
            ),
        )

    def in_degrees(self) -> np.ndarray:
        deg = np.zeros(self.num_nodes, dtype=np.int64)
        np.add.at(deg, self.edge_dst, 1)
        return deg

    def out_degrees(self) -> np.ndarray:
        deg = np.zeros(self.num_nodes, dtype=np.int64)
        np.add.at(deg, self.edge_src, 1)
        return deg

    def dense_adjacency(self) -> np.ndarray:
        """[Nv, Nv] dense 0/1 adjacency, A[dst, src] = 1.  Small graphs only."""
        a = np.zeros((self.num_nodes, self.num_nodes), dtype=np.float32)
        a[self.edge_dst, self.edge_src] = 1.0
        return a

    def gcn_edge_weights(self) -> np.ndarray:
        """Symmetric-normalized GCN weights per edge: 1/sqrt(d_dst * d_src).

        Assumes self-loops have already been added (Kipf & Welling renorm trick).
        """
        deg = self.in_degrees().astype(np.float64)
        w = 1.0 / np.sqrt(np.maximum(deg[self.edge_dst], 1) * np.maximum(deg[self.edge_src], 1))
        return w.astype(np.float32)
