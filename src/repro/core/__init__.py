# The paper's primary contribution: the GReTA-based GHOST dataflow.
from repro.core.graph import Graph
from repro.core.partition import PartitionedGraph, PartitionStats, partition_graph
from repro.core.aggregate import (
    AGGREGATE_BACKENDS,
    COMBINE_ORDERS,
    SHARD_STRATEGIES,
    BlockedGraph,
    CombinePlan,
    KernelSite,
    ReduceOp,
    ShardContext,
    ShardedBlockedGraph,
    ShardPlan,
    active_aggregate_backend,
    active_kernel_resolver,
    active_shard_context,
    aggregate_backend,
    aggregate_blocked,
    aggregate_combine_blocked,
    aggregate_combine_sharded,
    aggregate_edges,
    attention_aggregate_blocked,
    blocked_degrees,
    clear_planner_log,
    dense_combine,
    kernel_config_scope,
    plan_combine_order,
    plan_shard_strategy,
    planner_decisions,
    shard_blocked,
    shard_scope,
    to_blocked,
    with_degrees,
)
from repro.core.greta import ExecutionOrder, GretaSpec, run_layer_blocked, run_layer_edges
from repro.core.combine import CombineConfig, combine, linear
from repro.core.update import get_activation, is_optical, soa_transfer
from repro.core.pipeline import (
    StageLoad,
    grouped_latency,
    pipelined_latency,
    sequential_latency,
)
