"""Execution pipelining & scheduling model (paper Section 3.4.2, Fig. 6).

GHOST pipelines at two granularities:

  level 1 — within one output-vertex group V_i: reduce / transform / update
            units start as soon as their first input tile (R_c neighbors,
            R_r or T_r values) is ready rather than waiting for the whole
            upstream phase.
  level 2 — across output-vertex groups: group V_{i+1}'s first reduce starts
            right after group V_i's last reduce (the reduce units free up),
            overlapping with V_i's transform/update tail.

The model is an analytic flow-shop schedule (matching the paper's simulator
granularity, not a discrete-event simulation).  Each stage s is a dedicated
unit that processes groups in order.  Let C[s] be the time stage s becomes
free.  For group i with per-stage loads t[i, s] (tiles x tile_time):

  no pipelining      start_s = max(C[s], finish_{s-1});  finish_s = start_s + t
  tile pipelining    start_s = max(C[s], start_{s-1} + tile_{s-1})
                     finish_s = max(start_s + t, finish_{s-1} + tile_s)

i.e. a stage may begin one producer-tile after its upstream stage begins, and
cannot finish earlier than one tile after its upstream finishes — the classic
pipelined-dataflow bound.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence


@dataclasses.dataclass(frozen=True)
class StageLoad:
    """One pipeline stage for one group: ``tiles`` items, ``tile_time`` s each."""

    name: str
    tiles: int
    tile_time: float

    @property
    def total(self) -> float:
        return self.tiles * self.tile_time


def sequential_latency(stages: Sequence[StageLoad]) -> float:
    """No pipelining: phases execute back-to-back (the Fig. 8 baseline)."""
    return sum(s.total for s in stages)


def pipelined_latency(stages: Sequence[StageLoad]) -> float:
    """Level-1 (within-group) pipelining only, single group."""
    return grouped_latency([list(stages)], pipeline_within=True,
                           pipeline_across=False)


def grouped_latency(
    per_group_stages: Sequence[Sequence[StageLoad]],
    pipeline_within: bool = True,
    pipeline_across: bool = True,
) -> float:
    """Makespan over all output-vertex groups (levels 1 + 2).

    ``pipeline_across=False`` serializes groups (each group must fully drain
    before the next starts); ``pipeline_within=False`` serializes stages
    inside a group.  Both off reproduces the paper's no-PP baseline.
    """
    if not per_group_stages:
        return 0.0
    num_stages = max(len(g) for g in per_group_stages)
    free = [0.0] * num_stages          # when each stage unit becomes free
    group_done = 0.0
    for stages in per_group_stages:
        starts = [0.0] * len(stages)
        finishes = [0.0] * len(stages)
        barrier = 0.0 if pipeline_across else group_done
        for s, st in enumerate(stages):
            if s == 0:
                start = max(free[s], barrier)
                finish = start + st.total
            elif pipeline_within:
                start = max(free[s], starts[s - 1] + stages[s - 1].tile_time,
                            barrier)
                finish = max(start + st.total, finishes[s - 1] + st.tile_time)
            else:
                start = max(free[s], finishes[s - 1], barrier)
                finish = start + st.total
            starts[s], finishes[s] = start, finish
            free[s] = finish
        group_done = finishes[-1] if finishes else group_done
    return max(free)
