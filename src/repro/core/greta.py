"""GReTA programming model (paper Section 3.5, Algorithm 1).

Four stateless UDFs decompose every GNN layer:

  Gather    — builds a per-edge message from (h_u, h_v, h_uv).  All GNNs the
              paper targets use messages of the form  w_uv * pre(h_u)  with a
              scalar edge weight (1, GCN norm, or a GAT attention coeff) and a
              node-wise pre-map; this is the structure the photonic hardware
              (and the MXU) exploits, so the engine takes (pre, edge policy)
              rather than an arbitrary per-edge closure.
  Reduce    — SUM / MEAN / MAX over the messages of each output vertex.
  Transform — linear map with the shared weights (the combine block).
  Activate  — non-linear update (the update block).

Two execution orders (Section 3.4.2):
  aggregate_first  (GCN / GraphSAGE / GIN):  reduce -> transform -> activate
  transform_first  (GAT):                    transform -> attention-reduce -> activate
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.aggregate import (
    BlockedGraph,
    ReduceOp,
    aggregate_blocked,
    aggregate_edges,
)


class ExecutionOrder(str, enum.Enum):
    AGGREGATE_FIRST = "aggregate_first"
    TRANSFORM_FIRST = "transform_first"


@dataclasses.dataclass(frozen=True)
class GretaSpec:
    """A GNN layer expressed as GReTA UDFs.

    Attributes:
      pre: node-wise map applied to source features before aggregation
        (identity for GCN/SAGE/GIN sum path).
      reduce: the reduce-unit operation.
      transform: (h_agg, h_self, params) -> transformed features.  The
        combine-block linear map; receives the vertex's own (pre-aggregation)
        features for models that treat self separately (GraphSAGE concat, GIN
        (1+eps) center weighting).
      activate: update-block nonlinearity.
      order: aggregate_first or transform_first.
      self_loops: whether aggregation includes the vertex itself (GCN-style);
        graphs are expected to carry self-loop edges when True.
    """

    pre: Callable[[jax.Array], jax.Array]
    reduce: ReduceOp
    transform: Callable[[jax.Array, jax.Array, dict], jax.Array]
    activate: Callable[[jax.Array], jax.Array]
    order: ExecutionOrder = ExecutionOrder.AGGREGATE_FIRST
    self_loops: bool = True


def run_layer_edges(
    spec: GretaSpec,
    params: dict,
    feat: jax.Array,
    edge_src: jax.Array,
    edge_dst: jax.Array,
    num_nodes: int,
    edge_weights: Optional[jax.Array] = None,
) -> jax.Array:
    """Execute one GReTA layer with the edge-list backend (training path)."""
    if spec.order != ExecutionOrder.AGGREGATE_FIRST:
        raise ValueError(
            "transform_first layers (GAT) have model-specific attention; "
            "use the model implementation in repro.gnn.layers"
        )
    msgs_src = spec.pre(feat)
    h_agg = aggregate_edges(
        edge_src, edge_dst, msgs_src, num_nodes, spec.reduce, edge_weights
    )
    h = spec.transform(h_agg, feat, params)
    return spec.activate(h)


def run_layer_blocked(
    spec: GretaSpec,
    params: dict,
    feat_padded: jax.Array,
    bg: BlockedGraph,
) -> jax.Array:
    """Execute one GReTA layer with the GHOST blocked backend (serving path).

    ``feat_padded`` is [G_src * N, F]; the return is [G_dst * V, F_out] with
    padded rows present (slice with bg.num_nodes at the boundary).
    """
    if spec.order != ExecutionOrder.AGGREGATE_FIRST:
        raise ValueError(
            "transform_first layers (GAT) are executed by repro.gnn.layers"
        )
    msgs_src = spec.pre(feat_padded)
    h_agg = aggregate_blocked(bg, msgs_src, spec.reduce)
    h = spec.transform(h_agg, feat_padded, params)
    return spec.activate(h)
