"""AdamW in pure JAX (optax is not available offline).

Written to be ZeRO-shardable: the optimizer state is a pytree with exactly
the same structure/shapes as the parameters, so whatever NamedSharding the
parameters use applies verbatim to `m`/`v` (the distribution layer relies on
this property — see repro/distributed/sharding.py).

Supports decoupled weight decay, global-norm clipping, and an optional
master-dtype: parameters may be bf16 while m/v (and the update math) run in
fp32, the usual large-model recipe.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float | Callable[[jax.Array], jax.Array] = 1e-3
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip_norm: Optional[float] = 1.0
    state_dtype: Any = jnp.float32

    def lr_at(self, step: jax.Array) -> jax.Array:
        if callable(self.lr):
            return self.lr(step)
        return jnp.asarray(self.lr, jnp.float32)


class AdamWState(NamedTuple):
    step: jax.Array  # scalar int32
    m: Any           # pytree like params
    v: Any           # pytree like params


def adamw_init(params, cfg: AdamWConfig = AdamWConfig()) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, cfg.state_dtype)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_step(
    grads, state: AdamWState, params, cfg: AdamWConfig = AdamWConfig()
):
    """One AdamW update. Returns (new_params, new_state, metrics)."""
    step = state.step + 1
    gnorm = global_norm(grads)
    if cfg.grad_clip_norm is not None:
        scale = jnp.minimum(1.0, cfg.grad_clip_norm / jnp.maximum(gnorm, 1e-12))
        grads = jax.tree.map(lambda g: g * scale, grads)

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)
    lr = cfg.lr_at(step)

    def upd(p, g, m, v):
        g32 = g.astype(cfg.state_dtype)
        m_new = b1 * m + (1.0 - b1) * g32
        v_new = b2 * v + (1.0 - b2) * jnp.square(g32)
        m_hat = m_new / bc1
        v_hat = v_new / bc2
        delta = m_hat / (jnp.sqrt(v_hat) + cfg.eps)
        p32 = p.astype(cfg.state_dtype)
        p_new = p32 - lr * (delta + cfg.weight_decay * p32)
        return p_new.astype(p.dtype), m_new, v_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, AdamWState(step=step, m=new_m, v=new_v), metrics
