from repro.optim.adamw import AdamWConfig, adamw_init, adamw_step
from repro.optim.schedule import constant_schedule, warmup_cosine
