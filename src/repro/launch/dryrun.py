import os
os.environ["XLA_FLAGS"] = (os.environ.get("DRYRUN_XLA_FLAGS")
                           or "--xla_force_host_platform_device_count=512")
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

THE FIRST TWO LINES of this module set XLA_FLAGS before any jax import —
jax locks the device count at first initialization.  Do not move them.

For each cell this driver:
  1. builds the model from its pool config and takes abstract
     ShapeDtypeStruct params/caches (jax.eval_shape — nothing is allocated),
  2. derives the sharding plan (FSDP x TP; FSDP widened across the pod axis
     when the training state would not fit pod-local HBM),
  3. jit-lowers and compiles train_step / prefill_step / decode_step under
     the production mesh,
  4. records memory_analysis / cost_analysis / collective-bytes, applies the
     scan trip-count correction (see repro.roofline.analysis), computes the
     three-term roofline, and appends a JSON record under
     experiments/dryrun/.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
      --mesh both --out experiments/dryrun
"""

import argparse
import dataclasses
import functools
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.common.utils import tree_bytes
from repro.configs import ARCH_NAMES, get_config
from repro.configs.base import ModelConfig
from repro.distributed.sharding import (
    auto_shard_cache,
    auto_shard_params,
    batch_spec,
    estimate_bytes_per_device,
)
from repro.launch.mesh import make_production_mesh
from repro.models import build_model
from repro.models.transformer import DecoderLM
from repro.optim import AdamWConfig, adamw_init, adamw_step
from repro.roofline.analysis import (
    CellMetrics,
    Roofline,
    metrics_from_compiled,
    model_flops,
    total_params,
    active_params,
)

SHAPES = {
    "train_4k": dict(seq_len=4096, global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32768, global_batch=32, kind="prefill"),
    "decode_32k": dict(seq_len=32768, global_batch=128, kind="decode"),
    "long_500k": dict(seq_len=524288, global_batch=1, kind="decode"),
}

# Per-device HBM budget (v5e: 16 GB) used to decide pod-wide FSDP.
HBM_BUDGET = 13e9


def input_specs(cfg: ModelConfig, shape_name: str) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    sh = SHAPES[shape_name]
    s, b = sh["seq_len"], sh["global_batch"]
    i32 = jnp.int32
    if sh["kind"] == "train":
        specs = {
            "tokens": jax.ShapeDtypeStruct((b, s), i32),
            "labels": jax.ShapeDtypeStruct((b, s), i32),
        }
        if cfg.encoder_layers:
            specs["frames"] = jax.ShapeDtypeStruct(
                (b, cfg.encoder_frames, cfg.d_model), jnp.dtype(cfg.dtype))
        return specs
    if sh["kind"] == "prefill":
        return {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
    # decode: one new token against a seq_len-deep cache
    return {
        "tokens": jax.ShapeDtypeStruct((b, 1), i32),
        "pos": jax.ShapeDtypeStruct((), i32),
    }


def _cache_specs(model, cfg, batch, max_seq):
    if cfg.encoder_layers:
        params = model.param_specs()
        frames = jax.ShapeDtypeStruct(
            (batch, cfg.encoder_frames, cfg.d_model), jnp.dtype(cfg.dtype))
        return jax.eval_shape(
            lambda p, f: model.init_cache(p, f, batch, max_seq), params, frames)
    return model.cache_specs(batch, max_seq)


def build_step(model, cfg: ModelConfig, kind: str, opt_cfg: AdamWConfig):
    if kind == "train":
        def train_step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(
                lambda p: model.loss(p, batch)[0])(params)
            params, opt_state, metrics = adamw_step(grads, opt_state, params,
                                                    opt_cfg)
            return params, opt_state, loss
        return train_step
    if kind == "prefill":
        def prefill_step(params, caches, tokens):
            return model.prefill(params, tokens, caches)
        return prefill_step

    def decode_step(params, caches, tokens, pos):
        return model.decode_step(params, caches, tokens, pos)
    return decode_step


def segment_variant_cfgs(cfg: ModelConfig):
    """(depth-1 config, [configs with segment i at depth 2], segment counts).

    Used for the scan trip-count correction.  Layer counts are encoded via
    num_layers + structural fields; we reconstruct reduced configs whose
    plan_segments() yields counts of 1 (and 2 for the probed segment).
    """
    from repro.models.transformer import plan_segments

    segs = plan_segments(cfg) if cfg.encoder_layers == 0 else None
    if cfg.encoder_layers:
        # enc-dec: two "segments" (encoder, decoder scans).
        base = dataclasses.replace(cfg, num_layers=1, encoder_layers=1,
                                   scan_unroll=True)
        v_enc = dataclasses.replace(base, encoder_layers=2)
        v_dec = dataclasses.replace(base, num_layers=2)
        return base, [v_enc, v_dec], [cfg.encoder_layers, cfg.num_layers]

    counts = [s.count for s in segs]

    def rebuild(per_seg_counts):
        """Rebuild a config whose segments have the given counts."""
        total = sum(per_seg_counts)
        kw = dict(num_layers=total, scan_unroll=True)
        if cfg.moe and cfg.moe.first_dense_layers:
            kw["moe"] = dataclasses.replace(
                cfg.moe, first_dense_layers=per_seg_counts[0])
        if cfg.global_layer_indices:
            # segments alternate global(1)/local(k): global layers keep
            # count 1; rebuild indices from the local counts.
            idx = []
            pos = 0
            for seg, c in zip(segs, per_seg_counts):
                if seg.window == 0 and not cfg.moe:
                    idx.append(pos)
                pos += c
            kw["global_layer_indices"] = tuple(idx)
        return dataclasses.replace(cfg, **kw)

    ones = [1] * len(counts)
    base = rebuild(ones)
    variants = []
    for i in range(len(counts)):
        v = list(ones)
        v[i] = 2
        variants.append(rebuild(v))
    return base, variants, counts


def lower_cell(cfg: ModelConfig, shape_name: str, mesh, *,
               opts: frozenset = frozenset()):
    """Lower+compile one cell on ``mesh``; returns the record dict.

    opts: hillclimb optimization switches (EXPERIMENTS.md §Perf):
      serve_replicate — TP-only (no-FSDP) parameter layout for serve cells.
      kv_int8         — int8 quantized KV cache for serve cells.
    """
    sh = SHAPES[shape_name]
    kind = sh["kind"]
    b, s = sh["global_batch"], sh["seq_len"]
    if kind != "train" and "kv_int8" in opts:
        cfg = dataclasses.replace(cfg, kv_cache_dtype="int8")
    model = build_model(cfg)
    pspecs = model.param_specs()

    # FSDP-across-pods decision (training state = params + grads + m/v).
    multi_pod = "pod" in mesh.axis_names
    pbytes = tree_bytes(pspecs)
    train_factor = 6.0 if kind == "train" else 1.0
    data_shards = mesh.shape["data"] * mesh.shape["model"]
    fsdp_over_pod = bool(
        multi_pod and (pbytes * train_factor / data_shards > HBM_BUDGET))
    serve_mode = bool(
        kind != "train" and "serve_replicate" in opts
        and pbytes / mesh.shape["model"] <= HBM_BUDGET)
    plan = auto_shard_params(pspecs, mesh, fsdp_over_pod=fsdp_over_pod,
                             serve_mode=serve_mode)
    p_shard = plan.tree_for(pspecs)

    bspec = batch_spec(b, mesh)
    data_sh = NamedSharding(mesh, bspec)
    rep = NamedSharding(mesh, P())

    opt_cfg = AdamWConfig(lr=1e-4)
    step = build_step(model, cfg, kind, opt_cfg)
    specs = input_specs(cfg, shape_name)

    if kind == "train":
        opt_specs = jax.eval_shape(lambda p: adamw_init(p, opt_cfg), pspecs)
        opt_shard = type(opt_specs)(step=rep, m=p_shard, v=p_shard)
        in_spec_shardings = {
            k: NamedSharding(mesh, bspec) if k != "frames" else data_sh
            for k in specs
        }
        jitted = jax.jit(
            step,
            in_shardings=(p_shard, opt_shard, in_spec_shardings),
            out_shardings=(p_shard, opt_shard, rep),
            donate_argnums=(0, 1),
        )
        args = (pspecs, opt_specs, specs)
    else:
        cspecs = _cache_specs(model, cfg, b, s)
        c_shard = auto_shard_cache(cspecs, b, mesh)
        if kind == "prefill":
            jitted = jax.jit(
                step,
                in_shardings=(p_shard, c_shard, data_sh),
                out_shardings=(data_sh, c_shard),
                donate_argnums=(1,),
            )
            args = (pspecs, cspecs, specs["tokens"])
        else:
            jitted = jax.jit(
                step,
                in_shardings=(p_shard, c_shard, data_sh, rep),
                out_shardings=(data_sh, c_shard),
                donate_argnums=(1,),
            )
            args = (pspecs, cspecs, specs["tokens"], specs["pos"])

    from repro.distributed.context import mesh_context

    t0 = time.time()
    with mesh_context(mesh):
        lowered = jitted.lower(*args)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()

    m = metrics_from_compiled(compiled)
    record = {
        "arch": cfg.name,
        "shape": shape_name,
        "mesh": "x".join(str(v) for v in mesh.devices.shape),
        "axes": list(mesh.axis_names),
        "kind": kind,
        "opts": sorted(opts),
        "serve_mode": serve_mode,
        "fsdp_over_pod": fsdp_over_pod,
        "sharding_fallbacks": plan.fallbacks[:20],
        "lower_s": round(t1 - t0, 2),
        "compile_s": round(t2 - t1, 2),
        "param_bytes_total": pbytes,
        "param_bytes_per_device": estimate_bytes_per_device(pspecs, plan, mesh),
        "memory_analysis": {
            "argument_bytes": m.argument_bytes,
            "output_bytes": m.output_bytes,
            "temp_bytes": m.temp_bytes,
        },
        "raw": {
            "flops_per_device": m.flops,
            "bytes_per_device": m.bytes_accessed,
            "collective_bytes": m.collective,
        },
    }
    return record, m, model


def run_cell(cfg: ModelConfig, shape_name: str, mesh, *, correct: bool = True,
             opts: frozenset = frozenset()):
    record, m_full, _ = lower_cell(cfg, shape_name, mesh, opts=opts)
    sh = SHAPES[shape_name]
    num_chips = int(np.prod(mesh.devices.shape))

    corrected = m_full
    if correct:
        try:
            base_cfg, variant_cfgs, counts = segment_variant_cfgs(cfg)
            _, m_base, _ = lower_cell(base_cfg, shape_name, mesh, opts=opts)
            m_vars = []
            for vc in variant_cfgs:
                _, mv, _ = lower_cell(vc, shape_name, mesh, opts=opts)
                m_vars.append(mv)
            corrected = CellMetrics.accumulate_correction(
                m_full, m_base, m_vars, counts)
            record["correction"] = "per-segment-delta(unrolled)"
        except Exception as e:  # pragma: no cover
            record["correction"] = f"failed: {e}"
    else:
        record["correction"] = "none"

    mf = model_flops(cfg, sh["kind"], sh["seq_len"], sh["global_batch"])
    roof = Roofline.from_metrics(corrected, mf, num_chips)
    record["corrected"] = {
        "flops_per_device": corrected.flops,
        "bytes_per_device": corrected.bytes_accessed,
        "collective_bytes": corrected.collective,
    }
    record["roofline"] = roof.to_dict()
    record["params_total"] = total_params(cfg)
    record["params_active"] = active_params(cfg)
    return record


def cell_list(arch: str, shape: str):
    archs = ARCH_NAMES if arch == "all" else (arch,)
    shapes = tuple(SHAPES) if shape == "all" else (shape,)
    cells = []
    for a in archs:
        cfg = get_config(a)
        for s in shapes:
            skip = None
            if s == "long_500k" and not cfg.subquadratic:
                skip = ("long_500k needs sub-quadratic attention; "
                        f"{a} is full-attention (DESIGN.md skip policy)")
            cells.append((a, s, skip))
    return cells


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--no-correction", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--opt", default="",
                    help="comma-separated optimization switches "
                         "(e.g. serve_replicate)")
    args = ap.parse_args()
    opts = frozenset(o for o in args.opt.split(",") if o)

    os.makedirs(args.out, exist_ok=True)
    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("single_16x16", make_production_mesh(multi_pod=False)))
    if args.mesh in ("multi", "both"):
        meshes.append(("multi_2x16x16", make_production_mesh(multi_pod=True)))

    results = []
    for mesh_name, mesh in meshes:
        for arch, shape, skip in cell_list(args.arch, args.shape):
            out_path = os.path.join(args.out, f"{arch}__{shape}__{mesh_name}.json")
            if os.path.exists(out_path) and not args.force:
                print(f"[skip-cached] {arch} x {shape} x {mesh_name}")
                continue
            if skip:
                rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
                       "skipped": skip}
                with open(out_path, "w") as f:
                    json.dump(rec, f, indent=1)
                print(f"[SKIP] {arch} x {shape}: {skip}")
                continue
            t0 = time.time()
            try:
                rec = run_cell(get_config(arch), shape, mesh,
                               correct=not args.no_correction, opts=opts)
                rec["mesh_name"] = mesh_name
                status = (f"ok ({rec['compile_s']}s compile, "
                          f"bottleneck={rec['roofline']['bottleneck']})")
            except Exception as e:
                rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
                       "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-4000:]}
                status = f"ERROR {type(e).__name__}: {e}"
            with open(out_path, "w") as f:
                json.dump(rec, f, indent=1, default=float)
            print(f"[{time.time() - t0:7.1f}s] {arch} x {shape} x {mesh_name}: "
                  f"{status}", flush=True)
            results.append(rec)
    print(f"done: {len(results)} cells executed")


if __name__ == "__main__":
    main()
