"""Serving launcher: batched prefill + decode for any pool architecture.

A compact continuous-batching server core: requests join a waiting queue;
each engine tick either (a) prefills the next waiting request into a free
cache slot or (b) runs one batched decode step for all active slots.
Finished sequences (EOS or max_tokens) free their slot.  This is the
engine a cluster front-end would wrap with RPC; here it is driven
synthetically.  The paper-side GNN analogue is
``repro.serving.GnnServeEngine`` (slot-based batching over shape-bucketed
blocked forwards), driven by examples/serve_gnn.py.

CLI:
  PYTHONPATH=src python -m repro.launch.serve --arch chatglm3-6b \
      --preset cpu-demo --requests 8 --max-tokens 16
"""

from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.models import build_model


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray
    max_tokens: int
    generated: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    """Slot-based continuous batching over a shared KV cache."""

    def __init__(self, model, params, batch_slots: int, max_seq: int,
                 temperature: float = 0.0, seed: int = 0):
        self.model = model
        self.params = params
        self.slots: list[Optional[Request]] = [None] * batch_slots
        self.positions = np.zeros(batch_slots, np.int32)
        self.max_seq = max_seq
        self.caches = model.init_cache(batch_slots, max_seq)
        self.temperature = temperature
        self.key = jax.random.PRNGKey(seed)
        self.last_token = np.zeros((batch_slots, 1), np.int32)

        self._decode = jax.jit(model.decode_step, donate_argnums=(1,))

    def add_request(self, req: Request) -> bool:
        """Prefill into a free slot.  Single-slot prefill (per-request)."""
        try:
            slot = self.slots.index(None)
        except ValueError:
            return False
        # Per-request prefill via decode steps over the prompt (slot-local,
        # cache-safe for mixed occupancy; bulk prefill is used when the
        # whole batch starts together — see prefill_batch).
        for t, tok in enumerate(req.prompt):
            tok_b = np.zeros((len(self.slots), 1), np.int32)
            tok_b[slot, 0] = tok
            logits, self.caches = self._decode(
                self.params, self.caches, jnp.asarray(tok_b),
                jnp.asarray(t, jnp.int32))
        self.positions[slot] = len(req.prompt)
        self.last_token[slot, 0] = req.prompt[-1]
        self.slots[slot] = req
        return True

    def prefill_batch(self, reqs: list):
        """Bulk prefill when all slots start together (same prompt length)."""
        prompts = np.stack([r.prompt for r in reqs])
        logits, self.caches = jax.jit(self.model.prefill)(
            self.params, jnp.asarray(prompts), self.caches)
        nxt = np.asarray(jnp.argmax(logits[:, -1, :], axis=-1))
        for i, r in enumerate(reqs):
            self.slots[i] = r
            r.generated.append(int(nxt[i]))
            self.positions[i] = prompts.shape[1]
            self.last_token[i, 0] = nxt[i]

    def step(self) -> int:
        """One batched decode step; returns #active slots."""
        active = [i for i, r in enumerate(self.slots) if r is not None]
        if not active:
            return 0
        pos = int(self.positions[active].max())
        logits, self.caches = self._decode(
            self.params, self.caches, jnp.asarray(self.last_token),
            jnp.asarray(pos, jnp.int32))
        logits = np.asarray(logits[:, 0, :])
        for i in active:
            req = self.slots[i]
            if self.temperature > 0:
                self.key, sub = jax.random.split(self.key)
                tok = int(jax.random.categorical(
                    sub, jnp.asarray(logits[i]) / self.temperature))
            else:
                tok = int(logits[i].argmax())
            req.generated.append(tok)
            self.positions[i] += 1
            self.last_token[i, 0] = tok
            if len(req.generated) >= req.max_tokens \
                    or self.positions[i] >= self.max_seq - 1:
                req.done = True
                self.slots[i] = None
        return len(active)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="chatglm3-6b")
    ap.add_argument("--preset", default="cpu-demo")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-tokens", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=64)
    args = ap.parse_args()

    cfg = (get_config(args.arch) if args.preset == "production"
           else get_smoke_config(args.arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    engine = ServeEngine(model, params, batch_slots=args.requests,
                         max_seq=args.max_seq)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size, args.prompt_len)
                    .astype(np.int32), args.max_tokens)
            for i in range(args.requests)]
    t0 = time.time()
    engine.prefill_batch(reqs)
    steps = 0
    while engine.step():
        steps += 1
    dt = time.time() - t0
    total_tokens = sum(len(r.generated) for r in reqs)
    print(f"served {len(reqs)} requests, {total_tokens} tokens in {dt:.2f}s "
          f"({total_tokens / dt:.1f} tok/s), {steps} engine steps")
    for r in reqs[:2]:
        print(f"  req{r.rid}: {r.generated[:10]}...")


if __name__ == "__main__":
    main()
