"""Production mesh definitions.

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module touches no jax device state — required because the
dry-run must set XLA_FLAGS before any jax initialization.

Mesh shapes:
  single-pod  (16, 16)      axes ("data", "model")   — 256 chips
  multi-pod   (2, 16, 16)   axes ("pod", "data", "model") — 512 chips

Axis roles:
  pod    pure data parallelism across pods (gradient all-reduce crosses the
         inter-pod links once per step); optionally joins the FSDP axis for
         models that don't fit pod-local sharding (deepseek-v3 training).
  data   batch parallelism + FSDP (ZeRO-3 parameter/optimizer sharding).
  model  tensor parallelism (heads / d_ff / vocab / experts) and
         KV-cache sequence parallelism when serving.
"""

from __future__ import annotations

import jax


def _auto_axis_types(num_axes: int) -> dict:
    # jax.sharding.AxisType only exists on newer jax; older versions treat
    # every mesh axis as Auto already, so omitting the kwarg is equivalent.
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * num_axes}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_auto_axis_types(len(axes)))


def make_debug_mesh(devices_per_axis: tuple[int, ...] = (2, 2),
                    axes: tuple[str, ...] = ("data", "model")):
    """Small mesh for CPU-host tests (requires matching device count)."""
    return jax.make_mesh(devices_per_axis, axes,
                         **_auto_axis_types(len(axes)))


def data_axes(mesh) -> tuple[str, ...]:
    """Mesh axes usable for batch sharding, largest stride first."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


# The CPU-CI recipe for multi-device testing: XLA splits the host platform
# into N virtual devices.  Must be set BEFORE jax initializes (any
# jax.devices() call pins the count), which is why it is an env-var string
# here rather than a function that sets it.
HOST_DEVICE_RECIPE = 'XLA_FLAGS="--xla_force_host_platform_device_count=8"'


def make_data_mesh(num_devices: int | None = None, axis: str = "data"):
    """1-D serving mesh over the first ``num_devices`` local devices.

    This is the mesh the sharded blocked forward partitions over
    (``core.aggregate.shard_scope`` / ``aggregate_combine_sharded``): one
    named axis, conventionally "data" because destination block-rows and
    feature slices are both batch-like partitions (no tensor-parallel
    collectives beyond the feature strategy's contraction psum).

    Unlike ``make_production_mesh`` this takes a device *count*, so a
    device-scaling sweep can build 1/2/4/8-way meshes from one host
    process (started under ``HOST_DEVICE_RECIPE`` on CPU).
    """
    import numpy as np
    from jax.sharding import Mesh

    devices = jax.devices()
    n = len(devices) if num_devices is None else int(num_devices)
    if n < 1:
        raise ValueError("num_devices must be >= 1")
    if n > len(devices):
        raise ValueError(
            f"asked for {n} devices but only {len(devices)} are visible; "
            f"on CPU hosts start the process with {HOST_DEVICE_RECIPE}")
    return Mesh(np.asarray(devices[:n]), (axis,))
