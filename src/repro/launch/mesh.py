"""Production mesh definitions.

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module touches no jax device state — required because the
dry-run must set XLA_FLAGS before any jax initialization.

Mesh shapes:
  single-pod  (16, 16)      axes ("data", "model")   — 256 chips
  multi-pod   (2, 16, 16)   axes ("pod", "data", "model") — 512 chips

Axis roles:
  pod    pure data parallelism across pods (gradient all-reduce crosses the
         inter-pod links once per step); optionally joins the FSDP axis for
         models that don't fit pod-local sharding (deepseek-v3 training).
  data   batch parallelism + FSDP (ZeRO-3 parameter/optimizer sharding).
  model  tensor parallelism (heads / d_ff / vocab / experts) and
         KV-cache sequence parallelism when serving.
"""

from __future__ import annotations

import jax


def _auto_axis_types(num_axes: int) -> dict:
    # jax.sharding.AxisType only exists on newer jax; older versions treat
    # every mesh axis as Auto already, so omitting the kwarg is equivalent.
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * num_axes}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_auto_axis_types(len(axes)))


def make_debug_mesh(devices_per_axis: tuple[int, ...] = (2, 2),
                    axes: tuple[str, ...] = ("data", "model")):
    """Small mesh for CPU-host tests (requires matching device count)."""
    return jax.make_mesh(devices_per_axis, axes,
                         **_auto_axis_types(len(axes)))


def data_axes(mesh) -> tuple[str, ...]:
    """Mesh axes usable for batch sharding, largest stride first."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
