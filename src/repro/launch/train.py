"""Training launcher: sharded LM pretraining with fault tolerance.

Production path (one process per host on a real cluster; single process
here):

  * config-driven model from the pool (``--arch``), reduced presets for CPU,
  * deterministic resumable data pipeline (repro.data.tokens),
  * AdamW with warmup-cosine, ZeRO-sharded optimizer state,
  * async atomic checkpointing every N steps + auto-resume (--resume auto),
  * optional int8 error-feedback gradient compression (--grad-compress),
  * straggler watchdog hooks (heartbeats; evict triggers elastic replan),
  * elastic restart: restore a checkpoint onto a smaller mesh
    (--elastic-data-axis overrides the data-axis size at restore).

Example (CPU demo, also examples/train_lm.py):
  PYTHONPATH=src python -m repro.launch.train --arch chatglm3-6b \
      --preset cpu-demo --steps 300 --checkpoint-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import get_config, get_smoke_config
from repro.data.tokens import TokenPipeline
from repro.distributed.compression import compress_grads, init_compression
from repro.distributed.context import mesh_context
from repro.distributed.resilience import StragglerWatchdog
from repro.distributed.sharding import auto_shard_params, batch_spec
from repro.launch.mesh import make_production_mesh
from repro.models import build_model
from repro.optim import AdamWConfig, adamw_init, adamw_step
from repro.optim.schedule import warmup_cosine


@dataclasses.dataclass
class TrainConfig:
    arch: str = "chatglm3-6b"
    preset: str = "cpu-demo"          # cpu-demo | smoke | production
    steps: int = 300
    seq_len: int = 128
    global_batch: int = 8
    lr: float = 3e-4
    warmup: int = 50
    checkpoint_dir: str = "/tmp/repro_ckpt"
    checkpoint_every: int = 100
    resume: str = "auto"              # auto | none | <step>
    grad_compress: str = "none"       # none | int8_ef
    seed: int = 0
    log_every: int = 10


def build_train_state(model, cfg_opt: AdamWConfig, seed: int):
    params = model.init(jax.random.PRNGKey(seed))
    opt_state = adamw_init(params, cfg_opt)
    return params, opt_state


def make_step(model, opt_cfg: AdamWConfig, compress: bool):
    def step(params, opt_state, comp_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: model.loss(p, batch)[0])(params)
        if compress:
            grads, comp_state = compress_grads(grads, comp_state)
        params, opt_state, metrics = adamw_step(grads, opt_state, params,
                                                opt_cfg)
        metrics["loss"] = loss
        return params, opt_state, comp_state, metrics

    return jax.jit(step, donate_argnums=(0, 1, 2))


def run(tc: TrainConfig, mesh=None) -> dict:
    if tc.preset == "production":
        cfg = get_config(tc.arch)
    else:
        cfg = get_smoke_config(tc.arch)
    model = build_model(cfg)

    opt_cfg = AdamWConfig(
        lr=warmup_cosine(tc.lr, tc.warmup, tc.steps), weight_decay=0.1)
    params, opt_state = build_train_state(model, opt_cfg, tc.seed)
    comp_state = (init_compression(params)
                  if tc.grad_compress == "int8_ef" else None)

    if mesh is not None:
        plan = auto_shard_params(params, mesh)
        p_shard = plan.tree_for(params)
        params = jax.device_put(params, p_shard)

    pipe = TokenPipeline(cfg.vocab_size, tc.seq_len, tc.global_batch,
                         seed=tc.seed)
    ckpt = Checkpointer(tc.checkpoint_dir)
    watchdog = StragglerWatchdog()
    host = f"host{jax.process_index()}"

    start_step = 0
    if tc.resume != "none":
        target = (ckpt.latest_step() if tc.resume == "auto"
                  else int(tc.resume))
        if target is not None and target in ckpt.available_steps():
            state_tree = {"params": params, "opt": opt_state}
            restored, extra = ckpt.restore(target, state_tree)
            params, opt_state = restored["params"], restored["opt"]
            pipe.load_state_dict(extra["pipeline"])
            start_step = target
            print(f"[resume] restored step {target}")

    step_fn = make_step(model, opt_cfg, tc.grad_compress == "int8_ef")

    history = []
    with mesh_context(mesh):
        for step in range(start_step, tc.steps):
            t0 = time.time()
            batch_np = pipe.next_batch()
            batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
            params, opt_state, comp_state, metrics = step_fn(
                params, opt_state, comp_state, batch)
            loss = float(metrics["loss"])
            dt = time.time() - t0
            watchdog.record(host, dt)
            if step % tc.log_every == 0 or step == tc.steps - 1:
                tok_s = tc.global_batch * tc.seq_len / dt
                print(f"step {step:5d} loss {loss:.4f} "
                      f"{dt * 1e3:7.1f} ms/step {tok_s:9.0f} tok/s", flush=True)
                history.append({"step": step, "loss": loss, "ms": dt * 1e3})
            if (step + 1) % tc.checkpoint_every == 0 or step == tc.steps - 1:
                ckpt.save_async(step + 1, {"params": params, "opt": opt_state},
                                extra={"pipeline": pipe.state_dict()})
    ckpt.wait()
    return {"history": history, "final_loss": history[-1]["loss"] if history else None}


def main():
    ap = argparse.ArgumentParser()
    for f in dataclasses.fields(TrainConfig):
        flag = "--" + f.name.replace("_", "-")
        if f.type is bool or f.type == "bool":
            ap.add_argument(flag, action="store_true")
        else:
            ap.add_argument(flag, type=type(f.default), default=f.default)
    args = ap.parse_args()
    tc = TrainConfig(**{f.name: getattr(args, f.name)
                        for f in dataclasses.fields(TrainConfig)})
    out = run(tc)
    print(json.dumps(out["history"][-3:], indent=1))


if __name__ == "__main__":
    main()
