"""Gradient compression with error feedback (cross-pod traffic reduction).

int8 uniform quantization per-tensor with an fp32 error accumulator
(1-bit/8-bit SGD style error feedback): the quantization residual is carried
into the next step, so compression introduces no bias in the long run —
``decompress(compress(g)) + e_next == g + e_prev`` exactly.

Wire-format accounting: bf16 -> int8 halves the gradient bytes on the pod
axis (the slowest links).  The train step applies
compress -> (SPMD reduction happens on the compressed-then-dequantized
values) -> error update; the bytes saving applies to the cross-pod
all-reduce and is reported in the §Perf log.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class CompressionState(NamedTuple):
    error: Any  # pytree like grads, fp32


def init_compression(grads_like) -> CompressionState:
    return CompressionState(
        error=jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)
    )


def _compress_one(g: jax.Array, e: jax.Array):
    target = g.astype(jnp.float32) + e
    scale = jnp.maximum(jnp.max(jnp.abs(target)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(target / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    new_e = target - deq
    return deq.astype(g.dtype), new_e


def compress_grads(grads, state: CompressionState):
    """Returns (dequantized grads to feed the reduction, new state)."""
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(state.error)
    out = [_compress_one(g, e) for g, e in zip(flat_g, flat_e)]
    new_g = treedef.unflatten([o[0] for o in out])
    new_e = treedef.unflatten([o[1] for o in out])
    return new_g, CompressionState(error=new_e)
