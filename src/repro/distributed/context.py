"""Mesh context for activation sharding constraints.

Model code is mesh-agnostic; the launcher installs the active mesh (and the
activation-partitioning policy) here, and layers call ``constrain`` which
no-ops when no mesh is installed (CPU tests) — so the same model code runs
unsharded on a laptop and sequence-sharded on the production mesh.
"""

from __future__ import annotations

import contextlib
import dataclasses
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass
class ActivationPolicy:
    """Which logical activation dims to shard.

    seq_shard: shard the sequence dim of residual-stream activations over
    the 'model' axis between attention/mlp blocks (sequence parallelism) —
    the norm/elementwise segments then run on 1/TP of the tokens and the
    layer-boundary residual carry shrinks by TP x.
    """

    batch_axes: tuple = ("pod", "data")
    seq_shard: bool = True


_STATE = {"mesh": None, "policy": ActivationPolicy(), "dispatch_groups": 1}


def set_mesh(mesh: Optional[Mesh], policy: Optional[ActivationPolicy] = None):
    _STATE["mesh"] = mesh
    if policy is not None:
        _STATE["policy"] = policy
    # MoE dispatch groups = number of batch shards: routing/capacity become
    # shard-local, so the dispatch scatter never crosses shards (measured
    # TB-scale all-reduces otherwise — EXPERIMENTS.md §Perf iteration 2).
    if mesh is None:
        _STATE["dispatch_groups"] = 1
    else:
        g = 1
        for a in _STATE["policy"].batch_axes:
            if a in mesh.axis_names:
                g *= mesh.shape[a]
        _STATE["dispatch_groups"] = g


def get_mesh() -> Optional[Mesh]:
    return _STATE["mesh"]


def dispatch_groups(num_experts: int | None = None) -> int:
    """MoE dispatch group count.

    Grouped (shard-local) dispatch is only a win when experts are TP'd
    *inside* (E doesn't divide the model axis).  In the EP regime (E on
    'model') the grouped scatter/gather fights the two-axis sharding and
    XLA falls back to full rematerialization — measured 10x collective
    regressions (§Perf deepseek iterations, both refuted) — so EP keeps
    the ungrouped layout.
    """
    mesh = _STATE["mesh"]
    if (num_experts is not None and mesh is not None
            and "model" in mesh.axis_names
            and num_experts % mesh.shape["model"] == 0):
        return 1
    return _STATE["dispatch_groups"]


@contextlib.contextmanager
def mesh_context(mesh: Optional[Mesh], policy: Optional[ActivationPolicy] = None):
    prev = dict(_STATE)
    set_mesh(mesh, policy)
    try:
        yield
    finally:
        _STATE.update(prev)


def constrain_group_axis(x: jax.Array) -> jax.Array:
    """Pin a [G, ...] grouped tensor's leading dim to the batch axes."""
    mesh = _STATE["mesh"]
    if mesh is None:
        return x
    axes = tuple(a for a in _STATE["policy"].batch_axes if a in mesh.axis_names)
    if not axes or x.shape[0] % _axis_prod(mesh, axes):
        return x
    spec = P(axes, *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def constrain_moe_buffers(x: jax.Array) -> jax.Array:
    """Pin [G, E, C, D] MoE dispatch buffers: G on the batch axes, E on
    'model' when the expert count divides it (expert parallelism).  Keeping
    both assignments in ONE constraint is essential: constraining G alone
    fights EP propagation and triggers resharding storms (§Perf, deepseek
    iteration 1 refuted)."""
    mesh = _STATE["mesh"]
    if mesh is None or x.ndim != 4:
        return x
    axes = tuple(a for a in _STATE["policy"].batch_axes if a in mesh.axis_names)
    g_axes = axes if axes and x.shape[0] % _axis_prod(mesh, axes) == 0 else None
    e_axis = ("model" if "model" in mesh.axis_names
              and x.shape[1] % mesh.shape["model"] == 0 else None)
    if g_axes is None and e_axis is None:
        return x
    spec = P(g_axes, e_axis, None, None)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def _axis_prod(mesh, axes) -> int:
    out = 1
    for a in axes:
        out *= mesh.shape[a]
    return out


def _divisible_axes(dim: int, mesh: Mesh, axes) -> Optional[tuple]:
    use = []
    size = 1
    for a in axes:
        if a in mesh.axis_names and dim % (size * mesh.shape[a]) == 0:
            use.append(a)
            size *= mesh.shape[a]
    return tuple(use) if use else None


def constrain_residual(x: jax.Array) -> jax.Array:
    """Shard a residual-stream activation [B, S, D]: batch over data axes,
    sequence over 'model' when the policy enables it."""
    mesh = _STATE["mesh"]
    if mesh is None or x.ndim != 3:
        return x
    policy = _STATE["policy"]
    b_axes = _divisible_axes(x.shape[0], mesh, policy.batch_axes)
    s_axis = None
    if policy.seq_shard and "model" in mesh.axis_names \
            and x.shape[1] % mesh.shape["model"] == 0:
        s_axis = "model"
    spec = P(b_axes, s_axis, None)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
