"""Fault tolerance: straggler watchdog and elastic rescale planning.

Single-host container => the *mechanisms* are real and unit-tested; the
multi-host signals (per-host step heartbeats) arrive through the same
interfaces a cluster launcher would feed.

StragglerWatchdog — detects hosts whose step times are persistent outliers
(median + k*MAD over a sliding window).  The launcher polls ``verdict()``
each step: "ok" / "warn" (log + telemetry) / "evict" (trigger elastic
rescale without the slow host).

ElasticPlan — given a device loss, picks the largest valid (data, model)
mesh that fits the remaining chips (model axis preserved — TP degree is a
compile-time property of the sharded program; the data axis shrinks), and
the checkpoint restore path reshards the state (see
checkpoint.Checkpointer.restore with the new plan's shardings).
"""

from __future__ import annotations

import collections
import dataclasses
import statistics
from typing import Optional


class StragglerWatchdog:
    def __init__(self, window: int = 16, mad_factor: float = 4.0,
                 evict_after: int = 6):
        self.window = window
        self.mad_factor = mad_factor
        self.evict_after = evict_after
        self._times: dict = collections.defaultdict(
            lambda: collections.deque(maxlen=window))
        self._strikes: dict = collections.defaultdict(int)

    def record(self, host: str, step_time: float):
        self._times[host].append(step_time)

    def verdict(self) -> dict:
        """{host: 'ok'|'warn'|'evict'} based on cross-host outlier stats."""
        latest = {h: t[-1] for h, t in self._times.items() if t}
        if len(latest) < 3:
            return {h: "ok" for h in latest}
        med = statistics.median(latest.values())
        mad = statistics.median(abs(v - med) for v in latest.values()) or 1e-9
        out = {}
        for h, v in latest.items():
            if v > med + self.mad_factor * mad and v > 1.2 * med:
                self._strikes[h] += 1
            else:
                self._strikes[h] = 0
            if self._strikes[h] >= self.evict_after:
                out[h] = "evict"
            elif self._strikes[h] > 0:
                out[h] = "warn"
            else:
                out[h] = "ok"
        return out


@dataclasses.dataclass
class ElasticPlan:
    data: int
    model: int

    @property
    def devices(self) -> int:
        return self.data * self.model


def plan_rescale(current_data: int, current_model: int,
                 available_devices: int) -> Optional[ElasticPlan]:
    """Largest (data', model) mesh with data' <= current_data that fits.

    Keeps the model (TP) axis fixed — resharding TP changes per-op partition
    shapes; shrinking the data axis only re-balances batch and FSDP shards,
    which the checkpoint restore path handles.
    """
    if available_devices < current_model:
        return None
    data = min(current_data, available_devices // current_model)
    # data axis must divide the global batch in practice; prefer powers of 2.
    while data > 1 and (current_data % data != 0):
        data -= 1
    if data < 1:
        return None
    return ElasticPlan(data=data, model=current_model)
