"""Sharding rules: parameter/cache pytrees -> NamedSharding trees.

A small rule engine keyed on parameter-path substrings and tensor rank, not
a hand-written spec per architecture: every pool config flows through the
same rules.  The scheme is 2-D sharding (MaxText-style):

  * "model" axis: heads / d_ff / experts / vocab — the TP dimension.
  * "data" (+ optionally "pod") axis: the complementary weight dimension —
    FSDP / ZeRO-3; optimizer state inherits the parameter sharding verbatim
    (see repro.optim.adamw).
  * scan-stacked leading layer dims are never sharded.

``auto_shard_params`` walks the param pytree; each rule sees
(path, ndim, shape) and returns a PartitionSpec.  Divisibility is always
verified — a dimension that doesn't divide falls back to replication on that
axis (recorded, so the dry-run can report imperfect sharding rather than
silently compiling something else).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass
class ShardingPlan:
    shardings: dict          # flat {path: NamedSharding}
    fallbacks: list          # paths where divisibility forced replication
    fsdp_axes: tuple         # axes used for the FSDP dimension
    tp_axis: str

    def tree_for(self, tree):
        """Rebuild a pytree of NamedShardings matching ``tree``."""
        flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
        out = [self.shardings[_path_str(p)] for p, _ in flat]
        return jax.tree.unflatten(treedef, out)


def _path_str(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


def _fits(dim: int, mesh: Mesh, axes) -> bool:
    return dim % _axis_size(mesh, axes) == 0


# Substrings that identify the TP ("model"-sharded) dimension of a weight.
_TP_LAST_DIM = ("wq", "wk", "wv", "w_up", "w_gate", "w_uq", "w_uk", "w_uv",
                "unembed", "router")
_TP_FIRST_DIM = ("wo", "w_down", "w_o", "w_out")
_EXPERT_STACKED = ("w_gate", "w_up", "w_down")  # under a "moe" prefix: [E, ., .]


def spec_for_param(path: str, shape: tuple, mesh: Mesh,
                   fsdp_axes, tp_axis: str) -> tuple[P, bool]:
    """Returns (PartitionSpec, used_fallback)."""
    ndim = len(shape)
    name = path.rsplit("/", 1)[-1]
    in_moe = "/moe/" in path or path.endswith("moe")
    # Scan-stacked params carry a leading layer dim -> never sharded.
    # We detect it structurally: segment params have ndim >= 2 with leading L.
    lead = 1 if "segments/" in path or "encoder/" in path or "decoder/" in path else 0

    def build(dim_assign: dict) -> tuple[P, bool]:
        spec = [None] * ndim
        fell_back = False
        for d, axes in dim_assign.items():
            if axes is None:
                continue
            if _fits(shape[d], mesh, axes):
                spec[d] = axes
            else:
                fell_back = True
        return P(*spec), fell_back

    if ndim - lead == 3 and in_moe and name in _EXPERT_STACKED:
        # Expert-stacked weights [*, E, din, dout].
        e_dim, a, b = lead, lead + 1, lead + 2
        if _fits(shape[e_dim], mesh, tp_axis):
            # EP: experts on the model axis, FSDP over the larger inner dim.
            inner = a if shape[a] >= shape[b] else b
            return build({e_dim: tp_axis, inner: fsdp_axes})
        # Expert count doesn't divide the TP axis (e.g. mixtral's 8 experts
        # on a 16-way axis): replicating experts would replicate the whole
        # MoE FFN compute (measured 25x flops waste — EXPERIMENTS.md §Perf
        # iteration 1).  Instead use TP *inside* each expert: the expert
        # hidden dim goes on 'model', the d_model dim on the FSDP axes.
        hidden_dim = b if name in ("w_gate", "w_up") else a
        other = a if hidden_dim == b else b
        return build({hidden_dim: tp_axis, other: fsdp_axes})

    if ndim - lead == 2:
        a, b = lead, lead + 1
        if name in _TP_LAST_DIM:
            return build({b: tp_axis, a: fsdp_axes})
        if name in _TP_FIRST_DIM:
            return build({a: tp_axis, b: fsdp_axes})
        if name == "embed":
            return build({a: tp_axis, b: fsdp_axes})  # vocab on model
        # Generic matrices (LoRA projections, conv, mixes): FSDP the larger
        # dim, TP the other if it divides.
        big = a if shape[a] >= shape[b] else b
        small = b if big == a else a
        return build({big: fsdp_axes, small: tp_axis})

    if ndim - lead == 1 and shape[lead] >= 1024:
        return build({lead: fsdp_axes})
    # Scalars, small vectors, norm params: replicate.
    return P(), False


def auto_shard_params(param_tree, mesh: Mesh, *, fsdp_over_pod: bool = False,
                      serve_mode: bool = False) -> ShardingPlan:
    """Build NamedShardings for a parameter (or ShapeDtypeStruct) pytree.

    serve_mode: replicate the FSDP dimension (TP-only sharding).  At serving
    there is no optimizer state, so FSDP buys nothing and costs a per-layer
    parameter all-gather on every decode step (measured — EXPERIMENTS.md
    §Perf iteration 3); replication removes it whenever the TP-sharded
    parameters fit HBM.
    """
    tp_axis = "model"
    if serve_mode:
        fsdp_axes = None
    elif fsdp_over_pod and "pod" in mesh.axis_names:
        fsdp_axes: tuple | str = ("pod", "data")
    else:
        fsdp_axes = "data"
    flat, _ = jax.tree_util.tree_flatten_with_path(param_tree)
    shardings = {}
    fallbacks = []
    for path, leaf in flat:
        ps = _path_str(path)
        spec, fb = spec_for_param(ps, tuple(leaf.shape), mesh, fsdp_axes, tp_axis)
        shardings[ps] = NamedSharding(mesh, spec)
        if fb:
            fallbacks.append(ps)
    if fsdp_axes is None:
        fsdp_tuple: tuple = ()
    elif isinstance(fsdp_axes, tuple):
        fsdp_tuple = fsdp_axes
    else:
        fsdp_tuple = (fsdp_axes,)
    return ShardingPlan(shardings=shardings, fallbacks=fallbacks,
                        fsdp_axes=fsdp_tuple, tp_axis=tp_axis)


def batch_spec(batch_size: int, mesh: Mesh) -> P:
    """Shard the batch dim over as many data axes as divide it."""
    axes = [a for a in ("pod", "data") if a in mesh.axis_names]
    use = []
    size = 1
    for a in axes:
        if batch_size % (size * mesh.shape[a]) == 0:
            use.append(a)
            size *= mesh.shape[a]
    return P(tuple(use)) if use else P()


def cache_spec(shape: tuple, batch_size: int, mesh: Mesh, path: str = "") -> P:
    """KV/state cache sharding: batch over data axes; the sequence (buffer)
    dim of K/V tensors over 'model' (sequence-parallel serving).  Falls back
    to replication for non-divisible dims."""
    bspec = batch_spec(batch_size, mesh)
    b_axes = bspec[0] if len(bspec) else None
    spec = [None] * len(shape)
    # Caches are stacked [L, B, S, ...] (layer dim first under vmap/scan).
    if len(shape) >= 3:
        spec[1] = b_axes if (b_axes and _fits(shape[1], mesh, b_axes)) else None
        if len(shape) >= 4 and _fits(shape[2], mesh, "model"):
            spec[2] = "model"
    return P(*spec)


def auto_shard_cache(cache_tree, batch_size: int, mesh: Mesh):
    def one(path, leaf):
        return NamedSharding(
            mesh, cache_spec(tuple(leaf.shape), batch_size, mesh,
                             _path_str(path)))
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_tree)
    return jax.tree.unflatten(treedef, [one(p, l) for p, l in flat])


# ---------------------------------------------------------------------------
# Graph-aware specs: PartitionSpecs for the blocked-GNN containers
# (core.aggregate.BlockedGraph / ShardedBlockedGraph), so the serving path
# can place graph structure with the same machinery that places parameters.
# A ShardedBlockedGraph carries an explicit leading owner dimension — its
# tile/degree leaves split on the data axis; a plain BlockedGraph has no
# owner dimension and is replicated (its sharded execution partitions the
# *feature* operand instead; see core.aggregate.shard_scope).
# ---------------------------------------------------------------------------

# ShardedBlockedGraph array fields whose leading dim is the shard owner.
_OWNER_SPLIT_FIELDS = ("blocks", "block_row", "block_col", "deg")


def blocked_graph_specs(graph, axis: str = "data"):
    """Leaf-name -> PartitionSpec for a (Sharded)BlockedGraph.

    Returns a dict over the container's *array* fields only (the static
    ints are trace constants, not placeable leaves).
    """
    from repro.core.aggregate import BlockedGraph, ShardedBlockedGraph

    if isinstance(graph, ShardedBlockedGraph):
        return {name: P(axis) for name in _OWNER_SPLIT_FIELDS}
    if isinstance(graph, BlockedGraph):
        specs = {"blocks": P(), "block_row": P(), "block_col": P()}
        if graph.deg is not None:
            specs["deg"] = P()
        return specs
    raise TypeError(f"expected BlockedGraph or ShardedBlockedGraph, "
                    f"got {type(graph).__name__}")


def blocked_graph_shardings(graph, mesh: Mesh, axis: str = "data") -> dict:
    """Leaf-name -> NamedSharding for a (Sharded)BlockedGraph on ``mesh``."""
    return {name: NamedSharding(mesh, spec)
            for name, spec in blocked_graph_specs(graph, axis).items()}


def estimate_graph_bytes_per_device(graph, num_shards: int = 1) -> float:
    """Structure bytes each device holds under the graph's natural specs.

    Owner-split leaves of a ShardedBlockedGraph divide by the shard count
    (their leading dim is the owner dim); everything else is replicated.
    A plain BlockedGraph replicates wholesale regardless of ``num_shards``.
    """
    from repro.core.aggregate import ShardedBlockedGraph

    if num_shards < 1:
        raise ValueError("num_shards must be >= 1")
    split = isinstance(graph, ShardedBlockedGraph)
    total = 0.0
    for name in _OWNER_SPLIT_FIELDS:
        leaf = getattr(graph, name, None)
        if leaf is None:
            continue
        nbytes = float(np.prod(leaf.shape)) * jax.numpy.dtype(leaf.dtype).itemsize
        total += nbytes / (num_shards if split else 1)
    return total


def estimate_bytes_per_device(tree, plan: ShardingPlan, mesh: Mesh,
                              optimizer_multiplier: float = 0.0) -> float:
    """Parameter bytes per device under the plan (+ optional optimizer
    overhead expressed as a multiple of fp32 param bytes)."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    total = 0.0
    for path, leaf in flat:
        sh = plan.shardings[_path_str(path)]
        n_shards = 1
        for d, axes in enumerate(sh.spec):
            if axes is None:
                continue
            n_shards *= _axis_size(mesh, axes)
        elems = int(np.prod(leaf.shape))
        itemsize = jax.numpy.dtype(leaf.dtype).itemsize
        total += elems * itemsize / n_shards
        if optimizer_multiplier:
            total += elems * 4 * optimizer_multiplier / n_shards
    return total
