"""Deterministic synthetic LM token pipeline (sharded, resumable).

Offline container => no real corpora.  The stream is a seeded order-2
Markov chain over the vocabulary (so models have actual structure to learn,
unlike uniform noise), generated host-side in numpy.  Determinism contract:
batch(step) depends only on (seed, step, global_batch, seq_len) — restarts
resume exactly, and any host can regenerate any shard (no data-server
state), which is what makes checkpoint-restart and elastic rescaling exact.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class TokenPipeline:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    step: int = 0             # resumable cursor (checkpointed)

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        # Sparse-ish Markov structure: each state prefers a few successors.
        self._fanout = 32
        self._succ = rng.integers(
            0, self.vocab_size, size=(min(self.vocab_size, 4096), self._fanout)
        ).astype(np.int32)

    def state_dict(self) -> dict:
        return {"seed": self.seed, "step": self.step}

    def load_state_dict(self, d: dict):
        assert d["seed"] == self.seed, "pipeline seed mismatch on restore"
        self.step = int(d["step"])

    def _gen(self, step: int) -> np.ndarray:
        rng = np.random.default_rng((self.seed, step))
        b, s = self.global_batch, self.seq_len
        toks = np.empty((b, s + 1), np.int32)
        toks[:, 0] = rng.integers(0, self.vocab_size, b)
        states = toks[:, 0] % self._succ.shape[0]
        for t in range(1, s + 1):
            choice = rng.integers(0, self._fanout, b)
            nxt = self._succ[states, choice]
            # occasional jumps keep the chain aperiodic
            jump = rng.random(b) < 0.05
            nxt = np.where(jump, rng.integers(0, self.vocab_size, b), nxt)
            toks[:, t] = nxt
            states = nxt % self._succ.shape[0]
        return toks

    def next_batch(self) -> dict:
        toks = self._gen(self.step)
        self.step += 1
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def batch_at(self, step: int) -> dict:
        toks = self._gen(step)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
