"""Synthetic graph datasets matching the paper's Table 2 statistics.

The container is offline (no Planetoid/TU downloads), so we generate
synthetic datasets whose *structural statistics* match Table 2 exactly —
node/edge/feature/label/graph counts — and whose tasks are genuinely
learnable, so the fp32-vs-int8 accuracy comparison (Table 3) is meaningful:

* Node classification (Cora / PubMed / Citeseer / Amazon): degree-corrected
  stochastic block model with #labels communities and power-law degree
  propensities (citation-graph-like skew), planted class-indicative sparse
  features + noise.
* Graph classification (Proteins / Mutag / BZR / IMDB-binary): two structural
  families per dataset (ring-of-cliques vs. preferential-attachment trees)
  with class-conditional feature means.

All generation is seeded and deterministic.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core.graph import Graph

# Table 2 of the paper.
TABLE2 = {
    "Cora":        dict(nodes=2708, edges=10556, features=1433, labels=7, graphs=1),
    "PubMed":      dict(nodes=19717, edges=88651, features=500, labels=3, graphs=1),
    "Citeseer":    dict(nodes=3327, edges=9104, features=3703, labels=6, graphs=1),
    "Amazon":      dict(nodes=7650, edges=238162, features=745, labels=8, graphs=1),
    "Proteins":    dict(nodes=39, edges=73, features=3, labels=2, graphs=1113),
    "Mutag":       dict(nodes=18, edges=40, features=143, labels=2, graphs=188),
    "BZR":         dict(nodes=34, edges=38, features=189, labels=2, graphs=405),
    "IMDB-binary": dict(nodes=20, edges=193, features=136, labels=2, graphs=1000),
}

NODE_CLASSIFICATION = ("Cora", "PubMed", "Citeseer", "Amazon")
GRAPH_CLASSIFICATION = ("Proteins", "Mutag", "BZR", "IMDB-binary")


def _planted_features(rng, labels, num_features, signal=1.0, noise=1.0,
                      sparsity=0.05):
    """Sparse class-prototype features + Gaussian noise (bag-of-words-like)."""
    num_classes = labels.max() + 1
    proto = (rng.random((num_classes, num_features)) < sparsity).astype(np.float32)
    feat = signal * proto[labels]
    feat += noise * rng.standard_normal(feat.shape).astype(np.float32) * 0.3
    # Word-count-like nonnegativity, matching the citation datasets.
    return np.maximum(feat, 0.0)


def _dc_sbm_edges(rng, labels, num_edges, p_in=0.85):
    """Degree-corrected SBM: sample directed edge endpoints until we have
    ``num_edges`` unique non-self edges; intra-class with prob p_in."""
    n = len(labels)
    num_classes = labels.max() + 1
    # Power-law degree propensity (citation skew).
    theta = rng.pareto(2.5, size=n) + 1.0
    by_class = [np.where(labels == c)[0] for c in range(num_classes)]
    probs = [theta[idx] / theta[idx].sum() for idx in by_class]
    theta_all = theta / theta.sum()

    edges = set()
    batch = max(num_edges, 1024)
    while len(edges) < num_edges:
        src = rng.choice(n, size=batch, p=theta_all)
        intra = rng.random(batch) < p_in
        dst = np.empty(batch, dtype=np.int64)
        for c in range(num_classes):
            m = intra & (labels[src] == c)
            if m.any():
                dst[m] = rng.choice(by_class[c], size=int(m.sum()), p=probs[c])
        m = ~intra
        if m.any():
            dst[m] = rng.choice(n, size=int(m.sum()), p=theta_all)
        for s, d in zip(src.tolist(), dst.tolist()):
            if s != d:
                edges.add((s, d))
                if len(edges) >= num_edges:
                    break
    arr = np.array(sorted(edges), dtype=np.int32)
    return arr[:, 0], arr[:, 1]


def make_node_classification(name: str, seed: int = 0) -> Graph:
    spec = TABLE2[name]
    rng = np.random.default_rng(seed + hash(name) % 65536)
    n, e = spec["nodes"], spec["edges"]
    labels = rng.integers(0, spec["labels"], size=n).astype(np.int32)
    src, dst = _dc_sbm_edges(rng, labels, e)
    feat = _planted_features(rng, labels, spec["features"])

    idx = rng.permutation(n)
    n_train = max(int(0.6 * n), spec["labels"] * 20)
    n_val = int(0.2 * n)
    train_mask = np.zeros(n, bool); train_mask[idx[:n_train]] = True
    val_mask = np.zeros(n, bool); val_mask[idx[n_train:n_train + n_val]] = True
    test_mask = np.zeros(n, bool); test_mask[idx[n_train + n_val:]] = True

    return Graph(
        edge_src=src, edge_dst=dst, node_feat=feat, labels=labels,
        train_mask=train_mask, val_mask=val_mask, test_mask=test_mask,
        name=name,
    ).validate()


def _ring_of_cliques(rng, n):
    """Class-0 structure: small cliques chained in a ring (high clustering)."""
    edges = set()
    k = max(3, n // 6)
    for start in range(0, n - k + 1, k):
        members = range(start, min(start + k, n))
        for a in members:
            for b in members:
                if a < b:
                    edges.add((a, b))
    for i in range(n):
        edges.add((i, (i + 1) % n))
    return edges


def _pa_tree(rng, n, extra=2):
    """Class-1 structure: preferential-attachment tree + a few chords (low
    clustering, skewed degrees)."""
    edges = set()
    targets = [0]
    for i in range(1, n):
        j = int(rng.choice(targets))
        edges.add((min(i, j), max(i, j)))
        targets += [i, j]
    for _ in range(extra):
        a, b = rng.integers(0, n, 2)
        if a != b:
            edges.add((min(a, b), max(a, b)))
    return edges


def make_graph_classification(name: str, seed: int = 0,
                              num_graphs: int | None = None) -> list[Graph]:
    spec = TABLE2[name]
    rng = np.random.default_rng(seed + hash(name) % 65536)
    count = num_graphs or spec["graphs"]
    avg_n, avg_e, f = spec["nodes"], spec["edges"], spec["features"]
    graphs = []
    for gi in range(count):
        label = gi % 2
        n = max(4, int(rng.normal(avg_n, max(avg_n * 0.15, 1))))
        und = _ring_of_cliques(rng, n) if label == 0 else _pa_tree(rng, n)
        und = list(und)
        rng.shuffle(und)
        # Trim/keep to track the average undirected edge count.
        target_und = max(n - 1, int(rng.normal(avg_e, max(avg_e * 0.1, 1))) // 2)
        und = und[:max(target_und, n // 2)]
        src = np.array([a for a, b in und] + [b for a, b in und], np.int32)
        dst = np.array([b for a, b in und] + [a for a, b in und], np.int32)
        base = rng.standard_normal((n, f)).astype(np.float32) * 0.5
        base += (0.6 if label == 1 else -0.6) * np.linspace(1, 0, f, dtype=np.float32)
        deg = np.zeros(n, np.float32)
        np.add.at(deg, dst, 1.0)
        base[:, 0] = deg / max(deg.max(), 1.0)  # degree feature helps both classes
        graphs.append(Graph(
            edge_src=src, edge_dst=dst, node_feat=base,
            graph_label=label, name=f"{name}[{gi}]",
        ).validate())
    return graphs


def load(name: str, seed: int = 0, num_graphs: int | None = None):
    """Load a synthetic Table-2 dataset by name.

    Node-classification names return a single Graph; graph-classification
    names return a list of Graphs.
    """
    if name in NODE_CLASSIFICATION:
        return make_node_classification(name, seed)
    if name in GRAPH_CLASSIFICATION:
        return make_graph_classification(name, seed, num_graphs)
    raise KeyError(f"unknown dataset '{name}'; options: {sorted(TABLE2)}")
