"""GNN layers built on the GReTA decomposition (gather/reduce/transform/activate).

Each conv exposes the two execution backends:

  apply         — edge-list backend (training / oracle)
  apply_blocked — GHOST V x N blocked backend (serving; numerically equal)

and an optional quantized combine (the photonic 8-bit sign-split MVM).

Every ``apply_blocked`` aggregate+combine pair routes through
``core.aggregate.aggregate_combine_blocked``: the static order planner
picks aggregate-first vs combine-first per layer, and the ``pallas_fused``
serving backend lowers the aggregate-first order onto the fused SpMM+combine
epilogue kernel.  GAT is transform-first by construction; its projection is
the same ``dense_combine`` map the planner's combine-first leg uses.
Quantized combines stay on the unfused aggregate-then-int8-MVM path — the
sign-split quantizer is nonlinear, so reordering around it would change the
served numerics.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.aggregate import (
    BlockedGraph,
    ReduceOp,
    active_aggregate_backend,
    aggregate_blocked,
    aggregate_combine_blocked,
    aggregate_edges,
    attention_aggregate_blocked,
    dense_combine,
)
def init_linear(key, f_in: int, f_out: int, bias: bool = True) -> dict:
    wkey, _ = jax.random.split(key)
    scale = (2.0 / (f_in + f_out)) ** 0.5
    p = {"w": scale * jax.random.normal(wkey, (f_in, f_out), jnp.float32)}
    if bias:
        p["b"] = jnp.zeros((f_out,), jnp.float32)
    return p


def _linear(x, p, quantized: bool):
    return dense_combine(x, p["w"], p.get("b"), quantized=quantized)


def _to_dst_rows(x, pad_dst: int):
    """Pad-or-slice a source-padded [G_src*N, ...] array to [G_dst*V, ...]."""
    need = pad_dst - x.shape[0]
    if need > 0:
        x = jnp.pad(x, ((0, need),) + ((0, 0),) * (x.ndim - 1))
    return x[:pad_dst]


# ---------------------------------------------------------------------------
# GCN (Kipf & Welling) — aggregate(sum, Â) -> transform -> activate.
# ---------------------------------------------------------------------------


class GCNConv:
    @staticmethod
    def init(key, f_in, f_out):
        return init_linear(key, f_in, f_out)

    @staticmethod
    def apply(p, feat, edge_src, edge_dst, edge_weight, num_nodes,
              quantized=False):
        h = aggregate_edges(edge_src, edge_dst, feat, num_nodes,
                            ReduceOp.SUM, edge_weight)
        return _linear(h, p, quantized)

    @staticmethod
    def apply_blocked(p, bg: BlockedGraph, feat_padded, quantized=False):
        # GCN normalization is baked into the partition blocks; the whole
        # layer is one planner-ordered (and optionally fused) stage pair.
        return aggregate_combine_blocked(
            bg, feat_padded, p["w"], p.get("b"), reduce=ReduceOp.SUM,
            quantized=quantized)


# ---------------------------------------------------------------------------
# GraphSAGE (mean) — h' = W_self h + W_neigh mean(h_u).
# ---------------------------------------------------------------------------


class SAGEConv:
    @staticmethod
    def init(key, f_in, f_out):
        k1, k2 = jax.random.split(key)
        return {"self": init_linear(k1, f_in, f_out),
                "neigh": init_linear(k2, f_in, f_out, bias=False)}

    @staticmethod
    def apply(p, feat, edge_src, edge_dst, edge_weight, num_nodes,
              quantized=False):
        h = aggregate_edges(edge_src, edge_dst, feat, num_nodes, ReduceOp.MEAN)
        return _linear(feat, p["self"], quantized) + _linear(h, p["neigh"], quantized)

    @staticmethod
    def apply_blocked(p, bg: BlockedGraph, feat_padded, quantized=False):
        # Neighbor term = MEAN-aggregate fused with the (bias-free) W_neigh
        # combine; the self term stays a plain dense map on its own rows.
        h = aggregate_combine_blocked(
            bg, feat_padded, p["neigh"]["w"], reduce=ReduceOp.MEAN,
            quantized=quantized)
        self_feat = _to_dst_rows(feat_padded, bg.num_dst_groups * bg.v)
        return _linear(self_feat, p["self"], quantized) + h


# ---------------------------------------------------------------------------
# GIN — h' = MLP((1 + eps) h + sum(h_u)).
# ---------------------------------------------------------------------------


class GINConv:
    @staticmethod
    def init(key, f_in, f_out, mlp_layers=4, hidden=None):
        hidden = hidden or f_out
        keys = jax.random.split(key, mlp_layers)
        dims = [f_in] + [hidden] * (mlp_layers - 1) + [f_out]
        mlp = [init_linear(k, dims[i], dims[i + 1]) for i, k in enumerate(keys)]
        return {"eps": jnp.zeros(()), "mlp": mlp}

    @staticmethod
    def _mlp(p, x, quantized):
        for i, layer in enumerate(p["mlp"]):
            x = _linear(x, layer, quantized)
            if i + 1 < len(p["mlp"]):
                x = jax.nn.relu(x)
        return x

    @staticmethod
    def apply(p, feat, edge_src, edge_dst, edge_weight, num_nodes,
              quantized=False):
        h = aggregate_edges(edge_src, edge_dst, feat, num_nodes, ReduceOp.SUM)
        return GINConv._mlp(p, (1.0 + p["eps"]) * feat + h, quantized)

    @staticmethod
    def apply_blocked(p, bg: BlockedGraph, feat_padded, quantized=False):
        self_feat = _to_dst_rows(feat_padded, bg.num_dst_groups * bg.v)
        if quantized or active_aggregate_backend() != "pallas_fused":
            # Unfused form.  Quantized: the int8 MVM quantizes its input, so
            # W1 cannot be distributed over the (self, aggregate) sum
            # without changing numerics.  jnp/pallas: distributing W1 buys
            # nothing without the fused epilogue, and keeping the seed
            # association preserves the engine's batched-vs-unbatched
            # bit-exactness for GIN's magnitude-amplifying sum-pool readout.
            h = aggregate_blocked(bg, feat_padded, ReduceOp.SUM)
            return GINConv._mlp(p, (1.0 + p["eps"]) * self_feat + h, quantized)
        # Distribute the first MLP layer over the sum so its combine fuses
        # with the aggregation:  ((1+eps)x + h) W1 + b1
        #                     == (1+eps)(x W1) + (h W1) + b1.
        mlp0 = p["mlp"][0]
        h_w = aggregate_combine_blocked(bg, feat_padded, mlp0["w"],
                                        reduce=ReduceOp.SUM)
        x = (1.0 + p["eps"]) * (self_feat @ mlp0["w"]) + h_w
        if "b" in mlp0:
            x = x + mlp0["b"]
        for layer in p["mlp"][1:]:
            x = _linear(jax.nn.relu(x), layer, quantized)
        return x


# ---------------------------------------------------------------------------
# GAT — transform-first: e_uv = leaky_relu(a . [W h_v || W h_u]), softmax,
# weighted sum.  Multi-head with concat (hidden layers) or mean (output).
# ---------------------------------------------------------------------------


class GATConv:
    @staticmethod
    def init(key, f_in, f_out, heads=1):
        k1, k2, k3 = jax.random.split(key, 3)
        scale = (2.0 / (f_in + f_out)) ** 0.5
        return {
            "w": scale * jax.random.normal(k1, (f_in, heads, f_out)),
            "a_src": 0.1 * jax.random.normal(k2, (heads, f_out)),
            "a_dst": 0.1 * jax.random.normal(k3, (heads, f_out)),
            "b": jnp.zeros((heads, f_out)),
        }

    @staticmethod
    def _project(p, feat, quantized):
        # GAT is transform-first (paper Section 3.4.2): the projection IS
        # the combine-first order, so it runs through the shared combine map
        # rather than a private matmul.
        heads, f_out = p["a_src"].shape
        w2d = p["w"].reshape(feat.shape[-1], heads * f_out)
        wh = dense_combine(feat, w2d, quantized=quantized)
        return wh.reshape(feat.shape[0], heads, f_out)

    @staticmethod
    def apply(p, feat, edge_src, edge_dst, edge_weight, num_nodes,
              quantized=False, concat=True, negative_slope=0.2):
        wh = GATConv._project(p, feat, quantized)                # [N,H,F]
        s_src = (wh * p["a_src"]).sum(-1)                        # [N,H]
        s_dst = (wh * p["a_dst"]).sum(-1)
        logits = jax.nn.leaky_relu(
            s_dst[edge_dst] + s_src[edge_src], negative_slope
        )                                                        # [E,H]
        m = jax.ops.segment_max(logits, edge_dst, num_segments=num_nodes)
        m = jnp.where(jnp.isfinite(m), m, 0.0)
        z = jnp.exp(logits - m[edge_dst])
        denom = jax.ops.segment_sum(z, edge_dst, num_segments=num_nodes)
        alpha = z / jnp.maximum(denom[edge_dst], 1e-30)          # [E,H]
        msgs = alpha[..., None] * wh[edge_src]                   # [E,H,F]
        out = jax.ops.segment_sum(msgs, edge_dst, num_segments=num_nodes)
        out = out + p["b"]
        if concat:
            return out.reshape(num_nodes, -1)
        return out.mean(axis=1)

    @staticmethod
    def apply_blocked(p, bg: BlockedGraph, feat_padded, quantized=False,
                      concat=True, negative_slope=0.2):
        wh = GATConv._project(p, feat_padded, quantized)         # [Npad,H,F]
        s_src = (wh * p["a_src"]).sum(-1)
        s_dst = (wh * p["a_dst"]).sum(-1)
        pad_dst = bg.num_dst_groups * bg.v
        out = attention_aggregate_blocked(
            bg, wh, s_src, _to_dst_rows(s_dst, pad_dst), negative_slope
        )                                                        # [pad_dst,H,F]
        out = out + p["b"]
        if concat:
            return out.reshape(pad_dst, -1)
        return out.mean(axis=1)
