"""The four GNN models of the paper (Section 4.1).

  GCN        2 layers                       (node classification)
  GraphSAGE  2 layers, mean aggregation     (node classification)
  GAT        2 layers, 8 heads then 1 head  (node classification)
  GIN        2 convs x 4-layer MLPs = 8 MLP layers + sum-pool readout
             (graph classification)

Every model supports:
  init(key)                         parameter pytree
  apply(params, *edge arrays)       edge-list backend (training/oracle)
  apply_blocked(params, bg, featp)  GHOST blocked backend (serving)
and a `quantized=` flag that routes every combine through the photonic 8-bit
sign-split MVM.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregate import BlockedGraph
from repro.gnn.layers import GATConv, GCNConv, GINConv, SAGEConv


@dataclasses.dataclass
class GCN:
    f_in: int
    num_classes: int
    hidden: int = 64

    def init(self, key):
        k1, k2 = jax.random.split(key)
        return {"l1": GCNConv.init(k1, self.f_in, self.hidden),
                "l2": GCNConv.init(k2, self.hidden, self.num_classes)}

    def apply(self, p, feat, edge_src, edge_dst, edge_weight, num_nodes,
              quantized=False):
        h = GCNConv.apply(p["l1"], feat, edge_src, edge_dst, edge_weight,
                          num_nodes, quantized)
        h = jax.nn.relu(h)
        return GCNConv.apply(p["l2"], h, edge_src, edge_dst, edge_weight,
                             num_nodes, quantized)

    def apply_blocked(self, p, bg: BlockedGraph, feat_padded, quantized=False):
        h = jax.nn.relu(GCNConv.apply_blocked(p["l1"], bg, feat_padded, quantized))
        h = _redistribute(h, bg)
        return GCNConv.apply_blocked(p["l2"], bg, h, quantized)


@dataclasses.dataclass
class GraphSAGE:
    f_in: int
    num_classes: int
    hidden: int = 64

    def init(self, key):
        k1, k2 = jax.random.split(key)
        return {"l1": SAGEConv.init(k1, self.f_in, self.hidden),
                "l2": SAGEConv.init(k2, self.hidden, self.num_classes)}

    def apply(self, p, feat, edge_src, edge_dst, edge_weight, num_nodes,
              quantized=False):
        h = SAGEConv.apply(p["l1"], feat, edge_src, edge_dst, None,
                           num_nodes, quantized)
        h = jax.nn.relu(h)
        return SAGEConv.apply(p["l2"], h, edge_src, edge_dst, None,
                              num_nodes, quantized)

    def apply_blocked(self, p, bg, feat_padded, quantized=False):
        h = jax.nn.relu(SAGEConv.apply_blocked(p["l1"], bg, feat_padded, quantized))
        h = _redistribute(h, bg)
        return SAGEConv.apply_blocked(p["l2"], bg, h, quantized)


@dataclasses.dataclass
class GAT:
    f_in: int
    num_classes: int
    hidden: int = 8
    heads: int = 8

    def init(self, key):
        k1, k2 = jax.random.split(key)
        return {
            "l1": GATConv.init(k1, self.f_in, self.hidden, self.heads),
            "l2": GATConv.init(k2, self.hidden * self.heads, self.num_classes, 1),
        }

    def apply(self, p, feat, edge_src, edge_dst, edge_weight, num_nodes,
              quantized=False):
        h = GATConv.apply(p["l1"], feat, edge_src, edge_dst, None, num_nodes,
                          quantized, concat=True)
        h = jax.nn.elu(h)
        return GATConv.apply(p["l2"], h, edge_src, edge_dst, None, num_nodes,
                             quantized, concat=False)

    def apply_blocked(self, p, bg, feat_padded, quantized=False):
        h = jax.nn.elu(GATConv.apply_blocked(p["l1"], bg, feat_padded,
                                             quantized, concat=True))
        h = _redistribute(h, bg)
        return GATConv.apply_blocked(p["l2"], bg, h, quantized, concat=False)


@dataclasses.dataclass
class GIN:
    """2 GIN convs, each with a 4-layer MLP (8 MLP layers total, per the
    paper's 'MLP in GIN was implemented with eight layers'), sum-pool
    readout + linear classifier for graph classification."""

    f_in: int
    num_classes: int
    hidden: int = 32
    mlp_layers: int = 4

    def init(self, key):
        k1, k2, k3 = jax.random.split(key, 3)
        from repro.gnn.layers import init_linear
        return {
            "l1": GINConv.init(k1, self.f_in, self.hidden, self.mlp_layers),
            "l2": GINConv.init(k2, self.hidden, self.hidden, self.mlp_layers),
            "out": init_linear(k3, self.hidden, self.num_classes),
        }

    def node_embed(self, p, feat, edge_src, edge_dst, edge_weight, num_nodes,
                   quantized=False):
        h = GINConv.apply(p["l1"], feat, edge_src, edge_dst, None, num_nodes,
                          quantized)
        h = jax.nn.relu(h)
        return GINConv.apply(p["l2"], h, edge_src, edge_dst, None, num_nodes,
                             quantized)

    def apply(self, p, feat, edge_src, edge_dst, edge_weight, num_nodes,
              quantized=False, node_mask=None):
        """Graph-level logits: sum-pool over (valid) nodes, then classify."""
        h = self.node_embed(p, feat, edge_src, edge_dst, edge_weight,
                            num_nodes, quantized)
        if node_mask is not None:
            h = h * node_mask[:, None]
        pooled = h.sum(axis=0)
        return pooled @ p["out"]["w"] + p["out"]["b"]

    def node_embed_blocked(self, p, bg, feat_padded, quantized=False):
        """Blocked node embeddings [G_dst*V, hidden] (pre-readout)."""
        h = jax.nn.relu(GINConv.apply_blocked(p["l1"], bg, feat_padded, quantized))
        h = _redistribute(h, bg)
        return GINConv.apply_blocked(p["l2"], bg, h, quantized)

    def readout(self, p, h_nodes, node_mask=None):
        """Sum-pool valid node embeddings [Nv, hidden] -> class logits.

        Kept separate from the blocked forward so a serving engine can run
        the shape-bucketed embedding batch-wide and the readout per request
        at its true node count (the fp32 sum's value depends on row count).
        """
        if node_mask is not None:
            h_nodes = h_nodes * node_mask[: h_nodes.shape[0], None]
        pooled = h_nodes.sum(axis=0)
        return pooled @ p["out"]["w"] + p["out"]["b"]

    def apply_blocked(self, p, bg, feat_padded, quantized=False,
                      node_mask=None):
        h = self.node_embed_blocked(p, bg, feat_padded, quantized)
        return self.readout(p, h[:bg.num_nodes], node_mask)


def _redistribute(h_dst: jax.Array, bg: BlockedGraph) -> jax.Array:
    """Re-pad a destination-side activation [G_dst*V, F] to the source-side
    padding [G_src*N, F] for the next layer's tile loads."""
    pad_src = bg.num_src_groups * bg.n
    valid = h_dst[:bg.num_nodes]
    need = pad_src - valid.shape[0]
    return jnp.pad(valid, ((0, need), (0, 0)))


def build_model(name: str, f_in: int, num_classes: int, **kw):
    name = name.lower()
    if name == "gcn":
        return GCN(f_in, num_classes, **kw)
    if name in ("graphsage", "sage", "gs"):
        return GraphSAGE(f_in, num_classes, **kw)
    if name == "gat":
        return GAT(f_in, num_classes, **kw)
    if name == "gin":
        return GIN(f_in, num_classes, **kw)
    raise KeyError(f"unknown GNN model '{name}'")
