from repro.gnn.datasets import (
    GRAPH_CLASSIFICATION,
    NODE_CLASSIFICATION,
    TABLE2,
    load,
)
from repro.gnn.models import GAT, GCN, GIN, GraphSAGE, build_model
from repro.gnn.train import (
    eval_graph_classifier,
    eval_node_classifier,
    node_graph_arrays,
    train_graph_classifier,
    train_node_classifier,
)
