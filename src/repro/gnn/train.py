"""GNN training & evaluation loops (full-batch node classification and
padded-batch graph classification), used to reproduce Table 3.

The paper trains with PyTorch Geometric; training here is our own JAX
implementation with the shared AdamW optimizer.  Only post-training
quantization is required for Table 3 (8-bit vs 32-bit accuracy parity).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import Graph
from repro.optim import AdamWConfig, adamw_init, adamw_step


def node_graph_arrays(graph: Graph, add_self_loops: bool = True):
    """(feat, edge_src, edge_dst, gcn_weight, num_nodes, labels, masks)."""
    g = graph.with_self_loops() if add_self_loops else graph
    return dict(
        feat=jnp.asarray(g.node_feat),
        edge_src=jnp.asarray(g.edge_src),
        edge_dst=jnp.asarray(g.edge_dst),
        edge_weight=jnp.asarray(g.gcn_edge_weights()),
        num_nodes=g.num_nodes,
        labels=jnp.asarray(graph.labels),
        train_mask=jnp.asarray(graph.train_mask),
        val_mask=jnp.asarray(graph.val_mask),
        test_mask=jnp.asarray(graph.test_mask),
        graph=g,
    )


def _masked_xent(logits, labels, mask):
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    m = mask.astype(jnp.float32)
    return (nll * m).sum() / jnp.maximum(m.sum(), 1.0)


def train_node_classifier(
    model,
    graph: Graph,
    steps: int = 200,
    lr: float = 0.01,
    weight_decay: float = 5e-4,
    seed: int = 0,
    verbose: bool = False,
):
    """Full-batch training; returns (params, history)."""
    arrs = node_graph_arrays(graph)
    params = model.init(jax.random.PRNGKey(seed))
    cfg = AdamWConfig(lr=lr, weight_decay=weight_decay, b2=0.999)
    state = adamw_init(params, cfg)

    def loss_fn(p):
        logits = model.apply(p, arrs["feat"], arrs["edge_src"],
                             arrs["edge_dst"], arrs["edge_weight"],
                             arrs["num_nodes"])
        return _masked_xent(logits, arrs["labels"], arrs["train_mask"])

    @jax.jit
    def step(p, s):
        loss, grads = jax.value_and_grad(loss_fn)(p)
        p2, s2, _ = adamw_step(grads, s, p, cfg)
        return p2, s2, loss

    history = []
    for i in range(steps):
        params, state, loss = step(params, state)
        if verbose and (i % 50 == 0 or i == steps - 1):
            acc = eval_node_classifier(model, params, graph, "val_mask")
            history.append({"step": i, "loss": float(loss), "val_acc": acc})
    return params, history


def eval_node_classifier(model, params, graph: Graph, mask_name="test_mask",
                         quantized=False) -> float:
    arrs = node_graph_arrays(graph)
    logits = model.apply(params, arrs["feat"], arrs["edge_src"],
                         arrs["edge_dst"], arrs["edge_weight"],
                         arrs["num_nodes"], quantized=quantized)
    pred = jnp.argmax(logits, axis=-1)
    mask = arrs[mask_name]
    correct = ((pred == arrs["labels"]) & mask).sum()
    return float(correct / jnp.maximum(mask.sum(), 1))


# ---------------------------------------------------------------------------
# Graph classification (GIN): padded batches, vmap over graphs.
# ---------------------------------------------------------------------------


def pad_graph_batch(graphs: Sequence[Graph]):
    """Pad a list of graphs to common (max_nodes+1, max_edges); the extra
    node is a zero-feature sink that absorbs padded edges."""
    max_n = max(g.num_nodes for g in graphs) + 1  # +1 dummy sink
    max_e = max(g.num_edges for g in graphs)
    f = graphs[0].num_features
    b = len(graphs)
    feat = np.zeros((b, max_n, f), np.float32)
    es = np.full((b, max_e), max_n - 1, np.int32)
    ed = np.full((b, max_e), max_n - 1, np.int32)
    nmask = np.zeros((b, max_n), np.float32)
    labels = np.zeros((b,), np.int32)
    for i, g in enumerate(graphs):
        feat[i, :g.num_nodes] = g.node_feat
        es[i, :g.num_edges] = g.edge_src
        ed[i, :g.num_edges] = g.edge_dst
        nmask[i, :g.num_nodes] = 1.0
        labels[i] = g.graph_label
    return (jnp.asarray(feat), jnp.asarray(es), jnp.asarray(ed),
            jnp.asarray(nmask), jnp.asarray(labels), max_n)


def train_graph_classifier(
    model,
    graphs: Sequence[Graph],
    steps: int = 150,
    batch_size: int = 32,
    lr: float = 5e-3,
    weight_decay: float = 1e-4,
    seed: int = 0,
    train_frac: float = 0.8,
):
    rng = np.random.default_rng(seed)
    order = rng.permutation(len(graphs))
    n_train = int(train_frac * len(graphs))
    train_set = [graphs[i] for i in order[:n_train]]
    test_set = [graphs[i] for i in order[n_train:]]

    feat, es, ed, nmask, labels, max_n = pad_graph_batch(train_set)
    params = model.init(jax.random.PRNGKey(seed))
    cfg = AdamWConfig(lr=lr, weight_decay=weight_decay, b2=0.999)
    state = adamw_init(params, cfg)

    batched_apply = jax.vmap(
        lambda p, f, s, d, m: model.apply(p, f, s, d, None, max_n,
                                          node_mask=m),
        in_axes=(None, 0, 0, 0, 0),
    )

    def loss_fn(p, f, s, d, m, y):
        logits = batched_apply(p, f, s, d, m)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        return -jnp.take_along_axis(logp, y[:, None], axis=-1).mean()

    @jax.jit
    def step(p, st, f, s, d, m, y):
        loss, grads = jax.value_and_grad(loss_fn)(p, f, s, d, m, y)
        p2, st2, _ = adamw_step(grads, st, p, cfg)
        return p2, st2, loss

    n = feat.shape[0]
    for i in range(steps):
        idx = rng.integers(0, n, size=min(batch_size, n))
        params, state, loss = step(params, state, feat[idx], es[idx],
                                   ed[idx], nmask[idx], labels[idx])
    return params, test_set


def eval_graph_classifier(model, params, graphs: Sequence[Graph],
                          quantized=False, batch_size: int = 64) -> float:
    correct = 0
    for start in range(0, len(graphs), batch_size):
        chunk = graphs[start:start + batch_size]
        feat, es, ed, nmask, labels, max_n = pad_graph_batch(chunk)
        batched_apply = jax.vmap(
            lambda f, s, d, m: model.apply(params, f, s, d, None, max_n,
                                           quantized=quantized, node_mask=m),
            in_axes=(0, 0, 0, 0),
        )
        logits = batched_apply(feat, es, ed, nmask)
        pred = jnp.argmax(logits, axis=-1)
        correct += int((pred == labels).sum())
    return correct / len(graphs)
