"""Small shared utilities used across the framework."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def cdiv(a: int, b: int) -> int:
    """Ceiling division."""
    if b <= 0:
        raise ValueError(f"cdiv divisor must be positive, got {b}")
    return -(-a // b)


def round_up(a: int, b: int) -> int:
    """Round ``a`` up to the nearest multiple of ``b``."""
    return cdiv(a, b) * b


def tree_size(tree) -> int:
    """Total number of elements across all leaves of a pytree."""
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))


def tree_bytes(tree) -> int:
    """Total bytes across all leaves (works on ShapeDtypeStruct too)."""
    total = 0
    for x in jax.tree.leaves(tree):
        total += int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize
    return total


def human_bytes(n: float) -> str:
    for unit in ("B", "KiB", "MiB", "GiB", "TiB", "PiB"):
        if abs(n) < 1024.0:
            return f"{n:.2f} {unit}"
        n /= 1024.0
    return f"{n:.2f} EiB"


def human_number(n: float) -> str:
    for unit in ("", "K", "M", "B", "T"):
        if abs(n) < 1000.0:
            return f"{n:.2f}{unit}"
        n /= 1000.0
    return f"{n:.2f}Q"
