from repro.common.utils import (
    cdiv,
    round_up,
    tree_size,
    tree_bytes,
    human_bytes,
    human_number,
)

__all__ = [
    "cdiv",
    "round_up",
    "tree_size",
    "tree_bytes",
    "human_bytes",
    "human_number",
]
