"""Encoder-decoder LM (whisper-medium).

The audio conv frontend is a STUB per the pool spec: ``input_specs()``
provides precomputed frame embeddings [B, frames, d_model] (what the two
conv-subsampling layers would emit).  The encoder is a bidirectional
transformer over frames + sinusoidal positions; the decoder is causal with
cross-attention into the encoder output.

Serving: ``encode`` runs once per request; the decoder's cross K/V are
projected once from the encoder output and carried in the cache; decode
steps then behave like a decoder-only LM.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers.attention import (
    KVCache,
    attn_apply,
    attn_init,
    chunked_attention,
    init_kv_cache,
)
from repro.models.layers.mlp import mlp_apply, mlp_init
from repro.models.layers.norm import apply_norm, norm_init
from repro.models.layers.rope import sinusoidal_positions


class EncDecCache(NamedTuple):
    self_kv: KVCache          # stacked [L, ...] decoder self-attn cache
    cross_k: jax.Array        # [L, B, T_enc, KVH, hd]
    cross_v: jax.Array


def _dtype_of(cfg):
    return jnp.dtype(cfg.dtype)


def _enc_layer_init(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": norm_init(cfg.norm, cfg.d_model, cfg.norm_bias, dtype),
        "attn": attn_init(k1, cfg, dtype),
        "ln2": norm_init(cfg.norm, cfg.d_model, cfg.norm_bias, dtype),
        "ffn": mlp_init(k2, cfg.d_model, cfg.d_ff, glu=cfg.glu,
                        bias=cfg.mlp_bias, dtype=dtype),
    }


def _dec_layer_init(key, cfg, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": norm_init(cfg.norm, cfg.d_model, cfg.norm_bias, dtype),
        "attn": attn_init(k1, cfg, dtype),
        "ln_x": norm_init(cfg.norm, cfg.d_model, cfg.norm_bias, dtype),
        "xattn": attn_init(k2, cfg, dtype, cross=True),
        "ln2": norm_init(cfg.norm, cfg.d_model, cfg.norm_bias, dtype),
        "ffn": mlp_init(k3, cfg.d_model, cfg.d_ff, glu=cfg.glu,
                        bias=cfg.mlp_bias, dtype=dtype),
    }


class EncDecLM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg.validate()

    def init(self, key) -> dict:
        cfg = self.cfg
        dtype = _dtype_of(cfg)
        k_e, k_d, k_emb, k_pe = jax.random.split(key, 4)
        enc_keys = jax.random.split(k_e, cfg.encoder_layers)
        dec_keys = jax.random.split(k_d, cfg.num_layers)
        return {
            "embed": 0.02 * jax.random.normal(
                k_emb, (cfg.vocab_size, cfg.d_model), dtype),
            "pos_dec": 0.02 * jax.random.normal(
                k_pe, (4096, cfg.d_model), dtype),  # learned decoder positions
            "encoder": jax.vmap(
                lambda k: _enc_layer_init(k, cfg, dtype))(enc_keys),
            "enc_norm": norm_init(cfg.norm, cfg.d_model, cfg.norm_bias, dtype),
            "decoder": jax.vmap(
                lambda k: _dec_layer_init(k, cfg, dtype))(dec_keys),
            "final_norm": norm_init(cfg.norm, cfg.d_model, cfg.norm_bias, dtype),
        }

    def param_specs(self, seed: int = 0):
        return jax.eval_shape(self.init, jax.random.PRNGKey(seed))

    # ---- encoder ----

    def encode(self, params, frames: jax.Array) -> jax.Array:
        """frames: [B, T_enc, D] (stub conv output) -> encoder states."""
        cfg = self.cfg
        t = frames.shape[1]
        x = frames + sinusoidal_positions(t, cfg.d_model).astype(frames.dtype)
        positions = jnp.arange(t)

        def body(carry, lp):
            xx = carry
            h = apply_norm(cfg.norm, lp["ln1"], xx)
            a, _ = attn_apply(cfg, lp["attn"], h, positions, causal=False)
            xx = xx + a
            h2 = apply_norm(cfg.norm, lp["ln2"], xx)
            xx = xx + mlp_apply(lp["ffn"], h2, cfg.activation)
            return xx, None

        x, _ = jax.lax.scan(jax.checkpoint(body), x, params["encoder"],
                            unroll=cfg.encoder_layers if cfg.scan_unroll else 1)
        return apply_norm(cfg.norm, params["enc_norm"], x)

    # ---- decoder ----

    def _dec_positions_embed(self, params, tokens, pos0):
        cfg = self.cfg
        x = params["embed"][tokens]
        idx = jnp.clip(pos0 + jnp.arange(tokens.shape[1]),
                       0, params["pos_dec"].shape[0] - 1)
        return x + params["pos_dec"][idx]

    def decoder_states(self, params, tokens, enc_out, caches=None,
                       mode: str = "train", pos0=0):
        cfg = self.cfg
        b, s = tokens.shape
        positions = pos0 + jnp.arange(s)
        x = self._dec_positions_embed(params, tokens, pos0)

        if mode == "train":
            h, kvh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim

            def body(carry, lp):
                xx = carry
                hh = apply_norm(cfg.norm, lp["ln1"], xx)
                a, _ = attn_apply(cfg, lp["attn"], hh, positions, causal=True)
                xx = xx + a
                hx = apply_norm(cfg.norm, lp["ln_x"], xx)
                ek = (enc_out @ lp["xattn"]["wk"]).reshape(b, -1, kvh, hd)
                ev = (enc_out @ lp["xattn"]["wv"]).reshape(b, -1, kvh, hd)
                if "bk" in lp["xattn"]:
                    ek = ek + lp["xattn"]["bk"].reshape(kvh, hd)
                    ev = ev + lp["xattn"]["bv"].reshape(kvh, hd)
                xa, _ = attn_apply(cfg, lp["xattn"], hx, positions,
                                   cross_kv=(ek, ev))
                xx = xx + xa
                h2 = apply_norm(cfg.norm, lp["ln2"], xx)
                xx = xx + mlp_apply(lp["ffn"], h2, cfg.activation)
                return xx, None

            x, _ = jax.lax.scan(jax.checkpoint(body), x, params["decoder"],
                                unroll=cfg.num_layers if cfg.scan_unroll else 1)
            new_cache = None
        else:
            def body_serve(carry, layer_in):
                xx = carry
                lp, lc, ck, cv = layer_in
                hh = apply_norm(cfg.norm, lp["ln1"], xx)
                a, nc = attn_apply(cfg, lp["attn"], hh, positions, causal=True,
                                   cache=lc, update_cache=(mode == "prefill"))
                xx = xx + a
                hx = apply_norm(cfg.norm, lp["ln_x"], xx)
                xa, _ = attn_apply(cfg, lp["xattn"], hx, positions,
                                   cross_kv=(ck, cv))
                xx = xx + xa
                h2 = apply_norm(cfg.norm, lp["ln2"], xx)
                xx = xx + mlp_apply(lp["ffn"], h2, cfg.activation)
                return xx, nc

            x, new_self = jax.lax.scan(
                body_serve, x,
                (params["decoder"], caches.self_kv, caches.cross_k,
                 caches.cross_v))
            new_cache = EncDecCache(self_kv=new_self, cross_k=caches.cross_k,
                                    cross_v=caches.cross_v)
        return apply_norm(cfg.norm, params["final_norm"], x), new_cache

    # ---- caches / serving ----

    def init_cache(self, params, frames, batch: int, max_seq: int):
        """Run the encoder, project cross K/V once per layer, zero self KV."""
        cfg = self.cfg
        dtype = _dtype_of(cfg)
        kvh, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        enc_out = self.encode(params, frames)
        b, t, _ = enc_out.shape

        def project(lp):
            k = (enc_out @ lp["xattn"]["wk"]).reshape(b, t, kvh, hd)
            v = (enc_out @ lp["xattn"]["wv"]).reshape(b, t, kvh, hd)
            if "bk" in lp["xattn"]:
                k = k + lp["xattn"]["bk"].reshape(kvh, hd)
                v = v + lp["xattn"]["bv"].reshape(kvh, hd)
            return k, v

        ck, cv = jax.vmap(project)(params["decoder"])
        self_kv = jax.vmap(
            lambda _: init_kv_cache(batch, max_seq, kvh, hd, dtype)
        )(jnp.arange(cfg.num_layers))
        return EncDecCache(self_kv=self_kv, cross_k=ck, cross_v=cv)

    def loss(self, params, batch: dict, seq_chunk: int = 512):
        """batch: {"frames": [B,T,D], "tokens": [B,S], "labels": [B,S]}."""
        from repro.models.transformer import _chunked_ce

        enc_out = self.encode(params, batch["frames"])
        h, _ = self.decoder_states(params, batch["tokens"], enc_out,
                                   mode="train")
        mask = batch.get("mask")
        if mask is None:
            mask = jnp.ones_like(batch["labels"], jnp.float32)
        ce, denom = _chunked_ce(h, params["embed"].T, batch["labels"], mask,
                                seq_chunk)
        loss = ce / jnp.maximum(denom, 1.0)
        return loss, {"ce": loss}

    def prefill(self, params, tokens, caches):
        h, caches = self.decoder_states(params, tokens, None, caches,
                                        mode="prefill", pos0=0)
        return h[:, -1:, :] @ params["embed"].T, caches

    def decode_step(self, params, caches, tokens, pos):
        h, caches = self.decoder_states(params, tokens, None, caches,
                                        mode="decode", pos0=pos)
        return h @ params["embed"].T, caches
