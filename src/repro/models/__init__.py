from repro.models.model_zoo import build_model
from repro.models.transformer import DecoderLM
from repro.models.encdec import EncDecLM
