"""build_model(config) — the single entry point for every pool architecture."""

from __future__ import annotations

from repro.configs.base import ModelConfig
from repro.models.encdec import EncDecLM
from repro.models.transformer import DecoderLM


def build_model(cfg: ModelConfig):
    if cfg.encoder_layers > 0:
        return EncDecLM(cfg)
    return DecoderLM(cfg)
