"""Unified decoder LM assembled from a ModelConfig.

Handles every pool family except whisper (see encdec.py):
  dense (mistral/stablelm/command-r/chatglm/chameleon), moe (mixtral,
  deepseek incl. MLA + dense-prefix + MTP), hybrid (hymba: parallel
  attn+SSM with mixed global/local windows), ssm (rwkv6).

Structure
---------
Layers are grouped into *segments*: maximal runs of layers with identical
parameter structure AND cache shape (ffn kind, d_ff, attention window
class).  Each segment scans over its stacked per-layer parameters
(``jax.lax.scan``) so the HLO contains one body per segment regardless of
depth — essential for 88-layer configs on a 512-way mesh.  deepseek-v3 gets
[dense x3, moe x58]; hymba gets [global, local x14, global, local x15,
global]; uniform models get a single segment.

Memory discipline: training wraps each segment body in ``jax.checkpoint``
(remat), attention is chunked/online-softmax (see layers/attention.py), and
the LM loss is computed in sequence chunks so [B, S, V] logits are never
materialized.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.common.utils import cdiv
from repro.configs.base import ModelConfig
from repro.distributed.context import constrain_residual
from repro.models.layers.attention import (
    KVCache,
    attn_apply,
    attn_init,
    init_kv_cache,
)
from repro.models.layers.mla import (
    MLACache,
    init_mla_cache,
    mla_decode,
    mla_init,
    mla_prefill,
)
from repro.models.layers.mlp import mlp_apply, mlp_init
from repro.models.layers.moe import moe_apply, moe_init
from repro.models.layers.norm import apply_norm, norm_init
from repro.models.layers.rwkv import (
    RWKVCache,
    init_rwkv_cache,
    rwkv_channel_mix,
    rwkv_channel_mix_init,
    rwkv_time_mix,
    rwkv_time_mix_init,
)
from repro.models.layers.ssm import SSMCache, init_ssm_cache, ssm_apply, ssm_init

GLOBAL_WINDOW = 0  # window=0 means full attention


@dataclasses.dataclass(frozen=True)
class Segment:
    count: int
    window: int           # 0 = global; >0 = sliding window (uniform in seg)
    ffn: str              # "mlp" | "moe"
    d_ff: int


def plan_segments(cfg: ModelConfig) -> tuple[Segment, ...]:
    segs: list[Segment] = []
    for i in range(cfg.num_layers):
        if cfg.moe and i >= cfg.moe.first_dense_layers:
            ffn, d_ff = "moe", cfg.moe.d_ff_expert
        elif cfg.moe:
            ffn, d_ff = "mlp", (cfg.moe.dense_d_ff or cfg.d_ff)
        else:
            ffn, d_ff = "mlp", cfg.d_ff
        if cfg.sliding_window and i not in cfg.global_layer_indices:
            window = cfg.sliding_window
        else:
            window = GLOBAL_WINDOW
        if segs and (segs[-1].window == window and segs[-1].ffn == ffn
                     and segs[-1].d_ff == d_ff):
            segs[-1] = dataclasses.replace(segs[-1], count=segs[-1].count + 1)
        else:
            segs.append(Segment(1, window, ffn, d_ff))
    return tuple(segs)


# ---------------------------------------------------------------------------
# One decoder layer.
# ---------------------------------------------------------------------------


def _dtype_of(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def layer_init(key, cfg: ModelConfig, seg: Segment) -> dict:
    dtype = _dtype_of(cfg)
    ks = jax.random.split(key, 6)
    p: dict = {"ln1": norm_init(cfg.norm, cfg.d_model, cfg.norm_bias, dtype)}
    if cfg.rwkv is not None:
        p["tm"] = rwkv_time_mix_init(ks[0], cfg.d_model, cfg.rwkv, dtype)
        p["ln2"] = norm_init(cfg.norm, cfg.d_model, cfg.norm_bias, dtype)
        p["cm"] = rwkv_channel_mix_init(ks[1], cfg.d_model, cfg.d_ff, dtype)
        return p
    if cfg.mla is not None:
        p["mla"] = mla_init(ks[0], cfg.d_model, cfg.num_heads, cfg.mla, dtype)
    else:
        p["attn"] = attn_init(ks[0], cfg, dtype)
    if cfg.ssm is not None:  # hymba parallel branch
        p["ssm"] = ssm_init(ks[1], cfg.d_model, cfg.ssm, dtype)
        p["ln_attn_out"] = norm_init("rmsnorm", cfg.d_model, False, dtype)
        p["ln_ssm_out"] = norm_init("rmsnorm", cfg.d_model, False, dtype)
    if not cfg.parallel_block:
        p["ln2"] = norm_init(cfg.norm, cfg.d_model, cfg.norm_bias, dtype)
    if seg.ffn == "moe":
        p["moe"] = moe_init(ks[2], cfg.d_model, cfg.moe, dtype)
    else:
        p["ffn"] = mlp_init(ks[2], cfg.d_model, seg.d_ff, glu=cfg.glu,
                            bias=cfg.mlp_bias, dtype=dtype)
    return p


def layer_cache_init(cfg: ModelConfig, seg: Segment, batch: int,
                     max_seq: int) -> Any:
    """Zero cache for one layer of this segment (None for train mode)."""
    dtype = _dtype_of(cfg)
    if cfg.rwkv is not None:
        return init_rwkv_cache(batch, cfg.d_model, cfg.rwkv, dtype)
    buf = max_seq if seg.window == GLOBAL_WINDOW else min(seg.window, max_seq)
    if cfg.mla is not None:
        cache = init_mla_cache(batch, buf, cfg.mla, dtype)
    else:
        kv_dtype = jnp.dtype(cfg.kv_cache_dtype) if cfg.kv_cache_dtype else dtype
        cache = init_kv_cache(batch, buf, cfg.num_kv_heads,
                              cfg.resolved_head_dim, kv_dtype)
    if cfg.ssm is not None:
        return (cache, init_ssm_cache(batch, cfg.d_model, cfg.ssm, dtype))
    return cache


def layer_apply(cfg: ModelConfig, seg: Segment, p: dict, x: jax.Array,
                positions: jax.Array, cache: Any, mode: str):
    """Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)

    if cfg.rwkv is not None:
        h, (wkv_state, tm_last) = rwkv_time_mix(
            p["tm"], apply_norm(cfg.norm, p["ln1"], x), cfg.rwkv,
            cache if mode != "train" else None)
        x = x + h
        cm_last_in = cache.cm_last if mode != "train" else None
        h2, cm_last = rwkv_channel_mix(
            p["cm"], apply_norm(cfg.norm, p["ln2"], x), cm_last_in)
        x = x + h2
        new_cache = None
        if mode != "train":
            new_cache = RWKVCache(wkv_state=wkv_state, tm_last=tm_last,
                                  cm_last=cm_last,
                                  length=cache.length + x.shape[1])
        return x, new_cache, aux

    h = apply_norm(cfg.norm, p["ln1"], x)

    attn_cache = cache[0] if (cfg.ssm is not None and cache is not None) else cache
    if cfg.mla is not None:
        if mode == "decode":
            attn_out, new_attn_cache = mla_decode(
                p["mla"], h, cfg.num_heads, cfg.mla, positions, cfg.rope_theta,
                attn_cache)
        else:
            attn_out, new_attn_cache = mla_prefill(
                p["mla"], h, cfg.num_heads, cfg.mla, positions, cfg.rope_theta,
                cache=attn_cache if mode == "prefill" else None)
    else:
        attn_out, new_attn_cache = attn_apply(
            cfg, p["attn"], h, positions,
            window=seg.window, causal=True,
            cache=attn_cache if mode != "train" else None,
            update_cache=(mode == "prefill"),
        )

    new_cache: Any = new_attn_cache
    if cfg.ssm is not None:
        ssm_cache = cache[1] if cache is not None else None
        ssm_out, new_ssm_cache = ssm_apply(
            p["ssm"], h, cfg.ssm, ssm_cache if mode != "train" else None)
        fused = 0.5 * (apply_norm("rmsnorm", p["ln_attn_out"], attn_out)
                       + apply_norm("rmsnorm", p["ln_ssm_out"], ssm_out))
        attn_out = fused
        if mode != "train":
            new_cache = (new_attn_cache, new_ssm_cache)

    if cfg.parallel_block:
        ffn_out, aux = _apply_ffn(cfg, seg, p, h)
        x = x + attn_out + ffn_out
    else:
        x = x + attn_out
        h2 = apply_norm(cfg.norm, p["ln2"], x)
        ffn_out, aux = _apply_ffn(cfg, seg, p, h2)
        x = x + ffn_out
    return x, new_cache, aux


def _apply_ffn(cfg, seg, p, h):
    if seg.ffn == "moe":
        out, aux = moe_apply(p["moe"], h, cfg.moe)
        return out, aux
    return mlp_apply(p["ffn"], h, cfg.activation), jnp.zeros((), jnp.float32)


# ---------------------------------------------------------------------------
# Whole model.
# ---------------------------------------------------------------------------


class DecoderLM:
    """Config-built decoder-only LM with train / prefill / decode entries."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg.validate()
        self.segments = plan_segments(cfg)

    # ---- parameters ----

    def init(self, key) -> dict:
        cfg = self.cfg
        dtype = _dtype_of(cfg)
        keys = jax.random.split(key, len(self.segments) + 3)
        params: dict = {
            "embed": 0.02 * jax.random.normal(
                keys[0], (cfg.vocab_size, cfg.d_model), dtype),
            "final_norm": norm_init(cfg.norm, cfg.d_model, cfg.norm_bias, dtype),
        }
        if not cfg.tie_embeddings:
            params["unembed"] = 0.02 * jax.random.normal(
                keys[1], (cfg.d_model, cfg.vocab_size), dtype)
        segs = []
        for si, seg in enumerate(self.segments):
            lkeys = jax.random.split(keys[2 + si], seg.count)
            segs.append(jax.vmap(
                lambda k, _seg=seg: layer_init(k, cfg, _seg))(lkeys))
        params["segments"] = segs
        if cfg.mtp:
            params["mtp"] = {
                "proj": 0.02 * jax.random.normal(
                    keys[-1], (2 * cfg.d_model, cfg.d_model), dtype),
                "block": layer_init(jax.random.fold_in(keys[-1], 1), cfg,
                                    self.segments[-1]),
                "norm_h": norm_init(cfg.norm, cfg.d_model, cfg.norm_bias, dtype),
                "norm_e": norm_init(cfg.norm, cfg.d_model, cfg.norm_bias, dtype),
            }
        return params

    def param_specs(self, seed: int = 0):
        return jax.eval_shape(self.init, jax.random.PRNGKey(seed))

    # ---- caches ----

    def init_cache(self, batch: int, max_seq: int) -> list:
        caches = []
        for seg in self.segments:
            one = lambda _, _seg=seg: layer_cache_init(
                self.cfg, _seg, batch, max_seq)
            caches.append(jax.vmap(one)(jnp.arange(seg.count)))
        return caches

    def cache_specs(self, batch: int, max_seq: int):
        return jax.eval_shape(lambda: self.init_cache(batch, max_seq))

    # ---- forward ----

    def hidden_states(self, params, tokens, positions, caches=None,
                      mode: str = "train"):
        """tokens [B, S] -> (h [B, S, D], new_caches, aux)."""
        cfg = self.cfg
        x = params["embed"][tokens]
        aux_total = jnp.zeros((), jnp.float32)
        new_caches = []
        for si, seg in enumerate(self.segments):
            seg_params = params["segments"][si]
            seg_cache = caches[si] if caches is not None else None

            unroll = seg.count if cfg.scan_unroll else 1
            if mode == "train":
                def body_train(carry, lp, _seg=seg):
                    xx, nc, aux = layer_apply(cfg, _seg, lp, carry, positions,
                                              None, "train")
                    return constrain_residual(xx), aux

                x, auxs = jax.lax.scan(
                    jax.checkpoint(body_train), x, seg_params, unroll=unroll)
                new_caches.append(None)
            else:
                def body_serve(carry, layer_in, _seg=seg):
                    lp, lc = layer_in
                    xx, nc, aux = layer_apply(cfg, _seg, lp, carry, positions,
                                              lc, mode)
                    return constrain_residual(xx), (nc, aux)

                x, (ncache, auxs) = jax.lax.scan(
                    body_serve, x, (seg_params, seg_cache), unroll=unroll)
                new_caches.append(ncache)
            aux_total = aux_total + auxs.sum()
        h = apply_norm(cfg.norm, params["final_norm"], x)
        return h, new_caches, aux_total

    def unembed(self, params):
        if self.cfg.tie_embeddings:
            return params["embed"].T
        return params["unembed"]

    def logits(self, params, h):
        return h @ self.unembed(params)

    # ---- training loss (chunked over sequence) ----

    def loss(self, params, batch: dict, seq_chunk: int = 512):
        """batch: {"tokens": [B,S] int32, "labels": [B,S] int32,
        optional "mask": [B,S]}.  Returns (loss, metrics)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        b, s = tokens.shape
        positions = jnp.arange(s)
        h, _, aux = self.hidden_states(params, tokens, positions, mode="train")
        w_un = self.unembed(params)
        labels = batch["labels"]
        mask = batch.get("mask")
        if mask is None:
            mask = jnp.ones_like(labels, jnp.float32)

        ce, denom = _chunked_ce(h, w_un, labels, mask, seq_chunk)

        if cfg.mtp:
            ce_mtp, d_mtp = self._mtp_loss(params, h, tokens, labels, mask,
                                           positions, seq_chunk)
            ce = ce + 0.3 * ce_mtp
        loss = ce / jnp.maximum(denom, 1.0) + aux
        return loss, {"ce": ce / jnp.maximum(denom, 1.0), "aux": aux}

    def _mtp_loss(self, params, h, tokens, labels, mask, positions, seq_chunk):
        """deepseek MTP depth-1: predict token t+2 from (h_t, emb(token_{t+1}))."""
        cfg = self.cfg
        mp = params["mtp"]
        nxt = jnp.roll(tokens, -1, axis=1)
        e = params["embed"][nxt]
        hh = jnp.concatenate([
            apply_norm(cfg.norm, mp["norm_h"], h),
            apply_norm(cfg.norm, mp["norm_e"], e),
        ], axis=-1) @ mp["proj"]
        hh, _, _ = layer_apply(cfg, self.segments[-1], mp["block"], hh,
                               positions, None, "train")
        lbl2 = jnp.roll(labels, -1, axis=1)
        m2 = mask * (jnp.arange(tokens.shape[1]) < tokens.shape[1] - 1)
        return _chunked_ce(hh, self.unembed(params), lbl2, m2, seq_chunk)

    # ---- serving ----

    def prefill(self, params, tokens, caches):
        """Fill caches with a prompt; returns (last-token logits, caches)."""
        s = tokens.shape[1]
        h, caches, _ = self.hidden_states(
            params, tokens, jnp.arange(s), caches, mode="prefill")
        return self.logits(params, h[:, -1:, :]), caches

    def decode_step(self, params, caches, tokens, pos):
        """One decode step.  tokens [B,1]; pos [] absolute position."""
        positions = pos + jnp.arange(tokens.shape[1])
        h, caches, _ = self.hidden_states(
            params, tokens, positions, caches, mode="decode")
        return self.logits(params, h), caches


def _chunked_ce(h, w_un, labels, mask, seq_chunk: int):
    """Sum of masked CE over the sequence, computed in chunks so [B,S,V] is
    never materialized.  Returns (ce_sum, mask_sum)."""
    b, s, d = h.shape
    chunk = min(seq_chunk, s)
    if s % chunk:
        pad = chunk - s % chunk
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    n = h.shape[1] // chunk
    hc = h.reshape(b, n, chunk, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, n, chunk).transpose(1, 0, 2)
    mc = mask.reshape(b, n, chunk).transpose(1, 0, 2)

    def step(carry, inp):
        ce_sum, m_sum = carry
        hh, ll, mm = inp
        logits = (hh @ w_un).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, ll[..., None], axis=-1)[..., 0]
        ce = (logz - gold) * mm
        return (ce_sum + ce.sum(), m_sum + mm.sum()), None

    (ce_sum, m_sum), _ = jax.lax.scan(
        step, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (hc, lc, mc))
    return ce_sum, m_sum
