"""Multi-head Latent Attention (deepseek-v2/v3).

Faithful structure per arXiv:2412.19437:

  q:  x -> W_dq [d, q_lora] -> rmsnorm -> W_uq [q_lora, H*(nope+rope)]
  kv: x -> W_dkv [d, kv_lora]  (cached!)  -> rmsnorm
          -> W_uk [kv_lora, H*nope], W_uv [kv_lora, H*v_dim]
  k_rope: x -> W_kr [d, rope]   (single shared rope head, cached)

Prefill computes full k/v (direct form).  Decode uses the *absorbed* form:
q_nope is pre-multiplied by W_uk so attention scores contract against the
cached latent c_kv directly, and the attention output in latent space is
post-multiplied by W_uv — per-token cache is kv_lora + rope dims
(512 + 64 = 576 for the 671b config), MLA's entire memory advantage, and why
the decode_32k dry-run cell for this arch has a tiny KV-cache footprint.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import MLAConfig
from repro.models.layers.norm import apply_norm, rmsnorm_init
from repro.models.layers.rope import apply_rope


class MLACache(NamedTuple):
    c_kv: jax.Array    # [B, S_buf, kv_lora]
    k_rope: jax.Array  # [B, S_buf, rope_dim]
    length: jax.Array  # [] int32


def init_mla_cache(batch: int, buf_len: int, cfg: MLAConfig,
                   dtype=jnp.bfloat16) -> MLACache:
    return MLACache(
        c_kv=jnp.zeros((batch, buf_len, cfg.kv_lora_rank), dtype),
        k_rope=jnp.zeros((batch, buf_len, cfg.qk_rope_head_dim), dtype),
        length=jnp.zeros((), jnp.int32),
    )


def mla_init(key, d_model: int, num_heads: int, cfg: MLAConfig,
             dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 7)
    init = lambda k, fi, fo: jax.random.normal(k, (fi, fo), dtype) * (fi ** -0.5)
    h = num_heads
    qd = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
    return {
        "w_dq": init(ks[0], d_model, cfg.q_lora_rank),
        "q_norm": rmsnorm_init(cfg.q_lora_rank, dtype),
        "w_uq": init(ks[1], cfg.q_lora_rank, h * qd),
        "w_dkv": init(ks[2], d_model, cfg.kv_lora_rank),
        "kv_norm": rmsnorm_init(cfg.kv_lora_rank, dtype),
        "w_uk": init(ks[3], cfg.kv_lora_rank, h * cfg.qk_nope_head_dim),
        "w_uv": init(ks[4], cfg.kv_lora_rank, h * cfg.v_head_dim),
        "w_kr": init(ks[5], d_model, cfg.qk_rope_head_dim),
        "w_o": init(ks[6], h * cfg.v_head_dim, d_model),
    }


def _project_q(p, x, num_heads, cfg, positions, rope_theta):
    b, s, _ = x.shape
    cq = apply_norm("rmsnorm", p["q_norm"], x @ p["w_dq"])
    q = (cq @ p["w_uq"]).reshape(b, s, num_heads,
                                 cfg.qk_nope_head_dim + cfg.qk_rope_head_dim)
    q_nope = q[..., :cfg.qk_nope_head_dim]
    q_rope = apply_rope(q[..., cfg.qk_nope_head_dim:], positions, rope_theta, 1.0)
    return q_nope, q_rope


def mla_prefill(p, x, num_heads, cfg: MLAConfig, positions, rope_theta,
                cache: Optional[MLACache] = None, chunk_size: int = 1024):
    """Direct-form MLA over a full sequence; optionally fills the cache.

    Returns (out [B,S,D], new_cache).
    """
    from repro.models.layers.attention import chunked_attention

    b, s, _ = x.shape
    q_nope, q_rope = _project_q(p, x, num_heads, cfg, positions, rope_theta)

    c_kv = apply_norm("rmsnorm", p["kv_norm"], x @ p["w_dkv"])      # [B,S,r]
    k_nope = (c_kv @ p["w_uk"]).reshape(b, s, num_heads, cfg.qk_nope_head_dim)
    v = (c_kv @ p["w_uv"]).reshape(b, s, num_heads, cfg.v_head_dim)
    k_rope = apply_rope((x @ p["w_kr"])[:, :, None, :], positions,
                        rope_theta, 1.0)                            # [B,S,1,rope]
    k_rope_b = jnp.broadcast_to(
        k_rope, (b, s, num_heads, cfg.qk_rope_head_dim))

    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, k_rope_b], axis=-1)
    # Pad v to q/k head dim so one attention call computes the context, then
    # slice back (keeps chunked_attention generic).
    qk_dim = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
    v_pad = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, qk_dim - cfg.v_head_dim)))
    out = chunked_attention(q, k, v_pad, causal=True,
                            q_offset=positions[0], k_offset=positions[0],
                            chunk_size=chunk_size)
    out = out[..., :cfg.v_head_dim].reshape(b, s, num_heads * cfg.v_head_dim)

    new_cache = None
    if cache is not None:
        idx = (cache.length + jnp.arange(s)) % cache.c_kv.shape[1]
        new_cache = MLACache(
            c_kv=cache.c_kv.at[:, idx].set(c_kv.astype(cache.c_kv.dtype)),
            k_rope=cache.k_rope.at[:, idx].set(
                k_rope[:, :, 0, :].astype(cache.k_rope.dtype)),
            length=cache.length + s,
        )
    return out @ p["w_o"], new_cache


def mla_decode(p, x, num_heads, cfg: MLAConfig, positions, rope_theta,
               cache: MLACache):
    """Absorbed-form single/few-token decode against the latent cache."""
    b, s, _ = x.shape
    h = num_heads
    q_nope, q_rope = _project_q(p, x, num_heads, cfg, positions, rope_theta)

    c_kv_new = apply_norm("rmsnorm", p["kv_norm"], x @ p["w_dkv"])
    k_rope_new = apply_rope((x @ p["w_kr"])[:, :, None, :], positions,
                            rope_theta, 1.0)[:, :, 0, :]
    buf = cache.c_kv.shape[1]
    idx = (cache.length + jnp.arange(s)) % buf
    c_buf = cache.c_kv.at[:, idx].set(c_kv_new.astype(cache.c_kv.dtype))
    r_buf = cache.k_rope.at[:, idx].set(k_rope_new.astype(cache.k_rope.dtype))
    new_len = cache.length + s
    new_cache = MLACache(c_kv=c_buf, k_rope=r_buf, length=new_len)

    # Absorb W_uk into q:  q_lat[b,s,h,r] = q_nope . W_uk(per-head)
    w_uk = p["w_uk"].reshape(cfg.kv_lora_rank, h, cfg.qk_nope_head_dim)
    q_lat = jnp.einsum("bshd,rhd->bshr", q_nope, w_uk)
    scale = (cfg.qk_nope_head_dim + cfg.qk_rope_head_dim) ** -0.5
    scores = (
        jnp.einsum("bshr,bcr->bshc", q_lat.astype(jnp.float32),
                   c_buf.astype(jnp.float32))
        + jnp.einsum("bshd,bcd->bshc", q_rope.astype(jnp.float32),
                     r_buf.astype(jnp.float32))
    ) * scale                                               # [B,S,H,C]

    slot = jnp.arange(buf)
    k_pos = jnp.where(slot < new_len, slot, -1)             # full buffer: 1:1
    mask = (k_pos[None, :] >= 0) & (k_pos[None, :] <= positions[:, None])
    scores = jnp.where(mask[None, :, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)

    ctx_lat = jnp.einsum("bshc,bcr->bshr", probs,
                         c_buf.astype(jnp.float32))         # [B,S,H,r]
    w_uv = p["w_uv"].reshape(cfg.kv_lora_rank, h, cfg.v_head_dim)
    out = jnp.einsum("bshr,rhd->bshd", ctx_lat.astype(x.dtype), w_uv)
    out = out.reshape(b, s, h * cfg.v_head_dim)
    return out @ p["w_o"], new_cache
