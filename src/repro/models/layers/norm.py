"""Normalization layers (functional; params are dicts of arrays)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rmsnorm_init(d: int, dtype=jnp.float32) -> dict:
    return {"scale": jnp.ones((d,), dtype)}


def layernorm_init(d: int, bias: bool = True, dtype=jnp.float32) -> dict:
    p = {"scale": jnp.ones((d,), dtype)}
    if bias:
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def norm_init(kind: str, d: int, bias: bool = False, dtype=jnp.float32) -> dict:
    if kind == "rmsnorm":
        return rmsnorm_init(d, dtype)
    if kind == "layernorm":
        return layernorm_init(d, bias, dtype)
    raise ValueError(f"unknown norm '{kind}'")


def apply_norm(kind: str, p: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    x32 = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
        y = x32 * jax.lax.rsqrt(var + eps)
    elif kind == "layernorm":
        mu = jnp.mean(x32, axis=-1, keepdims=True)
        var = jnp.var(x32, axis=-1, keepdims=True)
        y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    else:
        raise ValueError(f"unknown norm '{kind}'")
    y = y * p["scale"].astype(jnp.float32)
    if "bias" in p:
        y = y + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


def head_rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    """Per-head qk-norm (chameleon): normalize over the head dim."""
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)
