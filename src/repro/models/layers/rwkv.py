"""RWKV-6 ("Finch") blocks: time-mix with data-dependent decay + channel-mix.

Faithful structure per arXiv:2404.05892:

* token shift   — per-channel lerp between x_t and x_{t-1}; the receptance/
                  key/value/gate mixes are learned constants, the decay mix
                  is data-dependent through a LoRA (the Finch contribution).
* decay         — w_t = exp(-exp(w_base + lora(x)));  per-channel, per-step.
* WKV recurrence (multi-head, head_dim x head_dim state S):
      y_t = r_t . (S_{t-1} + (u ⊙ k_t) v_t^T)
      S_t = diag(w_t) S_{t-1} + k_t v_t^T
* head group-norm, SiLU gate, output projection.
* channel-mix   — token shift, k = relu(W_k x)^2, out = sigmoid(W_r x) ⊙ W_v k.

The recurrence runs as an exact fp32 ``lax.scan`` over time (state is O(1)
in sequence length — the whole point of the architecture and of its
long_500k dry-run cell).  A chunked-parallel form is a recorded perf-
iteration candidate (EXPERIMENTS.md §Perf); correctness comes first here and
the decode path is already optimal (one step, no scan).
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import RWKVConfig


class RWKVCache(NamedTuple):
    wkv_state: jax.Array   # [B, H, hd, hd] fp32
    tm_last: jax.Array     # [B, D] last token seen by time-mix
    cm_last: jax.Array     # [B, D] last token seen by channel-mix
    length: jax.Array


def init_rwkv_cache(batch: int, d_model: int, cfg: RWKVConfig,
                    dtype=jnp.float32) -> RWKVCache:
    heads = d_model // cfg.head_dim
    return RWKVCache(
        wkv_state=jnp.zeros((batch, heads, cfg.head_dim, cfg.head_dim), jnp.float32),
        tm_last=jnp.zeros((batch, d_model), dtype),
        cm_last=jnp.zeros((batch, d_model), dtype),
        length=jnp.zeros((), jnp.int32),
    )


def rwkv_time_mix_init(key, d_model: int, cfg: RWKVConfig, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 9)
    init = lambda k, fi, fo: jax.random.normal(k, (fi, fo), dtype) * (fi ** -0.5)
    heads = d_model // cfg.head_dim
    return {
        "mix_r": jnp.full((d_model,), 0.5, dtype),
        "mix_k": jnp.full((d_model,), 0.5, dtype),
        "mix_v": jnp.full((d_model,), 0.5, dtype),
        "mix_g": jnp.full((d_model,), 0.5, dtype),
        "mix_w": jnp.full((d_model,), 0.5, dtype),
        "w_r": init(ks[0], d_model, d_model),
        "w_k": init(ks[1], d_model, d_model),
        "w_v": init(ks[2], d_model, d_model),
        "w_g": init(ks[3], d_model, d_model),
        "w_o": init(ks[4], d_model, d_model),
        # data-dependent decay LoRA: w_t = exp(-exp(w0 + tanh(x A) B))
        "decay_a": init(ks[5], d_model, cfg.decay_lora),
        "decay_b": init(ks[6], cfg.decay_lora, d_model) * 0.1,
        "decay_base": jnp.full((d_model,), -5.0, jnp.float32),
        "bonus_u": 0.1 * jax.random.normal(ks[7], (heads, cfg.head_dim), jnp.float32),
        "gn_scale": jnp.ones((d_model,), dtype),
    }


def rwkv_channel_mix_init(key, d_model: int, d_ff: int, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 3)
    init = lambda k, fi, fo: jax.random.normal(k, (fi, fo), dtype) * (fi ** -0.5)
    return {
        "mix_r": jnp.full((d_model,), 0.5, dtype),
        "mix_k": jnp.full((d_model,), 0.5, dtype),
        "w_r": init(ks[0], d_model, d_model),
        "w_k": init(ks[1], d_model, d_ff),
        "w_v": init(ks[2], d_ff, d_model),
    }


def _token_shift(x: jax.Array, last: jax.Array) -> jax.Array:
    """shift(x)_t = x_{t-1}, with ``last`` filling t=0.  x: [B,S,D]."""
    return jnp.concatenate([last[:, None, :], x[:, :-1, :]], axis=1)


def rwkv_time_mix(p: dict, x: jax.Array, cfg: RWKVConfig,
                  cache: Optional[RWKVCache] = None):
    """Returns (y [B,S,D], (new_state, new_last))."""
    b, s, d = x.shape
    heads = d // cfg.head_dim
    hd = cfg.head_dim

    last = cache.tm_last if cache is not None else jnp.zeros((b, d), x.dtype)
    xs = _token_shift(x, last)

    mix = lambda m: x + (xs - x) * m
    xr, xk, xv, xg, xw = (mix(p["mix_r"]), mix(p["mix_k"]), mix(p["mix_v"]),
                          mix(p["mix_g"]), mix(p["mix_w"]))

    r = (xr @ p["w_r"]).reshape(b, s, heads, hd).astype(jnp.float32)
    k = (xk @ p["w_k"]).reshape(b, s, heads, hd).astype(jnp.float32)
    v = (xv @ p["w_v"]).reshape(b, s, heads, hd).astype(jnp.float32)
    g = jax.nn.silu(xg @ p["w_g"])

    # Data-dependent decay (Finch): per-channel, per-step.
    dd = jnp.tanh(xw @ p["decay_a"]) @ p["decay_b"]
    logw = -jnp.exp(p["decay_base"] + dd.astype(jnp.float32))   # [B,S,D] (<0)
    w = jnp.exp(logw).reshape(b, s, heads, hd)                  # decay in (0,1)

    u = p["bonus_u"]                                            # [H, hd]

    state0 = (cache.wkv_state if cache is not None
              else jnp.zeros((b, heads, hd, hd), jnp.float32))

    def step(state, inp):
        r_t, k_t, v_t, w_t = inp                                # [B,H,hd] each
        kv = k_t[..., :, None] * v_t[..., None, :]              # [B,H,hd,hd]
        y_t = jnp.einsum("bhk,bhkv->bhv", r_t,
                         state + u[None, :, :, None] * kv)
        state = w_t[..., :, None] * state + kv
        return state, y_t

    seq = (r.transpose(1, 0, 2, 3), k.transpose(1, 0, 2, 3),
           v.transpose(1, 0, 2, 3), w.transpose(1, 0, 2, 3))
    state, ys = jax.lax.scan(step, state0, seq)
    y = ys.transpose(1, 0, 2, 3).reshape(b, s, d)               # fp32

    # Per-head group norm.
    yh = y.reshape(b, s, heads, hd)
    mu = yh.mean(-1, keepdims=True)
    var = yh.var(-1, keepdims=True)
    y = ((yh - mu) * jax.lax.rsqrt(var + 1e-5)).reshape(b, s, d)
    y = (y * p["gn_scale"].astype(jnp.float32)).astype(x.dtype)

    out = (y * g) @ p["w_o"]
    return out, (state, x[:, -1, :])


def rwkv_channel_mix(p: dict, x: jax.Array,
                     last: Optional[jax.Array] = None):
    b, s, d = x.shape
    if last is None:
        last = jnp.zeros((b, d), x.dtype)
    xs = _token_shift(x, last)
    xr = x + (xs - x) * p["mix_r"]
    xk = x + (xs - x) * p["mix_k"]
    k = jnp.square(jax.nn.relu(xk @ p["w_k"]))
    return jax.nn.sigmoid(xr @ p["w_r"]) * (k @ p["w_v"]), x[:, -1, :]
