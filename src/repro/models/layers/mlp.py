"""Feed-forward blocks: SwiGLU (gated) and plain 2-matrix MLPs."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _act(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


def mlp_init(key, d_model: int, d_ff: int, *, glu: bool, bias: bool,
             dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 3)
    init = lambda k, fi, fo: jax.random.normal(k, (fi, fo), dtype) * (fi ** -0.5)
    p = {"w_up": init(ks[0], d_model, d_ff), "w_down": init(ks[1], d_ff, d_model)}
    if glu:
        p["w_gate"] = init(ks[2], d_model, d_ff)
    if bias:
        p["b_up"] = jnp.zeros((d_ff,), dtype)
        p["b_down"] = jnp.zeros((d_model,), dtype)
    return p


def mlp_apply(p: dict, x: jax.Array, activation: str = "silu") -> jax.Array:
    up = x @ p["w_up"]
    if "b_up" in p:
        up = up + p["b_up"]
    if "w_gate" in p:
        up = _act(activation)(x @ p["w_gate"]) * up
    else:
        up = _act(activation)(up)
    out = up @ p["w_down"]
    if "b_down" in p:
        out = out + p["b_down"]
    return out
