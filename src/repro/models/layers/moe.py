"""Mixture-of-Experts layer with capacity-based scatter dispatch.

Structure note (DESIGN.md §3.3): top-k expert dispatch is the GHOST
partition dataflow on TPU — the token→expert assignment matrix is a sparse
adjacency whose non-empty (expert, capacity-slot) tiles are the only work
scheduled; empty capacity is the zero-block skip.  The dispatch below builds
per-expert dense buffers [E, C, D] (scatter), runs a batched expert einsum
(MXU-friendly, and the natural target for expert-parallel sharding on the
``model`` mesh axis — the scatter/gather become the EP all-to-all under
pjit), and combines with the routing weights (gather).

Routers: softmax top-k (mixtral) and sigmoid-score + top-k renormalization
(deepseek-v3).  Tokens beyond an expert's capacity are dropped (their
residual path passes through), the standard capacity-factor contract.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.common.utils import cdiv
from repro.configs.base import MoEConfig


def moe_init(key, d_model: int, cfg: MoEConfig, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 5)
    e, f = cfg.num_experts, cfg.d_ff_expert
    init = lambda k, shape, fan: jax.random.normal(k, shape, dtype) * (fan ** -0.5)
    p = {
        "router": init(ks[0], (d_model, e), d_model),
        "w_gate": init(ks[1], (e, d_model, f), d_model),
        "w_up": init(ks[2], (e, d_model, f), d_model),
        "w_down": init(ks[3], (e, f, d_model), f),
    }
    if cfg.num_shared_experts:
        fs = f * cfg.num_shared_experts
        kk = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": init(kk[0], (d_model, fs), d_model),
            "w_up": init(kk[1], (d_model, fs), d_model),
            "w_down": init(kk[2], (fs, d_model), fs),
        }
    return p


def _route(logits: jax.Array, cfg: MoEConfig):
    """Top-k routing -> (expert_idx [T,k], weights [T,k], aux_loss)."""
    if cfg.router == "softmax":
        probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
        w, idx = jax.lax.top_k(probs, cfg.top_k)
        w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    elif cfg.router == "sigmoid":
        scores = jax.nn.sigmoid(logits.astype(jnp.float32))
        w, idx = jax.lax.top_k(scores, cfg.top_k)
        w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
        probs = scores / jnp.maximum(scores.sum(-1, keepdims=True), 1e-9)
    else:
        raise ValueError(f"unknown router '{cfg.router}'")

    aux = jnp.zeros((), jnp.float32)
    if cfg.aux_loss_weight > 0.0:
        # Switch-style load-balance loss: E * sum_e f_e * P_e.
        e = logits.shape[-1]
        onehot = jax.nn.one_hot(idx[..., 0], e)
        f_e = onehot.mean(axis=0)
        p_e = probs.mean(axis=0)
        aux = cfg.aux_loss_weight * e * jnp.sum(f_e * p_e)
    return idx, w.astype(logits.dtype), aux


def moe_apply(p: dict, x: jax.Array, cfg: MoEConfig, activation=jax.nn.silu):
    """x: [B, S, D] -> (out [B, S, D], aux_loss).

    Dispatch is scatter-based with per-(group, expert) capacity
    C = ceil(top_k * T_group * capacity_factor / E).  Tokens are split into
    G dispatch groups (G = number of batch shards, installed by the
    launcher via repro.distributed.context) so routing, capacity, scatter
    and gather are all shard-local: the only cross-shard traffic is the
    expert-parallel exchange XLA inserts around the expert einsum itself.
    """
    from repro.distributed.context import constrain_moe_buffers, dispatch_groups

    b, s, d = x.shape
    t = b * s
    g = dispatch_groups(cfg.num_experts)
    if t % g:
        g = 1
    tg = t // g
    e, k = cfg.num_experts, cfg.top_k
    cap = max(cdiv(int(k * tg * cfg.capacity_factor), e), 1)
    xt = x.reshape(t, d)

    logits = xt @ p["router"]
    idx, w, aux = _route(logits, cfg)                      # [T,k]

    # Dispatch groups are folded into the expert dim: slot = g*E + e.  With
    # tokens batch-sharded and buffers dim-0 constrained to the same axes,
    # each shard's scatter writes only its own (g, *) slots — shard-local,
    # no cross-shard all-reduce of the buffers (§Perf iterations 2-3).
    tok_group = (jnp.arange(t, dtype=jnp.int32) // tg)     # [T]
    flat_e = idx.reshape(-1)                               # [T*k]
    flat_ge = jnp.repeat(tok_group, k) * e + flat_e        # [T*k] in [0, G*E)

    # Position within each (group, expert) queue, via a stable sort
    # (O(Tk log Tk) and O(Tk) memory — a [Tk, G*E] cumsum would be terabytes
    # at deepseek scale).
    sort_idx = jnp.argsort(flat_ge, stable=True)
    sorted_ge = flat_ge[sort_idx]
    counts = jnp.bincount(flat_ge, length=g * e)           # [G*E]
    starts = jnp.cumsum(counts) - counts
    rank_sorted = jnp.arange(t * k) - starts[sorted_ge]
    pos_flat = jnp.zeros((t * k,), jnp.int32).at[sort_idx].set(
        rank_sorted.astype(jnp.int32))
    keep = pos_flat < cap                                  # capacity drop

    flat_pos = jnp.where(keep, pos_flat, cap - 1)

    # Scatter tokens into grouped expert buffers [G*E, C, D].
    contrib = jnp.where(keep[:, None], jnp.repeat(xt, k, axis=0), 0.0)
    buffers = jnp.zeros((g * e, cap, d), xt.dtype).at[flat_ge, flat_pos].add(
        contrib)

    # Batched expert FFN (EP shards E on 'model' when it divides; the G dim
    # rides the batch axes — one joint constraint, see constrain_moe_buffers).
    bge = constrain_moe_buffers(buffers.reshape(g, e, cap, d))
    h = jnp.einsum("gecd,edf->gecf", bge, p["w_gate"])
    h = activation(h) * jnp.einsum("gecd,edf->gecf", bge, p["w_up"])
    y = jnp.einsum("gecf,efd->gecd", h, p["w_down"])       # [G, E, C, D]
    y = constrain_moe_buffers(y).reshape(g * e, cap, d)

    # Gather back + combine with routing weights.
    out_choices = y[flat_ge, flat_pos]                     # [T*k, D]
    out_choices = jnp.where(keep[:, None], out_choices, 0.0)
    out = (out_choices.reshape(t, k, d)
           * w[..., None].astype(xt.dtype)).sum(axis=1)

    if "shared" in p:
        sp = p["shared"]
        sh = activation(xt @ sp["w_gate"]) * (xt @ sp["w_up"])
        out = out + sh @ sp["w_down"]

    return out.reshape(b, s, d), aux
