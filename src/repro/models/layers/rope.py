"""Rotary position embeddings, including partial-rotary variants.

``rope_fraction`` controls the rotated share of each head:
  1.0  — full RoPE (llama/mistral lineage)
  0.5  — chatglm's "2d-RoPE" (rotate the first half, pass the rest through)
  0.25 — stablelm partial rotary
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rope_angles(positions: jax.Array, rot_dim: int, theta: float) -> tuple:
    """(sin, cos) of shape [..., rot_dim/2] for integer positions [...]."""
    half = rot_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs  # [..., half]
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(
    x: jax.Array,             # [..., seq, heads, head_dim]
    positions: jax.Array,     # [..., seq]
    theta: float,
    fraction: float = 1.0,
) -> jax.Array:
    """Rotate the leading ``fraction`` of each head's dims; pass the rest."""
    if theta <= 0.0 or fraction <= 0.0:
        return x
    head_dim = x.shape[-1]
    rot = int(head_dim * fraction)
    rot -= rot % 2
    if rot == 0:
        return x
    sin, cos = rope_angles(positions, rot, theta)          # [..., seq, rot/2]
    sin = sin[..., None, :]                                # broadcast heads
    cos = cos[..., None, :]
    xr, xp = x[..., :rot], x[..., rot:]
    x1, x2 = xr[..., : rot // 2], xr[..., rot // 2:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1.astype(x.dtype), y2.astype(x.dtype), xp], axis=-1)


def sinusoidal_positions(max_len: int, d_model: int) -> jax.Array:
    """Whisper-style fixed sinusoidal position table [max_len, d_model]."""
    pos = jnp.arange(max_len, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d_model // 2, dtype=jnp.float32)[None, :]
    inv = jnp.exp(-jnp.log(10000.0) * dim / (d_model // 2))
    ang = pos * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
