"""Selective SSM (Mamba-style) head block — used by hymba's parallel branch.

Implements the selective state-space recurrence with input-dependent
(Delta, B, C):

    h_t = exp(Delta_t * A) * h_{t-1} + Delta_t * B_t * x_t
    y_t = C_t . h_t + D * x_t

with A diagonal (negative), a depthwise causal conv front-end, and SiLU
gating, following Mamba.  Sequence processing uses a chunked
``lax.scan``-of-parallel-prefix: within a chunk the recurrence is computed
with an associative scan over the time axis (O(log C) depth); chunks carry
the state — so prefill is fast and decode is O(1) per token.

State cache per layer: (conv tail [B, W-1, d_inner], ssm state
[B, d_inner, N]) — constant in sequence length, which is what makes the
long_500k dry-run cell runnable for the hybrid arch.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import SSMConfig


class SSMCache(NamedTuple):
    conv: jax.Array    # [B, W-1, d_inner] last conv-window inputs
    state: jax.Array   # [B, d_inner, N] ssm state
    length: jax.Array  # [] int32


def ssm_init(key, d_model: int, cfg: SSMConfig, dtype=jnp.float32) -> dict:
    d_inner = cfg.expand * d_model
    dt_rank = cfg.dt_rank or max(d_model // 16, 1)
    ks = jax.random.split(key, 7)
    init = lambda k, shape, fan: jax.random.normal(k, shape, dtype) * (fan ** -0.5)
    # S4D-real initialization for A.
    a = jnp.tile(jnp.arange(1, cfg.state_dim + 1, dtype=jnp.float32)[None, :],
                 (d_inner, 1))
    return {
        "w_in": init(ks[0], (d_model, 2 * d_inner), d_model),   # x and gate z
        "conv_w": init(ks[1], (cfg.conv_width, d_inner), cfg.conv_width),
        "conv_b": jnp.zeros((d_inner,), dtype),
        "w_x_dbc": init(ks[2], (d_inner, dt_rank + 2 * cfg.state_dim), d_inner),
        "w_dt": init(ks[3], (dt_rank, d_inner), dt_rank),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((d_inner,), 0.01, jnp.float32))),
        "a_log": jnp.log(a),
        "d_skip": jnp.ones((d_inner,), jnp.float32),
        "w_out": init(ks[4], (d_inner, d_model), d_inner),
    }


def init_ssm_cache(batch: int, d_model: int, cfg: SSMConfig,
                   dtype=jnp.float32) -> SSMCache:
    d_inner = cfg.expand * d_model
    return SSMCache(
        conv=jnp.zeros((batch, cfg.conv_width - 1, d_inner), dtype),
        state=jnp.zeros((batch, d_inner, cfg.state_dim), dtype),
        length=jnp.zeros((), jnp.int32),
    )


def _causal_conv(x, conv_tail, w, bias):
    """Depthwise causal conv along time.  x: [B,S,Di]; tail: [B,W-1,Di]."""
    width = w.shape[0]
    xx = jnp.concatenate([conv_tail, x], axis=1)           # [B, S+W-1, Di]
    out = sum(
        xx[:, i:i + x.shape[1], :] * w[i][None, None, :] for i in range(width)
    )
    new_tail = xx[:, -(width - 1):, :] if width > 1 else conv_tail
    return out + bias, new_tail


def _selective_scan_chunk(state, dt, a, bx, c):
    """One chunk of the selective recurrence via associative scan.

    state: [B, Di, N]; dt: [B, C, Di]; a: [Di, N];
    bx: [B, C, Di, N] (Delta*B*x); c: [B, C, N].
    Returns (y [B, C, Di], new_state).
    """
    decay = jnp.exp(dt[..., None] * (-jnp.exp(a))[None, None, :, :])  # [B,C,Di,N]

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, b2 + a2 * b1

    acc_decay, acc_b = jax.lax.associative_scan(combine, (decay, bx), axis=1)
    h = acc_decay * state[:, None] + acc_b                  # [B,C,Di,N]
    y = jnp.einsum("bcdn,bcn->bcd", h, c)
    return y, h[:, -1]


def ssm_apply(
    p: dict,
    x: jax.Array,             # [B, S, D]
    cfg: SSMConfig,
    cache: Optional[SSMCache] = None,
    chunk_size: int = 256,
):
    """Returns (y [B,S,D], new_cache_or_None)."""
    b, s, d = x.shape
    d_inner = cfg.expand * d
    dt_rank = p["w_dt"].shape[0]

    xz = x @ p["w_in"]
    xs, z = xz[..., :d_inner], xz[..., d_inner:]

    tail = cache.conv if cache is not None else jnp.zeros(
        (b, p["conv_w"].shape[0] - 1, d_inner), xs.dtype)
    xs, new_tail = _causal_conv(xs, tail, p["conv_w"], p["conv_b"])
    xs = jax.nn.silu(xs)

    dbc = xs @ p["w_x_dbc"]
    dt = jax.nn.softplus(
        dbc[..., :dt_rank] @ p["w_dt"] + p["dt_bias"]
    ).astype(jnp.float32)                                   # [B,S,Di]
    bmat = dbc[..., dt_rank:dt_rank + cfg.state_dim].astype(jnp.float32)
    cmat = dbc[..., dt_rank + cfg.state_dim:].astype(jnp.float32)

    bx = (dt * xs.astype(jnp.float32))[..., None] * bmat[:, :, None, :]  # [B,S,Di,N]

    state = (cache.state.astype(jnp.float32) if cache is not None
             else jnp.zeros((b, d_inner, cfg.state_dim), jnp.float32))

    chunk = min(chunk_size, s)
    if s % chunk:
        # Pad time to a chunk multiple (padded steps have dt=0 -> identity).
        pad = chunk - s % chunk
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bx = jnp.pad(bx, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0)))
    n_chunks = dt.shape[1] // chunk

    def step(st, inp):
        dt_c, bx_c, c_c = inp
        y_c, st_new = _selective_scan_chunk(st, dt_c, p["a_log"], bx_c, c_c)
        return st_new, y_c

    dt_c = dt.reshape(b, n_chunks, chunk, d_inner).transpose(1, 0, 2, 3)
    bx_c = bx.reshape(b, n_chunks, chunk, d_inner, cfg.state_dim).transpose(1, 0, 2, 3, 4)
    c_c = cmat.reshape(b, n_chunks, chunk, cfg.state_dim).transpose(1, 0, 2, 3)
    state, ys = jax.lax.scan(step, state, (dt_c, bx_c, c_c))
    y = ys.transpose(1, 0, 2, 3).reshape(b, -1, d_inner)[:, :s]

    y = y + xs.astype(jnp.float32) * p["d_skip"][None, None, :]
    y = (y.astype(x.dtype) * jax.nn.silu(z)) @ p["w_out"]

    new_cache = None
    if cache is not None:
        new_cache = SSMCache(conv=new_tail.astype(cache.conv.dtype),
                             state=state.astype(cache.state.dtype),
                             length=cache.length + s)
    return y, new_cache
