"""Attention: GQA / MHA with chunked online-softmax (flash-style) compute.

Memory discipline is structural here: scores are never materialized at
[S, S].  The KV sequence is processed in chunks with a running
(max, denominator, accumulator) carry — the pure-JAX analogue of flash
attention, which keeps the per-layer activation footprint at
O(S * chunk) and makes 32k prefill lowerable on the production mesh.

Supports: causal masks, sliding windows (mixtral/hymba), bidirectional
(whisper encoder), cross-attention (whisper decoder), KV-cache decode
(single-token query against a long cache), partial/2d RoPE, qk-norm,
GQA without materializing repeated KV heads.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.common.utils import cdiv
from repro.models.layers.norm import head_rmsnorm
from repro.models.layers.rope import apply_rope

NEG_INF = -1e30


class KVCache(NamedTuple):
    """Ring-buffer KV cache for one attention layer (stacked over layers by
    the model).  ``length`` counts total tokens seen; for windowed layers the
    buffer holds the last ``k.shape[1]`` positions (rolling).

    int8 mode (the paper's 8-bit sign-split representation applied to the
    cache — §Perf decode lever): k/v are stored int8 with one f32 scale per
    (batch, position, kv-head) vector; quantize-on-write, dequantize-on-read
    halves cache HBM traffic, the dominant decode roofline term."""

    k: jax.Array       # [B, S_buf, KVH, hd] (bf16 or int8)
    v: jax.Array       # [B, S_buf, KVH, hd]
    length: jax.Array  # [] int32, tokens written so far
    k_scale: jax.Array | None = None  # [B, S_buf, KVH] f32 (int8 mode)
    v_scale: jax.Array | None = None


def init_kv_cache(batch: int, buf_len: int, kv_heads: int, head_dim: int,
                  dtype=jnp.bfloat16) -> KVCache:
    dtype = jnp.dtype(dtype)
    quant = dtype == jnp.int8
    scale = (jnp.ones((batch, buf_len, kv_heads), jnp.float32)
             if quant else None)
    return KVCache(
        k=jnp.zeros((batch, buf_len, kv_heads, head_dim), dtype),
        v=jnp.zeros((batch, buf_len, kv_heads, head_dim), dtype),
        length=jnp.zeros((), jnp.int32),
        k_scale=scale,
        v_scale=scale,
    )


def _quantize_kv(x: jax.Array):
    """Per-(b, pos, head) vector symmetric int8 quantization."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize_kv(q: jax.Array, scale: jax.Array | None, out_dtype):
    if scale is None:
        return q.astype(out_dtype)
    return (q.astype(jnp.float32) * scale[..., None]).astype(out_dtype)


def chunked_attention(
    q: jax.Array,             # [B, Sq, H, hd]
    k: jax.Array,             # [B, Sk, KVH, hd]
    v: jax.Array,             # [B, Sk, KVH, hd]
    *,
    causal: bool = True,
    window: int = 0,          # 0 = global
    q_offset: jax.Array | int = 0,   # absolute position of q[0]
    k_offset: jax.Array | int = 0,   # absolute position of k[0]
    kv_valid_len: Optional[jax.Array] = None,  # mask cache tail
    chunk_size: int = 1024,
    q_chunk_size: int = 512,
    softcap: float = 0.0,
) -> jax.Array:
    """2-D tiled online-softmax attention.  Returns [B, Sq, H, hd].

    The query axis is tiled with ``lax.map`` (peak activation is one
    [B, q_chunk, H, kv_chunk] score tile, never [S, S]); each query tile
    runs the online-softmax KV scan below.
    """
    b, sq, h, hd = q.shape
    if sq > q_chunk_size:
        n_q = cdiv(sq, q_chunk_size)
        pad_q = n_q * q_chunk_size - sq
        qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        qs = qp.reshape(b, n_q, q_chunk_size, h, hd).transpose(1, 0, 2, 3, 4)
        offs = jnp.asarray(q_offset) + q_chunk_size * jnp.arange(n_q)

        def one(args):
            q_tile, off = args
            return chunked_attention(
                q_tile, k, v, causal=causal, window=window, q_offset=off,
                k_offset=k_offset, kv_valid_len=kv_valid_len,
                chunk_size=chunk_size, q_chunk_size=sq, softcap=softcap)

        out = jax.lax.map(one, (qs, offs))
        return out.transpose(1, 0, 2, 3, 4).reshape(b, -1, h, hd)[:, :sq]
    _, sk, kvh, _ = k.shape
    g = h // kvh
    scale = hd ** -0.5

    chunk = min(chunk_size, sk)
    n_chunks = cdiv(sk, chunk)
    pad = n_chunks * chunk - sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    # [n_chunks, B, C, KVH, hd]
    kc = k.reshape(b, n_chunks, chunk, kvh, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, chunk, kvh, hd).transpose(1, 0, 2, 3, 4)

    qq = (q.astype(jnp.float32) * scale).reshape(b, sq, kvh, g, hd)
    q_pos = jnp.asarray(q_offset) + jnp.arange(sq)          # [Sq]
    valid_total = jnp.asarray(
        kv_valid_len if kv_valid_len is not None else sk, jnp.int32
    )

    def step(carry, inp):
        m, l, acc = carry
        kch, vch, ci = inp
        k_pos = jnp.asarray(k_offset) + ci * chunk + jnp.arange(chunk)  # [C]
        s = jnp.einsum(
            "bqkgd,bckd->bqkgc", qq, kch.astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )                                                   # [B,Sq,KVH,G,C]
        if softcap > 0.0:
            s = softcap * jnp.tanh(s / softcap)
        mask = (ci * chunk + jnp.arange(chunk))[None, :] < valid_total  # [1,C]
        if causal:
            mask = mask & (k_pos[None, :] <= q_pos[:, None])            # [Sq,C]
        if window > 0:
            mask = mask & (k_pos[None, :] > q_pos[:, None] - window)
        mask = jnp.broadcast_to(mask, (sq, chunk))
        s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)

        m_new = jnp.maximum(m, s.max(axis=-1))
        corr = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * corr + p.sum(axis=-1)
        pv = jnp.einsum(
            "bqkgc,bckd->bqkgd", p, vch.astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new), None

    init = (
        jnp.full((b, sq, kvh, g), NEG_INF, jnp.float32),
        jnp.zeros((b, sq, kvh, g), jnp.float32),
        jnp.zeros((b, sq, kvh, g, hd), jnp.float32),
    )
    (m, l, acc), _ = jax.lax.scan(
        step, init, (kc, vc, jnp.arange(n_chunks))
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, sq, h, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# Full attention block (projections + rope + cache management).
# ---------------------------------------------------------------------------


def attn_init(key, cfg, dtype=jnp.float32, cross: bool = False) -> dict:
    """Parameters for one attention block (cfg: ModelConfig)."""
    d, h, kvh, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    init = lambda k, fi, fo: jax.random.normal(k, (fi, fo), dtype) * (fi ** -0.5)
    p = {
        "wq": init(ks[0], d, h * hd),
        "wk": init(ks[1], d, kvh * hd),
        "wv": init(ks[2], d, kvh * hd),
        "wo": init(ks[3], h * hd, d),
    }
    if cfg.attn_bias:
        p["bq"] = jnp.zeros((h * hd,), dtype)
        p["bk"] = jnp.zeros((kvh * hd,), dtype)
        p["bv"] = jnp.zeros((kvh * hd,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def attn_apply(
    cfg,
    p: dict,
    x: jax.Array,                       # [B, S, D]
    positions: jax.Array,               # [S] absolute positions
    *,
    window: int = 0,
    causal: bool = True,
    cache: Optional[KVCache] = None,    # decode/prefill cache (self-attn)
    update_cache: bool = False,
    cross_kv: Optional[tuple] = None,   # (k, v) from encoder (cross-attn)
    chunk_size: int = 1024,
):
    """Returns (out [B,S,D], new_cache_or_None)."""
    b, s, d = x.shape
    h, kvh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim

    q = x @ p["wq"]
    if "bq" in p:
        q = q + p["bq"]
    q = q.reshape(b, s, h, hd)

    if cross_kv is not None:
        k, v = cross_kv
        new_cache = None
        q = q if not cfg.qk_norm else head_rmsnorm(q, p["q_norm"])
        out = chunked_attention(q, k, v, causal=False, chunk_size=chunk_size,
                                softcap=cfg.logit_softcap)
    else:
        k = x @ p["wk"]
        v = x @ p["wv"]
        if "bk" in p:
            k = k + p["bk"]
            v = v + p["bv"]
        k = k.reshape(b, s, kvh, hd)
        v = v.reshape(b, s, kvh, hd)

        if cfg.qk_norm:
            q = head_rmsnorm(q, p["q_norm"])
            k = head_rmsnorm(k, p["k_norm"])
        q = apply_rope(q, positions, cfg.rope_theta, cfg.rope_fraction)
        k = apply_rope(k, positions, cfg.rope_theta, cfg.rope_fraction)

        if cache is None:
            out = chunked_attention(
                q, k, v, causal=causal, window=window,
                q_offset=positions[0], k_offset=positions[0],
                chunk_size=chunk_size, softcap=cfg.logit_softcap,
            )
            new_cache = None
        else:
            buf_len = cache.k.shape[1]
            quantized_cache = cache.k.dtype == jnp.int8
            # Ring-buffer write (rolling for windowed layers).
            idx = (cache.length + jnp.arange(s)) % buf_len
            if quantized_cache:
                kq, ks = _quantize_kv(k)
                vq, vs = _quantize_kv(v)
                k_buf = cache.k.at[:, idx].set(kq)
                v_buf = cache.v.at[:, idx].set(vq)
                new_cache = KVCache(
                    k=k_buf, v=v_buf, length=cache.length + s,
                    k_scale=cache.k_scale.at[:, idx].set(ks),
                    v_scale=cache.v_scale.at[:, idx].set(vs),
                )
            else:
                k_buf = cache.k.at[:, idx].set(k.astype(cache.k.dtype))
                v_buf = cache.v.at[:, idx].set(v.astype(cache.v.dtype))
                new_cache = KVCache(k=k_buf, v=v_buf,
                                    length=cache.length + s)
            new_len = new_cache.length
            if update_cache and s > 1:
                #

                # Prefill: attend within the fresh sequence directly.
                out = chunked_attention(
                    q, k, v, causal=causal, window=window,
                    q_offset=positions[0], k_offset=positions[0],
                    chunk_size=chunk_size, softcap=cfg.logit_softcap,
                )
            else:
                # Decode: attend over the (unrotated) ring buffer.  Buffer
                # slot i holds absolute position: for a full (non-windowed)
                # buffer slots map 1:1; for rolling buffers the oldest
                # ``new_len - buf_len`` positions have been overwritten, and
                # slot p holds position p + buf_len*floor((new_len-1-p)/buf_len)
                # — since attention over a window only needs relative
                # recency, we mask to the last min(new_len, buf_len) tokens.
                k_pos = _ring_positions(new_len, buf_len)
                k_read = _dequantize_kv(k_buf, new_cache.k_scale, q.dtype)
                v_read = _dequantize_kv(v_buf, new_cache.v_scale, q.dtype)
                out = _decode_attention(
                    q, k_read, v_read, k_pos, positions,
                    window=window, softcap=cfg.logit_softcap,
                    chunk_size=chunk_size,
                )

    out = out.reshape(b, s, h * hd) @ p["wo"]
    return out, new_cache


def _ring_positions(length: jax.Array, buf_len: int) -> jax.Array:
    """Absolute position stored in each ring-buffer slot ([buf_len] int32).

    Slot s holds the latest token t with t % buf_len == s and t < length;
    slots not yet written get position -1 (masked by caller via q_pos).
    """
    slots = jnp.arange(buf_len)
    # latest t < length with t ≡ s (mod buf_len)
    last = length - 1 - (length - 1 - slots) % buf_len
    return jnp.where(slots < length, last, -1)


def _decode_attention(q, k_buf, v_buf, k_pos, q_positions, *, window,
                      softcap, chunk_size):
    """Attention of q over a ring buffer with explicit per-slot positions."""
    b, sq, h, hd = q.shape
    kvh = k_buf.shape[2]
    g = h // kvh
    qq = (q.astype(jnp.float32) * hd ** -0.5).reshape(b, sq, kvh, g, hd)
    s = jnp.einsum("bqkgd,bckd->bqkgc", qq, k_buf.astype(jnp.float32),
                   preferred_element_type=jnp.float32)
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)
    q_pos = q_positions  # [Sq]
    mask = (k_pos[None, :] >= 0) & (k_pos[None, :] <= q_pos[:, None])
    if window > 0:
        mask = mask & (k_pos[None, :] > q_pos[:, None] - window)
    s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bqkgc,bckd->bqkgd", p, v_buf.astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    return out.reshape(b, sq, h, hd).astype(q.dtype)
