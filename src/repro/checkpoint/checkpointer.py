"""Sharded, atomic, async checkpointing with elastic restore.

Design (multi-host-shaped, exercised single-host here):

* Each host writes only the array shards it owns (``.addressable_shards``)
  into ``<dir>/step_<n>.tmp/host<k>.npz`` plus a JSON index mapping flat
  parameter paths -> (global shape, dtype, shard indices).  On a single
  host that degenerates to one npz, but the format round-trips the general
  case.
* Commit is an atomic directory rename ``step_<n>.tmp -> step_<n>`` after
  all shards land; a crashed write can never be mistaken for a checkpoint.
* ``save_async`` hands the device->host transfer result to a background
  thread so the train loop overlaps serialization with the next steps
  (fault tolerance requirement: checkpoint cost must not serialize
  training).
* ``restore`` takes the *target* sharding tree — which may be built on a
  DIFFERENT mesh than the save used.  Shards are reassembled to full arrays
  and re-device_put under the new sharding: this is the elastic-rescale
  path (N hosts -> M hosts) and is tested by tests/test_checkpoint.py.
* ``keep_last`` old checkpoints are garbage-collected after commit.
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree):
    # jax.tree.flatten_with_path only exists on newer jax; tree_util's
    # spelling works across the versions this repo supports.
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in p)
             for p, _ in flat]
    return paths, [v for _, v in flat], treedef


class Checkpointer:
    def __init__(self, directory: str, keep_last: int = 3):
        self.directory = directory
        self.keep_last = keep_last
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ---- save ----

    def save(self, step: int, tree: Any, extra: Optional[dict] = None):
        self.wait()
        self._save_sync(step, tree, extra or {})

    def save_async(self, step: int, tree: Any, extra: Optional[dict] = None):
        """Device->host copy happens now; disk IO happens on a thread."""
        self.wait()
        paths, leaves, _ = _flatten(tree)
        host_leaves = [np.asarray(x) for x in leaves]  # sync point
        extra = dict(extra or {})

        def work():
            self._write(step, paths, leaves_np=host_leaves, extra=extra)

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _save_sync(self, step: int, tree: Any, extra: dict):
        paths, leaves, _ = _flatten(tree)
        self._write(step, paths, [np.asarray(x) for x in leaves], extra)

    def _write(self, step: int, paths, leaves_np, extra: dict):
        tmp = os.path.join(self.directory, f"step_{step}.tmp")
        final = os.path.join(self.directory, f"step_{step}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        index = {
            "step": step,
            "extra": extra,
            "params": {
                p: {"shape": list(a.shape), "dtype": str(a.dtype)}
                for p, a in zip(paths, leaves_np)
            },
        }
        np.savez(os.path.join(tmp, "host0.npz"),
                 **{p: a for p, a in zip(paths, leaves_np)})
        with open(os.path.join(tmp, "index.json"), "w") as f:
            json.dump(index, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic commit
        self._gc()

    def _gc(self):
        steps = self.available_steps()
        for s in steps[:-self.keep_last] if self.keep_last else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s}"))

    # ---- restore ----

    def available_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.directory):
            m = re.fullmatch(r"step_(\d+)", name)
            if m and os.path.exists(os.path.join(self.directory, name, "index.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.available_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, target_tree: Any,
                shardings: Any = None) -> tuple[Any, dict]:
        """Restore into the structure of ``target_tree``; if ``shardings``
        (a matching pytree of NamedSharding) is given, arrays are placed
        under it — this is how an elastic restart onto a different mesh
        reshards the state."""
        d = os.path.join(self.directory, f"step_{step}")
        with open(os.path.join(d, "index.json")) as f:
            index = json.load(f)
        data = np.load(os.path.join(d, "host0.npz"))
        paths, leaves, treedef = _flatten(target_tree)
        sh_leaves = None
        if shardings is not None:
            _, sh_leaves, _ = _flatten(shardings)
        out = []
        for i, (p, ref) in enumerate(zip(paths, leaves)):
            if p not in data:
                raise KeyError(f"checkpoint missing parameter '{p}'")
            arr = data[p]
            if tuple(arr.shape) != tuple(ref.shape):
                raise ValueError(
                    f"shape mismatch for '{p}': ckpt {arr.shape} vs target {ref.shape}")
            if sh_leaves is not None:
                out.append(jax.device_put(arr, sh_leaves[i]))
            else:
                out.append(jax.numpy.asarray(arr, dtype=ref.dtype))
        return jax.tree.unflatten(treedef, out), index["extra"]
