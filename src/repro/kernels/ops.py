"""Jit'd public wrappers around the Pallas kernels.

On CPU (this container) the kernels run in ``interpret=True`` mode — the
kernel body executes in Python with the same block/grid semantics, which is
how correctness is validated offline.  On TPU backends the compiled kernels
run natively.  ``auto_interpret()`` picks per backend.

The wrappers also handle padding to tile multiples so callers can pass
arbitrary shapes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.partition import PartitionedGraph
from repro.kernels.block_spmm import block_spmm
from repro.kernels.fused_block_spmm import (
    apply_epilogue_activation,
    fused_block_spmm,
)
from repro.kernels.quant_matmul import quant_matmul
from repro.kernels import ref
from repro.photonic.quant import QuantConfig, compute_scale, quantize, quantize_weights


def auto_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x: jax.Array, axis: int, multiple: int) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("num_dst_groups", "block_f", "interpret"))
def block_spmm_padded(
    blocks: jax.Array,
    block_row: jax.Array,
    block_col: jax.Array,
    feat: jax.Array,
    num_dst_groups: int,
    block_f: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """block_spmm with automatic feature-dim padding.  Returns [G_dst*V, F]."""
    interpret = auto_interpret() if interpret is None else interpret
    f = feat.shape[1]
    featp = _pad_to(feat, 1, block_f)
    out = block_spmm(
        blocks, block_row, block_col, featp, num_dst_groups,
        block_f=block_f, interpret=interpret,
    )
    # Destination groups with no tiles are never visited by the kernel, so
    # their output blocks are uninitialized; zero them here.
    v = blocks.shape[1]
    visited = jnp.zeros((num_dst_groups,), jnp.bool_).at[block_row].set(True)
    out = jnp.where(jnp.repeat(visited, v)[:, None], out, 0.0)
    return out[:, :f]


@functools.partial(
    jax.jit,
    static_argnames=("num_dst_groups", "activation", "reduce", "quantized",
                     "lane", "interpret"),
)
def fused_block_spmm_padded(
    blocks: jax.Array,          # [B, V, N] CSR-row-sorted tiles
    block_row: jax.Array,       # [B] int32, non-decreasing
    block_col: jax.Array,       # [B] int32
    feat: jax.Array,            # [G_src * N, F_in]
    w: jax.Array,               # [F_in, F_out] float weights
    bias: jax.Array | None,     # [F_out] or None
    inv_deg: jax.Array | None,  # [G_dst * V] inverse degrees (MEAN) or None
    num_dst_groups: int,
    activation: str = "none",
    reduce: str = "sum",
    quantized: bool = False,
    lane: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """fused_block_spmm with lane padding + unvisited-row patch-up.

    Pads F_in/F_out to ``lane`` multiples (zero feature columns x zero
    weight rows contribute nothing; padded output columns are sliced off),
    runs the fused kernel, and rewrites never-visited destination groups to
    ``act(bias)`` — the value the unfused oracle assigns to an all-zero
    aggregation row.  ``quantized`` quantizes the float weights here
    (per-output-channel, identical to ``photonic.quant.quantize_weights``)
    and selects the int8 sign-split combine epilogue; see the kernel
    docstring for its documented tolerance vs the per-tensor-scale oracle.
    Returns [G_dst * V, F_out].
    """
    interpret = auto_interpret() if interpret is None else interpret
    f_in, f_out = w.shape
    v = blocks.shape[1]
    featp = _pad_to(feat, 1, lane)
    bias_row = (jnp.zeros((f_out,), feat.dtype) if bias is None
                else bias.astype(feat.dtype))
    biasp = _pad_to(bias_row.reshape(1, f_out), 1, lane)
    apply_deg = inv_deg is not None
    invd = (jnp.ones((num_dst_groups * v, 1), feat.dtype) if not apply_deg
            else inv_deg.reshape(num_dst_groups * v, 1).astype(feat.dtype))

    w_scale = None
    if quantized:
        # Weight quantization matches the unfused oracle exactly (shared
        # scheme); zero-padded int8 rows/columns stay exact no-ops, and
        # padded output channels get scale 0 (sliced off below).
        wq, sw = quantize_weights(w, QuantConfig())
        wp = _pad_to(_pad_to(wq, 0, lane), 1, lane)
        w_scale = _pad_to(sw.reshape(1, f_out).astype(jnp.float32), 1, lane)
    else:
        wp = _pad_to(_pad_to(w, 0, lane), 1, lane)

    out = fused_block_spmm(
        blocks, block_row, block_col, featp, wp, biasp, invd,
        num_dst_groups, activation=activation, apply_deg=apply_deg,
        reduce=reduce, w_scale=w_scale, interpret=interpret,
    )[:, :f_out]
    # Destination groups with no tiles are never visited by the kernel, so
    # their output blocks are uninitialized; the oracle maps their all-zero
    # aggregation rows through the epilogue, i.e. to act(bias) (a zero row
    # quantizes to zeros, so this holds for the int8 epilogue too).
    visited = jnp.zeros((num_dst_groups,), jnp.bool_).at[block_row].set(True)
    fill = apply_epilogue_activation(bias_row.astype(jnp.float32),
                                     activation).astype(out.dtype)
    return jnp.where(jnp.repeat(visited, v)[:, None], out, fill[None, :])


def aggregate_blocked_kernel(pg_or_bg, feat_padded: jax.Array,
                             block_f: int = 128,
                             interpret: bool | None = None) -> jax.Array:
    """GHOST blocked aggregation via the Pallas kernel.

    Accepts a PartitionedGraph (numpy) or BlockedGraph (device) container.
    """
    if isinstance(pg_or_bg, PartitionedGraph):
        blocks = jnp.asarray(pg_or_bg.blocks)
        row = jnp.asarray(pg_or_bg.block_row)
        col = jnp.asarray(pg_or_bg.block_col)
        g_dst = pg_or_bg.num_dst_groups
    else:
        blocks, row, col = pg_or_bg.blocks, pg_or_bg.block_row, pg_or_bg.block_col
        g_dst = pg_or_bg.num_dst_groups
    return block_spmm_padded(blocks, row, col, feat_padded, g_dst,
                             block_f=block_f, interpret=interpret)


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "interpret"),
)
def quantized_matmul_kernel(
    x: jax.Array,          # [M, K] float
    w: jax.Array,          # [K, N] float
    block_m: int = 256,
    block_n: int = 256,
    block_k: int = 256,
    interpret: bool | None = None,
) -> jax.Array:
    """Quantize x (per-tensor) and w (per-channel), multiply on the int8
    kernel, dequantize.  Matches photonic.quant.quantized_matmul numerics."""
    interpret = auto_interpret() if interpret is None else interpret
    cfg = QuantConfig()
    sx = compute_scale(x, axis=None, qmax=cfg.qmax)
    xq = quantize(x, sx, cfg.qmax)
    wq, sw = quantize_weights(w, cfg)

    m, k = xq.shape
    n = wq.shape[1]
    bm, bn, bk = (min(block_m, m), min(block_n, n), min(block_k, k))
    xq = _pad_to(_pad_to(xq, 0, bm), 1, bk)
    wq = _pad_to(_pad_to(wq, 0, bk), 1, bn)
    swp = _pad_to(sw.reshape(-1), 0, bn)

    out = quant_matmul(
        xq, wq,
        jnp.asarray([sx], jnp.float32).reshape(1),
        swp.astype(jnp.float32),
        block_m=bm, block_n=bn, block_k=bk,
        out_dtype=jnp.float32,
        interpret=interpret,
    )
    return out[:m, :n].astype(w.dtype)
