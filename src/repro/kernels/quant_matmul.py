"""Pallas TPU kernel: int8 sign-split quantized matmul (GHOST combine stage).

TPU adaptation of the photonic MR-bank MVM (Section 3.3.2): activations and
weights are 8-bit amplitude levels (sign-split, N_levels = 2^7 per polarity),
products accumulate in the analog domain and a balanced photodetector takes
the signed difference.  On the MXU this is an int8 x int8 -> int32 matmul
with per-output-channel scale recovery — serving fast path for the combine
block and for every LM linear layer with ``quantized=true``.

Tiling: classic (M, N, K) grid with the K loop innermost; the int32
accumulator lives in the revisited output VMEM block; dequantization happens
on the last K step only (the BPD + transimpedance stage), writing float out.

VMEM working set per step: bm x bk int8 + bk x bn int8 + bm x bn int32/f32.
All tile dims default to MXU-aligned 128/256 multiples.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w_ref, sx_ref, sw_ref, out_ref, acc_ref, *, k_steps):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w_ref[...],
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )

    @pl.when(ki == k_steps - 1)
    def _finish():
        # BPD recombination + rescale: per-tensor activation scale x
        # per-output-channel weight scale.
        scale = sx_ref[0] * sw_ref[...]           # [bn]
        out_ref[...] = (acc_ref[...].astype(jnp.float32)
                        * scale[None, :]).astype(out_ref.dtype)


def quant_matmul(
    x_q: jax.Array,        # [M, K] int8 quantized activations
    w_q: jax.Array,        # [K, N] int8 quantized weights
    x_scale: jax.Array,    # [1] f32 per-tensor activation scale
    w_scale: jax.Array,    # [N] f32 per-channel weight scales
    block_m: int = 256,
    block_n: int = 256,
    block_k: int = 256,
    out_dtype=jnp.float32,
    interpret: bool = False,
) -> jax.Array:
    """Tiled int8 matmul with fused dequantization. Returns [M, N] float."""
    m, k = x_q.shape
    k2, n = w_q.shape
    if k != k2:
        raise ValueError(f"contraction mismatch {k} vs {k2}")
    block_m = min(block_m, m)
    block_n = min(block_n, n)
    block_k = min(block_k, k)
    if m % block_m or n % block_n or k % block_k:
        raise ValueError(
            f"shapes ({m},{k})x({k},{n}) not divisible by tiles "
            f"({block_m},{block_n},{block_k}); pad at the call site"
        )
    k_steps = k // block_k
    grid = (m // block_m, n // block_n, k_steps)

    return pl.pallas_call(
        functools.partial(_kernel, k_steps=k_steps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda mi, ni, ki: (mi, ki)),
            pl.BlockSpec((block_k, block_n), lambda mi, ni, ki: (ki, ni)),
            pl.BlockSpec((1,), lambda mi, ni, ki: (0,)),
            pl.BlockSpec((block_n,), lambda mi, ni, ki: (ni,)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda mi, ni, ki: (mi, ni)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.int32)],
        interpret=interpret,
    )(x_q, w_q, x_scale, w_scale)
