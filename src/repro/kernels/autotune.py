"""Shape-class kernel autotuner for the blocked aggregate+combine stage.

GNNBuilder (arXiv 2303.16459) shows that per-model design-space search over
tiling/parallelism parameters is what turns a generic GNN-accelerator
template into a competitive one; the acceleration survey (arXiv 2306.14052)
frames per-shape kernel specialization as the primary software lever.  This
module brings both to the jax_pallas reproduction: instead of the one
hardcoded lowering the fused kernel shipped with (fused epilogue, 128-lane
padding, FLOP-planner order), every *shape class* gets a measured winner
from the configuration space

  * fused epilogue kernel vs unfused (block_spmm + dense/quantized combine)
  * aggregate-first vs combine-first execution order
  * unfused SpMM feature tile width ``block_f``
  * fused-kernel lane padding ``lane``

A shape class is a coarse key over the trace-static call-site description
(``core.aggregate.KernelSite``): tile counts and group counts rounded up to
powers of two — the *same* rounding the serving bucketer applies
(``serving.bucketing.next_pow2``), so one tuned class covers exactly the
sites one ``(model, bucket)`` executor trace produces — plus the raw
``(v, n)`` group geometry, pow2-bucketed feature widths, reduce mode,
dtype, and quantization.

Candidates are timed end-to-end through the public
``aggregate_combine_blocked`` entry (a jit per candidate, warmed up, then
``block_until_ready``-timed), so the numbers include exactly the lowering
serving executes — and the baseline candidate is always the pre-autotune
hardcoded behavior, so a tuned class can never regress it within one
search's timing.

Winners live in an in-process table and persist to a JSON cache stamped
with the jax version and device kind (``jax.devices()[0]``).  A cache
written by a different jax or device is *stale* — kernel timings do not
transfer — and is discarded wholesale on load, triggering a fresh search
(the same trust model the executor pool applies to its traces: winners are
per-environment, keyed per shape class).  Serving warm-starts by pointing
the tuner at the persisted cache: the executor pool resolves configs at
trace-build time (see ``serving.registry.ExecutorPool``), so a warm cache
means zero searches on the serving path.
"""

from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregate import (
    BlockedGraph,
    KernelSite,
    ReduceOp,
    aggregate_backend,
    aggregate_combine_blocked,
    kernel_config_scope,
    with_degrees,
)

CACHE_VERSION = 1

# Tunable tile widths: lane multiples of the fp32 (8, 128) TPU tile.
LANE_CANDIDATES = (128, 256)


def _next_pow2(x: int) -> int:
    """Smallest power of two >= x — mirrors serving.bucketing.next_pow2 so
    shape classes and serving buckets round identically (kept local to
    avoid importing the serving package from the kernel layer)."""
    if x <= 1:
        return 1
    return 1 << (int(x) - 1).bit_length()


@dataclasses.dataclass(frozen=True)
class KernelConfig:
    """One point in the kernel configuration space.

    ``None`` fields keep the call site's default behavior (planner order,
    backend-default fusion, 128-lane tiles) — the duck-typed contract
    ``core.aggregate.kernel_config_scope`` documents.
    """

    fused: Optional[bool] = None
    order: Optional[str] = None       # "aggregate_first" | "combine_first"
    block_f: Optional[int] = None     # unfused SpMM feature tile width
    lane: Optional[int] = None        # fused kernel lane padding
    shard: Optional[str] = None       # "feature" | "none": multi-device
                                      # routing under an active shard_scope
                                      # ("none" pins a site single-device).
                                      # Not searched by the autotuner — a
                                      # deployment-level override, since the
                                      # mesh is chosen per executor pool,
                                      # not per shape class.

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "KernelConfig":
        fields = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in fields})


# The pre-autotune hardcoded behaviors (PR 5): fused 128-lane epilogue for
# linear stages; unfused fallback for MAX and quantized combines.
def baseline_config(shape_class: "ShapeClass") -> KernelConfig:
    pinned = shape_class.reduce == "max" or shape_class.quantized
    if pinned:
        return KernelConfig(fused=False, order="aggregate_first",
                            block_f=128, lane=128)
    return KernelConfig(fused=True, order="aggregate_first",
                        block_f=128, lane=128)


class ShapeClass(NamedTuple):
    """Coarse shape key: pow2-bucketed geometry + reduce/dtype/quant mode."""

    num_blocks: int       # pow2
    num_dst_groups: int   # pow2
    num_src_groups: int   # pow2
    v: int
    n: int
    f_in: int             # pow2
    f_out: int            # pow2
    reduce: str
    dtype: str
    quantized: bool

    @classmethod
    def from_site(cls, site: KernelSite) -> "ShapeClass":
        return cls(
            num_blocks=_next_pow2(site.num_blocks),
            num_dst_groups=_next_pow2(site.num_dst_groups),
            num_src_groups=_next_pow2(site.num_src_groups),
            v=site.v,
            n=site.n,
            f_in=_next_pow2(site.f_in),
            f_out=_next_pow2(site.f_out),
            reduce=site.reduce,
            dtype=site.dtype,
            quantized=bool(site.quantized),
        )

    def key(self) -> str:
        """Stable string key for the persisted cache (executor-trace style:
        one entry per shape class, environment stamped at the cache level)."""
        q = "q8" if self.quantized else "fp"
        return (f"B{self.num_blocks}.D{self.num_dst_groups}"
                f".S{self.num_src_groups}.v{self.v}.n{self.n}"
                f".fi{self.f_in}.fo{self.f_out}.{self.reduce}"
                f".{self.dtype}.{q}")


def candidate_configs(shape_class: ShapeClass,
                      max_candidates: Optional[int] = None
                      ) -> list[KernelConfig]:
    """The search space for one shape class, baseline first.

    Ordering matters twice: the first entry is always the pre-autotune
    hardcoded behavior (so the trajectory records a default-vs-tuned
    comparison), and ``max_candidates`` (the CI smoke budget) truncates
    from the *back*, keeping the baseline and the primary alternative.
    """
    pinned = shape_class.reduce == "max" or shape_class.quantized
    cands = [baseline_config(shape_class)]
    # The primary alternative: flip fused <-> unfused.
    cands.append(dataclasses.replace(cands[0], fused=not cands[0].fused))
    # Wider tiles only when a feature dim actually exceeds one lane tile —
    # otherwise they are pure extra padding.
    if max(shape_class.f_in, shape_class.f_out) > 128:
        cands.append(KernelConfig(fused=True, order="aggregate_first",
                                  block_f=128, lane=256))
    if shape_class.f_in > 128:
        cands.append(KernelConfig(fused=False, order="aggregate_first",
                                  block_f=256, lane=128))
    if not pinned:
        # Order is only searchable for linear stages (MAX / int8 pin it).
        cands.append(KernelConfig(fused=False, order="combine_first",
                                  block_f=128, lane=128))
        if shape_class.f_out > 128:
            cands.append(KernelConfig(fused=False, order="combine_first",
                                      block_f=256, lane=128))
    if max_candidates is not None:
        cands = cands[:max(1, int(max_candidates))]
    return cands


def synthesize_problem(shape_class: ShapeClass, seed: int = 0,
                       tile_density: float = 0.25):
    """A representative problem instance at the class's padded geometry.

    Tiles are CSR-row-sorted (the kernel contract) with random columns and
    Bernoulli entries; features/weights are standard normal.  Structure is
    synthetic but shape-exact, which is what kernel timing keys on.
    """
    rng = np.random.default_rng(seed)
    b = shape_class.num_blocks
    gd, gs = shape_class.num_dst_groups, shape_class.num_src_groups
    v, n = shape_class.v, shape_class.n
    row = np.sort(rng.integers(0, gd, b)).astype(np.int32)
    col = rng.integers(0, gs, b).astype(np.int32)
    vals = (rng.random((b, v, n)) < tile_density).astype(np.float32)
    bg = with_degrees(BlockedGraph(
        blocks=jnp.asarray(vals),
        block_row=jnp.asarray(row),
        block_col=jnp.asarray(col),
        num_dst_groups=gd,
        num_src_groups=gs,
        v=v, n=n, num_nodes=gd * v,
    ))
    featp = jnp.asarray(
        rng.standard_normal((gs * n, shape_class.f_in)).astype(np.float32))
    w = jnp.asarray(
        rng.standard_normal(
            (shape_class.f_in, shape_class.f_out)).astype(np.float32))
    bias = jnp.asarray(
        rng.standard_normal((shape_class.f_out,)).astype(np.float32))
    return bg, featp, w, bias


def _environment() -> dict:
    dev = jax.devices()[0]
    return {
        "cache_version": CACHE_VERSION,
        "jax_version": jax.__version__,
        "device_kind": f"{dev.platform}:{dev.device_kind}",
    }


@dataclasses.dataclass
class AutotuneCache:
    """JSON-persisted winners, keyed by shape-class string.

    The environment stamp (jax version + device kind) gates the whole
    cache: winners measured on another device or jax build are stale and
    discarded on load, forcing a re-search — never silently reused.
    """

    path: Optional[str] = None
    entries: dict = dataclasses.field(default_factory=dict)
    meta: dict = dataclasses.field(default_factory=_environment)
    stale_discarded: bool = False

    @classmethod
    def load(cls, path: Optional[str]) -> "AutotuneCache":
        if path is None:
            return cls()
        try:
            with open(path) as f:
                raw = json.load(f)
        except (FileNotFoundError, json.JSONDecodeError):
            return cls(path=path)
        env = _environment()
        if not isinstance(raw, dict) or any(
                raw.get(k) != env[k] for k in env):
            return cls(path=path, stale_discarded=True)
        entries = {
            key: KernelConfig.from_dict(cfg)
            for key, cfg in raw.get("entries", {}).items()
            if isinstance(cfg, dict)
        }
        return cls(path=path, entries=entries)

    def validate(self) -> "AutotuneCache":
        """Fail-fast schema check (the CI smoke gate)."""
        for key, cfg in self.entries.items():
            if not isinstance(key, str) or not isinstance(cfg, KernelConfig):
                raise ValueError(f"malformed autotune cache entry {key!r}")
            if cfg.fused is None:
                raise ValueError(
                    f"cache entry {key!r} has no fused decision")
        for field in ("jax_version", "device_kind"):
            if not self.meta.get(field):
                raise ValueError(f"autotune cache meta missing {field}")
        return self

    def lookup(self, shape_class: ShapeClass) -> Optional[KernelConfig]:
        return self.entries.get(shape_class.key())

    def store(self, shape_class: ShapeClass, config: KernelConfig) -> None:
        self.entries[shape_class.key()] = config
        self.save()

    def save(self) -> None:
        if self.path is None:
            return
        doc = dict(self.meta)
        doc["entries"] = {
            key: self.entries[key].to_dict()
            for key in sorted(self.entries)
        }
        parent = os.path.dirname(os.path.abspath(self.path))
        os.makedirs(parent, exist_ok=True)
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(doc, f, indent=2, sort_keys=True)
            f.write("\n")
        os.replace(tmp, self.path)


@dataclasses.dataclass
class TuneResult:
    """One search's full trajectory (benchmark/ledger fodder)."""

    shape_class: str
    candidates: list          # [{"config": {...}, "us": float}] in search order
    chosen: dict              # winning config
    baseline_us: float        # the pre-autotune hardcoded behavior's time
    tuned_us: float

    @property
    def speedup_vs_baseline(self) -> float:
        return self.baseline_us / self.tuned_us if self.tuned_us else 0.0

    def to_dict(self) -> dict:
        return {**dataclasses.asdict(self),
                "speedup_vs_baseline": self.speedup_vs_baseline}


class Autotuner:
    """Search + cache + resolver for per-shape-class kernel configs.

    ``resolve`` is the ``kernel_config_scope`` hook: map the call site to
    its shape class, return the cached winner, and (when ``tune_on_miss``)
    run the search for classes never seen.  The executor pool calls
    ``resolve`` for every site of a trace *before* building it (an
    abstract ``eval_shape`` pre-pass records the sites), so searches run
    as plain host-side timing, never inside a jit trace.
    """

    def __init__(
        self,
        cache_path: Optional[str] = None,
        *,
        repeats: int = 3,
        max_candidates: Optional[int] = None,
        tune_on_miss: bool = True,
        seed: int = 0,
    ):
        if repeats < 1:
            raise ValueError("repeats must be >= 1")
        self.cache = AutotuneCache.load(cache_path)
        self.repeats = repeats
        self.max_candidates = max_candidates
        self.tune_on_miss = tune_on_miss
        self.seed = seed
        self.searches = 0                       # searches actually run
        self.trajectory: list[TuneResult] = []  # one entry per search
        self._resolved: dict[str, KernelConfig] = {}  # live (looked-up) configs

    # -- resolver hook ---------------------------------------------------

    def resolve(self, site: KernelSite) -> Optional[KernelConfig]:
        shape_class = ShapeClass.from_site(site)
        config = self.ensure(shape_class)
        if config is not None:
            self._resolved[shape_class.key()] = config
        return config

    def scope(self):
        """Context manager installing this tuner as the active resolver."""
        return kernel_config_scope(self.resolve)

    def live_configs(self) -> dict:
        """Shape-class -> config for every class resolved so far (what the
        serve report surfaces as the live kernel configuration set)."""
        return {key: cfg.to_dict()
                for key, cfg in sorted(self._resolved.items())}

    # -- search ----------------------------------------------------------

    def ensure(self, shape_class: ShapeClass) -> Optional[KernelConfig]:
        """Cached winner, searching on miss (None only with search off)."""
        config = self.cache.lookup(shape_class)
        if config is None and self.tune_on_miss:
            config = self.tune(shape_class)
        return config

    def tune(self, shape_class: ShapeClass) -> KernelConfig:
        """Run the timed search for one shape class and cache the winner."""
        self.searches += 1
        problem = synthesize_problem(shape_class, seed=self.seed)
        candidates = candidate_configs(shape_class, self.max_candidates)
        timed = [(cfg, self._time_candidate(shape_class, cfg, problem))
                 for cfg in candidates]
        best_cfg, best_us = min(timed, key=lambda t: t[1])
        self.trajectory.append(TuneResult(
            shape_class=shape_class.key(),
            candidates=[{"config": cfg.to_dict(), "us": us}
                        for cfg, us in timed],
            chosen=best_cfg.to_dict(),
            baseline_us=timed[0][1],   # candidate 0 is always the baseline
            tuned_us=best_us,
        ))
        self.cache.store(shape_class, best_cfg)
        return best_cfg

    def _time_candidate(self, shape_class: ShapeClass, config: KernelConfig,
                        problem) -> float:
        """Wall time (us) of one jitted aggregate+combine under ``config``.

        Timed through ``block_until_ready`` (completed compute, not async
        dispatch), min over ``repeats`` after a compile warm-up — the same
        discipline as benchmarks/kernel_micro.
        """
        bg, featp, w, bias = problem
        reduce = ReduceOp(shape_class.reduce)
        quantized = shape_class.quantized

        @jax.jit
        def fn(featp, w, bias):
            # Both context managers are trace-time selections: the config
            # and backend bake into this candidate's compiled program.
            with aggregate_backend("pallas_fused"), \
                    kernel_config_scope(lambda site: config):
                return aggregate_combine_blocked(
                    bg, featp, w, bias, reduce=reduce, quantized=quantized)

        jax.block_until_ready(fn(featp, w, bias))  # compile outside timing
        best = float("inf")
        for _ in range(self.repeats):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(featp, w, bias))
            best = min(best, time.perf_counter() - t0)
        return best * 1e6
