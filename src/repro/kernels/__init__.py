# Pallas TPU kernels for the paper's compute hot-spots:
#   block_spmm        — blocked-sparse aggregation (GHOST aggregate stage)
#   fused_block_spmm  — aggregation with the combine matmul (+bias/activation)
#                       fused into the SpMM epilogue, so the aggregated
#                       intermediate never round-trips through HBM
#   quant_matmul      — int8 sign-split MVM (GHOST combine stage)
# ops.py holds the jit'd wrappers (interpret=True on CPU); ref.py the oracles.
# autotune.py searches the fused/unfused config space per shape class and
# persists winners (serving resolves them at trace-build time).
from repro.kernels.ops import (
    aggregate_blocked_kernel,
    block_spmm_padded,
    fused_block_spmm_padded,
    quantized_matmul_kernel,
)
from repro.kernels.autotune import (
    Autotuner,
    AutotuneCache,
    KernelConfig,
    ShapeClass,
    candidate_configs,
    synthesize_problem,
)
