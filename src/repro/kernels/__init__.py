# Pallas TPU kernels for the paper's two compute hot-spots:
#   block_spmm   — blocked-sparse aggregation (GHOST aggregate stage)
#   quant_matmul — int8 sign-split MVM (GHOST combine stage)
# ops.py holds the jit'd wrappers (interpret=True on CPU); ref.py the oracles.
from repro.kernels.ops import (
    aggregate_blocked_kernel,
    block_spmm_padded,
    quantized_matmul_kernel,
)
