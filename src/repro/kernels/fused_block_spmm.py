"""Pallas TPU kernel: fused blocked-sparse aggregation + combine epilogue.

GHOST runs aggregate and combine as separate pipeline stages; the blocked
TPU port in ``block_spmm.py`` mirrors that literally and therefore writes
the aggregated intermediate ``[G_dst*V, F_in]`` to HBM before the dense
combine matmul reads it straight back.  This kernel fuses the combine into
the SpMM epilogue (the standard GNN-accelerator fusion, cf. Zhang et al.
arXiv 2306.14052 / VersaGNN arXiv 2105.01280): the per-row aggregation
accumulator never leaves VMEM, and on the *last* visit to an output row it
is multiplied by the resident weight tile (plus bias and an optional
activation) before the only HBM write — ``[V, F_out]`` instead of
``[V, F_in]`` + a later round-trip.

Dataflow (extends the scalar-prefetch/CSR-sorted ``block_spmm`` design):

* ``block_row`` / ``block_col`` are scalar-prefetched into SMEM; the
  BlockSpec index maps steer the HBM->VMEM DMAs so all-zero adjacency
  tiles are never fetched (GHOST's zero-block skipping).
* Tiles must be CSR-sorted by destination row (``partition_graph``'s
  default fetch order).  Consecutive grid steps that share a destination
  row accumulate into a VMEM *scratch* buffer ``acc[V, F_in]``; the buffer
  is initialized on the first visit to each row (``@pl.when``) and consumed
  by the combine epilogue on the last.
* The weight tile ``[F_in, F_out]`` and bias row use constant index maps,
  so Pallas keeps them VMEM-resident across the whole grid — they are
  DMA'd once, exactly like weights in the canonical fused-matmul pattern.
* MEAN reduction folds in as a per-row scale of the accumulator by the
  precomputed inverse degree (graph-static; see
  ``core.aggregate.blocked_degrees``) *before* the combine matmul, which
  matches the unfused oracle's normalize-then-combine order.

Reduce modes:

* ``"sum"`` (also carrying MEAN via ``apply_deg``): the accumulator is
  zero-initialized and each visit adds one dense tile product.
* ``"max"``: the paper's optical-comparator reduce.  The accumulator is
  initialized to ``-inf`` and each visit merges the masked per-tile
  feature maximum with ``jnp.maximum``; the epilogue rewrites rows that
  never saw an edge (still ``-inf``) to 0, exactly like the comparator
  oracle (no inputs -> no output), before running the same combine.
  Edge multiplicity is irrelevant for MAX, so only the ``blocks != 0``
  mask enters.

Combine epilogues:

* float (default): ``out[r] = act((acc[r] * inv_deg[r]) @ W + bias)``.
* ``quantized`` — the photonic 8-bit sign-split MVM (paper Section 3.3.2),
  reusing ``kernels/quant_matmul.py``'s accumulate-dequantize scheme: the
  weight tile arrives pre-quantized int8 with per-output-channel scales,
  the row-block accumulator is quantized *in the epilogue* with a
  per-row-block symmetric scale (``max|acc| / 127`` over the ``[V, F_in]``
  block — each destination row block is one MR-bank mapping, so the
  amplitude normalization is per mapping), the product accumulates in
  int32 (the photodetector current sum), and dequantization is the
  balanced-photodetector rescale ``s_act * s_w``.

  Numerics contract: the unfused oracle
  (``photonic.quant.quantized_matmul``) uses one *per-tensor* activation
  scale over the whole aggregated matrix, which cannot be known before
  every row finishes aggregating — materializing it is exactly the HBM
  round-trip this kernel exists to remove.  The fused path's per-row-block
  scales are a finer granularity of the same symmetric scheme, so outputs
  agree with the oracle within the int8 quantization error of *both*
  paths:  |fused - unfused|[i, j] <= 0.5 * (s_blk(i) + s_tensor) *
  sum_k |W_deq[k, j]|  (the documented int8 tolerance; both paths share
  identical weight quantization, so only the activation rounding differs).
  tests/test_properties.py checks this bound property-style.

Grid: (num_blocks,).  VMEM working set per step:
  adjacency tile   V x N
  feature tile     N x F_in   (full feature width; the combine epilogue
                               needs the complete row accumulator, so the
                               feature dim is not grid-tiled — when F_in is
                               large the order planner in core.aggregate
                               prefers combine-first and this kernel runs
                               over the narrower F_out instead)
  weight tile      F_in x F_out   (resident; int8 when quantized)
  accumulator      V x F_in       (scratch, fp32)
  output tile      V x F_out

Destination groups with no tiles are never visited; the wrapper in
``kernels.ops`` patches them to ``act(bias)`` — exactly what the unfused
oracle produces for an all-zero aggregation row (in both float and
quantized epilogues: a zero row quantizes to zeros).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Shared vocabulary with the XLA-side _apply_activation (single source of
# truth, so the fused and unfused paths can never drift apart in what they
# accept).  This import cannot cycle: core.aggregate only reaches back into
# kernels lazily, inside functions.
from repro.core.aggregate import EPILOGUE_ACTIVATIONS

FUSED_REDUCES = ("sum", "max")


def apply_epilogue_activation(y: jax.Array, activation: str) -> jax.Array:
    """In-kernel (Pallas-safe) twin of core.aggregate._apply_activation."""
    if activation == "relu":
        return jnp.maximum(y, 0.0)
    if activation == "elu":
        return jnp.where(y > 0.0, y, jnp.expm1(y))
    return y


def _kernel(block_row, block_col, blocks_ref, feat_ref, w_ref, bias_ref,
            invdeg_ref, *refs, num_blocks: int, activation: str,
            apply_deg: bool, reduce: str, quantized: bool):
    if quantized:
        sw_ref, out_ref, acc_ref = refs
    else:
        sw_ref = None
        out_ref, acc_ref = refs
    b = pl.program_id(0)

    first_visit = jnp.logical_or(
        b == 0, block_row[jnp.maximum(b, 1) - 1] != block_row[b]
    )
    # CSR row-sorted tiles: the final grid step is always the last visit to
    # its (maximal) destination row, so clamping the lookahead is safe.
    last_visit = jnp.logical_or(
        b == num_blocks - 1,
        block_row[jnp.minimum(b + 1, num_blocks - 1)] != block_row[b],
    )

    if reduce == "max":
        @pl.when(first_visit)
        def _init():
            acc_ref[...] = jnp.full_like(acc_ref, -jnp.inf)

        # Optical-comparator merge: masked per-tile feature max, then a
        # running maximum across the row's tiles.  Multiplicity does not
        # enter MAX, only edge presence.
        mask = blocks_ref[...] != 0                                # [V, N]
        cand = jnp.where(
            mask[:, :, None],
            feat_ref[...][None, :, :].astype(jnp.float32),         # [1,N,F]
            -jnp.inf,
        )
        acc_ref[...] = jnp.maximum(acc_ref[...], cand.max(axis=1))
    else:
        @pl.when(first_visit)
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        acc_ref[...] += jnp.dot(
            blocks_ref[...],
            feat_ref[...].astype(blocks_ref.dtype),
            preferred_element_type=jnp.float32,
        ).astype(acc_ref.dtype)

    @pl.when(last_visit)
    def _combine():
        acc = acc_ref[...]
        if reduce == "max":
            # Rows with tiles but no in-tile edges never merged a finite
            # candidate; the comparator oracle maps them to 0.
            acc = jnp.where(jnp.isfinite(acc), acc, 0.0)
        if apply_deg:  # MEAN: normalize before combine, like the oracle
            acc = acc * invdeg_ref[...]
        if quantized:
            # Photonic sign-split MVM: symmetric int8 quantization of the
            # row-block accumulator (per-mapping amplitude scale), int32
            # accumulation, BPD recombination + transimpedance rescale.
            s_act = jnp.maximum(jnp.max(jnp.abs(acc)), 1e-12) / 127.0
            q = jnp.clip(jnp.round(acc / s_act), -127.0, 127.0
                         ).astype(jnp.int8)
            prod = jax.lax.dot_general(
                q, w_ref[...],
                (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.int32,
            )
            y = prod.astype(jnp.float32) * s_act * sw_ref[...]
        else:
            y = jnp.dot(acc, w_ref[...].astype(acc.dtype),
                        preferred_element_type=jnp.float32)
        y = y + bias_ref[...].astype(y.dtype)
        out_ref[...] = apply_epilogue_activation(y, activation).astype(
            out_ref.dtype)


def fused_block_spmm(
    blocks: jax.Array,      # [B, V, N] tile values (CSR-sorted by row)
    block_row: jax.Array,   # [B] int32 destination-group ids (non-decreasing)
    block_col: jax.Array,   # [B] int32 source-group ids
    feat: jax.Array,        # [G_src * N, F_in] padded source features
    w: jax.Array,           # [F_in, F_out] combine weights (int8 if quantized)
    bias: jax.Array,        # [1, F_out] combine bias (zeros when unused)
    inv_deg: jax.Array,     # [G_dst * V, 1] inverse degrees (ones for SUM)
    num_dst_groups: int,
    activation: str = "none",
    apply_deg: bool = False,
    reduce: str = "sum",
    w_scale: jax.Array | None = None,  # [1, F_out] dequant scales (quantized)
    interpret: bool = False,
) -> jax.Array:
    """Fused out[r*V:(r+1)*V] = act(epilogue(reduce_b blocks[b] @ feat_tile)).

    Returns [num_dst_groups * V, F_out].  Feature/weight dims must already
    be lane-padded (see ops.fused_block_spmm_padded for the padding and the
    unvisited-row patch-up).  ``w_scale`` present selects the int8
    quantized combine epilogue; ``w`` must then be the int8 weight tile.
    """
    num_blocks, v, n = blocks.shape
    f_in = feat.shape[1]
    f_out = w.shape[1]
    quantized = w_scale is not None
    if w.shape[0] != f_in:
        raise ValueError(f"weight rows {w.shape[0]} != feature dim {f_in}")
    if feat.shape[0] % n:
        raise ValueError("feat rows must be a multiple of the tile width N")
    if activation not in EPILOGUE_ACTIVATIONS:
        raise ValueError(f"unknown epilogue activation '{activation}'; "
                         f"expected one of {EPILOGUE_ACTIVATIONS}")
    if reduce not in FUSED_REDUCES:
        raise ValueError(f"unknown fused reduce '{reduce}'; "
                         f"expected one of {FUSED_REDUCES}")
    if reduce == "max" and apply_deg:
        raise ValueError("MAX reduce has no degree normalization")
    if quantized and w.dtype != jnp.int8:
        raise ValueError("quantized epilogue expects int8 weights "
                         f"(got {w.dtype}); quantize at the call site")

    # Roofline accounting for the scheduler: one SpMM visit per tile plus
    # one combine matmul per destination row (num_dst_groups upper bound).
    w_bytes = 1 if quantized else 4
    cost = pl.CostEstimate(
        flops=2 * num_blocks * v * n * f_in
        + 2 * num_dst_groups * v * f_in * f_out,
        bytes_accessed=(4 * num_blocks * (v * n + n * f_in)
                        + w_bytes * f_in * f_out
                        + 4 * num_dst_groups * v * f_out),
        transcendentals=0,
    )

    kernel = functools.partial(_kernel, num_blocks=num_blocks,
                               activation=activation, apply_deg=apply_deg,
                               reduce=reduce, quantized=quantized)
    in_specs = [
        pl.BlockSpec((None, v, n), lambda b, br, bc: (b, 0, 0)),
        pl.BlockSpec((n, f_in), lambda b, br, bc: (bc[b], 0)),
        pl.BlockSpec((f_in, f_out), lambda b, br, bc: (0, 0)),
        pl.BlockSpec((1, f_out), lambda b, br, bc: (0, 0)),
        pl.BlockSpec((v, 1), lambda b, br, bc: (br[b], 0)),
    ]
    operands = [feat, w, bias, inv_deg]
    if quantized:
        in_specs.append(pl.BlockSpec((1, f_out), lambda b, br, bc: (0, 0)))
        operands.append(w_scale)

    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(num_blocks,),
            in_specs=in_specs,
            out_specs=pl.BlockSpec(
                (v, f_out), lambda b, br, bc: (br[b], 0)
            ),
            scratch_shapes=[pltpu.VMEM((v, f_in), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((num_dst_groups * v, f_out),
                                       feat.dtype),
        cost_estimate=cost,
        interpret=interpret,
    )(block_row, block_col, blocks, *operands)
    return out
