"""Pallas TPU kernel: fused blocked-sparse aggregation + combine epilogue.

GHOST runs aggregate and combine as separate pipeline stages; the blocked
TPU port in ``block_spmm.py`` mirrors that literally and therefore writes
the aggregated intermediate ``[G_dst*V, F_in]`` to HBM before the dense
combine matmul reads it straight back.  This kernel fuses the combine into
the SpMM epilogue (the standard GNN-accelerator fusion, cf. Zhang et al.
arXiv 2306.14052 / VersaGNN arXiv 2105.01280): the per-row aggregation
accumulator never leaves VMEM, and on the *last* visit to an output row it
is multiplied by the resident weight tile (plus bias and an optional
activation) before the only HBM write — ``[V, F_out]`` instead of
``[V, F_in]`` + a later round-trip.

Dataflow (extends the scalar-prefetch/CSR-sorted ``block_spmm`` design):

* ``block_row`` / ``block_col`` are scalar-prefetched into SMEM; the
  BlockSpec index maps steer the HBM->VMEM DMAs so all-zero adjacency
  tiles are never fetched (GHOST's zero-block skipping).
* Tiles must be CSR-sorted by destination row (``partition_graph``'s
  default fetch order).  Consecutive grid steps that share a destination
  row accumulate into a VMEM *scratch* buffer ``acc[V, F_in]``; the buffer
  is zeroed on the first visit to each row (``@pl.when``) and consumed by
  the combine epilogue on the last.
* The weight tile ``[F_in, F_out]`` and bias row use constant index maps,
  so Pallas keeps them VMEM-resident across the whole grid — they are
  DMA'd once, exactly like weights in the canonical fused-matmul pattern.
* MEAN reduction folds in as a per-row scale of the accumulator by the
  precomputed inverse degree (graph-static; see
  ``core.aggregate.blocked_degrees``) *before* the combine matmul, which
  matches the unfused oracle's normalize-then-combine order.

Grid: (num_blocks,).  VMEM working set per step:
  adjacency tile   V x N
  feature tile     N x F_in   (full feature width; the combine epilogue
                               needs the complete row accumulator, so the
                               feature dim is not grid-tiled — when F_in is
                               large the order planner in core.aggregate
                               prefers combine-first and this kernel runs
                               over the narrower F_out instead)
  weight tile      F_in x F_out   (resident)
  accumulator      V x F_in       (scratch, fp32)
  output tile      V x F_out

The epilogue math per destination row r:

  out[r] = act( (acc[r] * inv_deg[r]) @ W + bias )

Destination groups with no tiles are never visited; the wrapper in
``kernels.ops`` patches them to ``act(bias)`` — exactly what the unfused
oracle produces for an all-zero aggregation row.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Shared vocabulary with the XLA-side _apply_activation (single source of
# truth, so the fused and unfused paths can never drift apart in what they
# accept).  This import cannot cycle: core.aggregate only reaches back into
# kernels lazily, inside functions.
from repro.core.aggregate import EPILOGUE_ACTIVATIONS


def apply_epilogue_activation(y: jax.Array, activation: str) -> jax.Array:
    """In-kernel (Pallas-safe) twin of core.aggregate._apply_activation."""
    if activation == "relu":
        return jnp.maximum(y, 0.0)
    if activation == "elu":
        return jnp.where(y > 0.0, y, jnp.expm1(y))
    return y


def _kernel(block_row, block_col, blocks_ref, feat_ref, w_ref, bias_ref,
            invdeg_ref, out_ref, acc_ref, *, num_blocks: int,
            activation: str, apply_deg: bool):
    b = pl.program_id(0)

    first_visit = jnp.logical_or(
        b == 0, block_row[jnp.maximum(b, 1) - 1] != block_row[b]
    )
    # CSR row-sorted tiles: the final grid step is always the last visit to
    # its (maximal) destination row, so clamping the lookahead is safe.
    last_visit = jnp.logical_or(
        b == num_blocks - 1,
        block_row[jnp.minimum(b + 1, num_blocks - 1)] != block_row[b],
    )

    @pl.when(first_visit)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        blocks_ref[...],
        feat_ref[...].astype(blocks_ref.dtype),
        preferred_element_type=jnp.float32,
    ).astype(acc_ref.dtype)

    @pl.when(last_visit)
    def _combine():
        acc = acc_ref[...]
        if apply_deg:  # MEAN: normalize before combine, like the oracle
            acc = acc * invdeg_ref[...]
        y = jnp.dot(acc, w_ref[...].astype(acc.dtype),
                    preferred_element_type=jnp.float32)
        y = y + bias_ref[...].astype(y.dtype)
        out_ref[...] = apply_epilogue_activation(y, activation).astype(
            out_ref.dtype)


def fused_block_spmm(
    blocks: jax.Array,      # [B, V, N] tile values (CSR-sorted by row)
    block_row: jax.Array,   # [B] int32 destination-group ids (non-decreasing)
    block_col: jax.Array,   # [B] int32 source-group ids
    feat: jax.Array,        # [G_src * N, F_in] padded source features
    w: jax.Array,           # [F_in, F_out] combine weights
    bias: jax.Array,        # [1, F_out] combine bias (zeros when unused)
    inv_deg: jax.Array,     # [G_dst * V, 1] inverse degrees (ones for SUM)
    num_dst_groups: int,
    activation: str = "none",
    apply_deg: bool = False,
    interpret: bool = False,
) -> jax.Array:
    """Fused out[r*V:(r+1)*V] = act((sum_b blocks[b] @ feat_tile) @ W + bias).

    Returns [num_dst_groups * V, F_out].  Feature/weight dims must already
    be lane-padded (see ops.fused_block_spmm_padded for the padding and the
    unvisited-row patch-up).
    """
    num_blocks, v, n = blocks.shape
    f_in = feat.shape[1]
    f_out = w.shape[1]
    if w.shape[0] != f_in:
        raise ValueError(f"weight rows {w.shape[0]} != feature dim {f_in}")
    if feat.shape[0] % n:
        raise ValueError("feat rows must be a multiple of the tile width N")
    if activation not in EPILOGUE_ACTIVATIONS:
        raise ValueError(f"unknown epilogue activation '{activation}'; "
                         f"expected one of {EPILOGUE_ACTIVATIONS}")

    # Roofline accounting for the scheduler: one SpMM visit per tile plus
    # one combine matmul per destination row (num_dst_groups upper bound).
    cost = pl.CostEstimate(
        flops=2 * num_blocks * v * n * f_in
        + 2 * num_dst_groups * v * f_in * f_out,
        bytes_accessed=4 * (num_blocks * (v * n + n * f_in)
                            + f_in * f_out + num_dst_groups * v * f_out),
        transcendentals=0,
    )

    kernel = functools.partial(_kernel, num_blocks=num_blocks,
                               activation=activation, apply_deg=apply_deg)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(num_blocks,),
            in_specs=[
                pl.BlockSpec((None, v, n), lambda b, br, bc: (b, 0, 0)),
                pl.BlockSpec((n, f_in), lambda b, br, bc: (bc[b], 0)),
                pl.BlockSpec((f_in, f_out), lambda b, br, bc: (0, 0)),
                pl.BlockSpec((1, f_out), lambda b, br, bc: (0, 0)),
                pl.BlockSpec((v, 1), lambda b, br, bc: (br[b], 0)),
            ],
            out_specs=pl.BlockSpec(
                (v, f_out), lambda b, br, bc: (br[b], 0)
            ),
            scratch_shapes=[pltpu.VMEM((v, f_in), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((num_dst_groups * v, f_out),
                                       feat.dtype),
        cost_estimate=cost,
        interpret=interpret,
    )(block_row, block_col, blocks, feat, w, bias, inv_deg)
    return out
