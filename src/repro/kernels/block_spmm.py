"""Pallas TPU kernel: blocked-sparse aggregation (GHOST aggregate stage).

This is the TPU adaptation of the paper's V x N partitioned aggregation
(Sections 3.3.1 + 3.4.1): only non-zero adjacency tiles are visited, each
tile contributes a dense (V x N) @ (N x F) product, and partial sums
accumulate per destination group — the coherent-summation MR array's job,
mapped onto the MXU.

Key TPU-native design decisions (HW codesign, not a port):

* The non-zero tile list is *scalar-prefetched* (``num_scalar_prefetch=2``):
  ``block_row``/``block_col`` land in SMEM before the grid runs, and the
  BlockSpec ``index_map``s use them to steer HBM->VMEM DMAs — so zero tiles
  are never fetched, the moral equivalent of GHOST's zero-block skipping at
  the memory system rather than the datapath.
* Tiles are CSR-sorted by destination row.  Consecutive grid steps that hit
  the same output row revisit the same VMEM output block, so accumulation
  happens in VMEM without HBM round-trips; the block is zero-initialized on
  first visit (``@pl.when``).
* The feature dimension is tiled at ``bf`` (lane-dim multiple of 128 on real
  hardware) as the *outer* grid axis and the block list as the *inner* axis,
  so for a fixed feature tile the row-sorted blocks stream through and every
  output block's accumulation steps are consecutive — the same constraint the
  canonical Pallas matmul uses for its K loop (an output block must not be
  left and revisited).

Grid: (F // bf, num_blocks).  VMEM working set per step:
  blocks tile  V x N
  feature tile N x bf
  output tile  V x bf

This kernel writes the aggregated intermediate [G_dst*V, F] to HBM, which
the combine matmul then reads straight back.  When a combine follows the
aggregation, prefer ``fused_block_spmm`` (same scalar-prefetch/CSR-sorted
design, combine folded into the epilogue so the accumulator never leaves
VMEM) via ``core.aggregate.aggregate_combine_blocked``, which also plans
the aggregate-first vs combine-first execution order; this unfused kernel
remains the right tool for bare aggregations (no trailing combine), for
MAX-adjacent paths, and as the combine-first order's SpMM over F_out.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(block_row, block_col, blocks_ref, feat_ref, out_ref):
    b = pl.program_id(1)

    first_visit = jnp.logical_or(
        b == 0, block_row[jnp.maximum(b, 1) - 1] != block_row[b]
    )

    @pl.when(first_visit)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    acc = jnp.dot(
        blocks_ref[...],
        feat_ref[...].astype(blocks_ref.dtype),
        preferred_element_type=jnp.float32,
    )
    out_ref[...] += acc.astype(out_ref.dtype)


def block_spmm(
    blocks: jax.Array,      # [B, V, N] tile values (CSR-sorted by row)
    block_row: jax.Array,   # [B] int32 destination-group ids (non-decreasing)
    block_col: jax.Array,   # [B] int32 source-group ids
    feat: jax.Array,        # [G_src * N, F] padded source features
    num_dst_groups: int,
    block_f: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Blocked SpMM: out[r*V:(r+1)*V] += sum_b blocks[b] @ feat_tile(col_b).

    Returns [num_dst_groups * V, F].  ``feat.shape[1]`` must be a multiple of
    ``block_f`` (pad at the call site; see ops.block_spmm_padded).
    """
    num_blocks, v, n = blocks.shape
    f = feat.shape[1]
    if f % block_f:
        raise ValueError(f"feature dim {f} not a multiple of block_f={block_f}")
    if feat.shape[0] % n:
        raise ValueError("feat rows must be a multiple of the tile width N")

    grid = (f // block_f, num_blocks)

    out = pl.pallas_call(
        _kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((None, v, n), lambda fi, b, br, bc: (b, 0, 0)),
                pl.BlockSpec((n, block_f), lambda fi, b, br, bc: (bc[b], fi)),
            ],
            out_specs=pl.BlockSpec(
                (v, block_f), lambda fi, b, br, bc: (br[b], fi)
            ),
        ),
        out_shape=jax.ShapeDtypeStruct((num_dst_groups * v, f), feat.dtype),
        interpret=interpret,
    )(block_row, block_col, blocks, feat)
    return out
