"""Pure-jnp oracles for the Pallas kernels (the numerics contract).

Every kernel test sweeps shapes/dtypes and asserts allclose against these.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def block_spmm_ref(
    blocks: jax.Array,      # [B, V, N]
    block_row: jax.Array,   # [B]
    block_col: jax.Array,   # [B]
    feat: jax.Array,        # [G_src * N, F]
    num_dst_groups: int,
) -> jax.Array:
    """out[r] = sum_{b: row(b)=r} blocks[b] @ feat[col(b)]  -> [G_dst*V, F]."""
    _, v, n = blocks.shape
    f = feat.shape[1]
    src_tiles = feat.reshape(-1, n, f)[block_col]          # [B, N, F]
    partial = jnp.einsum(
        "bvn,bnf->bvf", blocks, src_tiles.astype(blocks.dtype)
    )
    out = jax.ops.segment_sum(partial, block_row, num_segments=num_dst_groups)
    return out.reshape(num_dst_groups * v, f).astype(feat.dtype)


def quant_matmul_ref(
    x_q: jax.Array,        # [M, K] int8
    w_q: jax.Array,        # [K, N] int8
    x_scale: jax.Array,    # [1] f32
    w_scale: jax.Array,    # [N] f32
    out_dtype=jnp.float32,
) -> jax.Array:
    acc = jax.lax.dot_general(
        x_q, w_q, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32
    )
    return (acc.astype(jnp.float32) * (x_scale[0] * w_scale)[None, :]).astype(out_dtype)
