"""hymba-1.5b [hybrid]: 32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001, ssm_state=16 — parallel attn + mamba heads in each layer.
[arXiv:2411.13676; hf]

Hymba fuses attention heads and SSM (mamba) heads *in parallel* within each
layer (outputs mean-fused after per-branch normalization).  Most layers use
sliding-window attention; three layers (first / middle / last) use global
full attention — which is what keeps long-context decode sub-quadratic.
"""

from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    rope_theta=10_000.0,
    sliding_window=1024,
    global_layer_indices=(0, 15, 31),
    norm="rmsnorm",
    activation="silu",
    glu=True,
    ssm=SSMConfig(state_dim=16, expand=2, conv_width=4),
    source="[arXiv:2411.13676; hf]",
    notes="Meta-token prefix omitted (orthogonal to the backbone shapes); "
          "parallel attn+SSM fusion per layer implemented faithfully.",
).validate()
