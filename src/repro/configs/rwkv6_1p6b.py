"""rwkv6-1.6b [ssm]: 24L d_model=2048 (attention-free) d_ff=7168
vocab=65536 — Finch: data-dependent decay.  [arXiv:2404.05892; unverified]
"""

from repro.configs.base import ModelConfig, RWKVConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    family="ssm",
    num_layers=24,
    d_model=2048,
    num_heads=32,              # wkv heads = d_model / head_dim
    num_kv_heads=32,
    head_dim=64,
    d_ff=7168,
    vocab_size=65536,
    rope_theta=0.0,            # attention-free, no rope
    norm="layernorm",
    norm_bias=True,
    activation="relu",         # rwkv channel-mix uses relu^2
    glu=False,
    rwkv=RWKVConfig(head_dim=64, decay_lora=64, gate_lora=32),
    source="[arXiv:2404.05892; unverified]",
).validate()
