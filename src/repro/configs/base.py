"""Config schema for the LM architecture pool.

One frozen dataclass tree describes every architecture; the model zoo
(`repro.models.model_zoo.build_model`) assembles the computation from it.
All pool entries live in sibling modules (one file per architecture) with the
exact numbers from their public sources.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared_experts: int = 0
    first_dense_layers: int = 0       # deepseek: leading dense FFN layers
    dense_d_ff: int = 0               # d_ff of those dense layers
    capacity_factor: float = 1.25
    router: str = "softmax"           # softmax (mixtral) | sigmoid (deepseek-v3)
    aux_loss_weight: float = 0.0


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 16
    expand: int = 2                   # d_inner = expand * d_model (mamba)
    dt_rank: int = 0                  # 0 -> ceil(d_model / 16)
    conv_width: int = 4


@dataclasses.dataclass(frozen=True)
class RWKVConfig:
    head_dim: int = 64
    decay_lora: int = 64              # LoRA rank for data-dependent decay (w)
    gate_lora: int = 32


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                 # 0 -> d_model // num_heads

    # --- attention flavor ---
    rope_theta: float = 10_000.0
    rope_fraction: float = 1.0        # chatglm 2d-RoPE rotates half the dims
    sliding_window: int = 0           # 0 = global attention
    global_layer_indices: Tuple[int, ...] = ()  # hymba: full-attn layers
    qk_norm: bool = False             # chameleon
    attn_bias: bool = False
    logit_softcap: float = 0.0

    # --- block structure ---
    norm: str = "rmsnorm"             # rmsnorm | layernorm
    norm_bias: bool = False
    mlp_bias: bool = False
    activation: str = "silu"          # silu (SwiGLU) | gelu (plain MLP)
    glu: bool = True                  # gated MLP (SwiGLU) vs 2-matrix MLP
    parallel_block: bool = False      # command-r: attn & mlp in parallel
    tie_embeddings: bool = False

    # --- specialist sub-configs ---
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None   # hymba: parallel attn+mamba heads
    rwkv: Optional[RWKVConfig] = None # rwkv6: attention-free

    # --- encoder-decoder (whisper) ---
    encoder_layers: int = 0
    encoder_frames: int = 1500        # 30 s of audio at 50 Hz post-conv
    cross_attention: bool = False

    # --- training extras ---
    mtp: bool = False                 # deepseek multi-token-prediction head
    mtp_depth: int = 1

    # --- bookkeeping ---
    source: str = ""                  # provenance tag [source; verified-tier]
    notes: str = ""
    dtype: str = "bfloat16"
    kv_cache_dtype: str = ""          # "" = dtype; "int8" = quantized cache
    scan_unroll: bool = False         # unroll layer scans (roofline variants)

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def attention_free(self) -> bool:
        return self.rwkv is not None

    @property
    def subquadratic(self) -> bool:
        """True if long-context decode is sub-quadratic in memory/compute —
        the criterion for running the long_500k shape."""
        if self.rwkv is not None:
            return True
        if self.ssm is not None and self.sliding_window > 0:
            return True  # hymba: SWA + SSM; global layers are few and noted
        return False

    def validate(self) -> "ModelConfig":
        if self.num_heads % max(self.num_kv_heads, 1):
            raise ValueError(f"{self.name}: heads {self.num_heads} not divisible "
                             f"by kv heads {self.num_kv_heads}")
        if self.moe and self.moe.top_k > self.moe.num_experts:
            raise ValueError(f"{self.name}: top_k > num_experts")
        return self

    def scaled(self, **overrides) -> "ModelConfig":
        """A reduced copy for smoke tests (same family/features)."""
        return dataclasses.replace(self, **overrides)


def reduced_for_smoke(cfg: ModelConfig) -> ModelConfig:
    """Shrink any pool config to CPU-smoke size, preserving its structure."""
    kw = dict(
        num_layers=min(cfg.num_layers, 2 if not cfg.global_layer_indices else 3),
        d_model=128,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 2) or 1,
        d_ff=256,
        vocab_size=512,
        head_dim=32,
        dtype="float32",
        encoder_layers=min(cfg.encoder_layers, 2),
        encoder_frames=16,
        sliding_window=min(cfg.sliding_window, 8) if cfg.sliding_window else 0,
        global_layer_indices=(0,) if cfg.global_layer_indices else (),
    )
    if cfg.moe:
        kw["moe"] = dataclasses.replace(
            cfg.moe, num_experts=min(cfg.moe.num_experts, 4),
            top_k=min(cfg.moe.top_k, 2), d_ff_expert=64,
            first_dense_layers=min(cfg.moe.first_dense_layers, 1),
            dense_d_ff=min(cfg.moe.dense_d_ff, 256) or 0,
        )
    if cfg.mla:
        kw["mla"] = dataclasses.replace(
            cfg.mla, q_lora_rank=64, kv_lora_rank=32,
            qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16,
        )
        kw["head_dim"] = 0
    if cfg.ssm:
        kw["ssm"] = dataclasses.replace(cfg.ssm, state_dim=8)
    if cfg.rwkv:
        kw["rwkv"] = dataclasses.replace(cfg.rwkv, head_dim=32, decay_lora=16,
                                         gate_lora=8)
    return cfg.scaled(**kw)
