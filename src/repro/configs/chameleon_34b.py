"""chameleon-34b [vlm]: 48L d_model=8192 64H (GQA kv=8) d_ff=22016
vocab=65536 — early-fusion VQ image tokens, qk-norm.
[arXiv:2405.09818; unverified]

The VQ-VAE image tokenizer is a STUB: images arrive as token ids inside the
unified 65536 vocabulary (early fusion), which is exactly how the backbone
consumes them; input_specs() provides the fused token stream.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    family="vlm",
    num_layers=48,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=22016,
    vocab_size=65536,
    rope_theta=10_000.0,
    qk_norm=True,              # chameleon stabilizes with qk layernorm
    norm="rmsnorm",
    activation="silu",
    glu=True,
    source="[arXiv:2405.09818; unverified]",
    notes="Modality frontend (VQ image tokenizer) is a STUB: early-fusion "
          "token ids in the shared vocab.",
).validate()
