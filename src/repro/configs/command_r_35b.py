"""command-r-35b [dense]: 40L d_model=8192 64H (GQA kv=8) d_ff=22528
vocab=256000 — GQA, no-bias, parallel attn+FFN block.
[hf:CohereForAI/c4ai-command-r-v01; unverified]
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b",
    family="dense",
    num_layers=40,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=22528,
    vocab_size=256000,
    rope_theta=8_000_000.0,
    norm="layernorm",
    norm_bias=False,
    attn_bias=False,
    mlp_bias=False,
    parallel_block=True,       # cohere parallel attention + FFN
    activation="silu",
    glu=True,
    tie_embeddings=True,       # cohere ties input/output embeddings
    source="[hf:CohereForAI/c4ai-command-r-v01; unverified]",
).validate()
