"""whisper-medium [audio]: enc-dec, conv frontend stubbed.

24L d_model=1024 16H (GQA kv=16 = full MHA) d_ff=4096 vocab=51865.
[arXiv:2212.04356; unverified]
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="audio",
    num_layers=24,            # decoder layers
    encoder_layers=24,
    encoder_frames=1500,      # 30 s audio -> 1500 frames post-conv (stubbed)
    cross_attention=True,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=51865,
    rope_theta=0.0,           # whisper uses learned/sinusoidal positions
    norm="layernorm",
    norm_bias=True,
    attn_bias=True,
    mlp_bias=True,
    activation="gelu",
    glu=False,
    source="[arXiv:2212.04356; unverified]",
    notes="Modality frontend (2x conv subsampling) is a STUB: input_specs() "
          "provides precomputed frame embeddings (B, 1500, d_model).",
).validate()
