"""Architecture config registry: ``get_config(name)`` / ``list_configs()``.

The 10 assigned pool architectures plus the paper's own GNN workloads (GNN
configs live in repro.gnn; this registry covers the LM zoo consumed by
``--arch`` on the launchers).
"""

from __future__ import annotations

import importlib

from repro.configs.base import ModelConfig, reduced_for_smoke

_MODULES = {
    "whisper-medium": "repro.configs.whisper_medium",
    "mistral-large-123b": "repro.configs.mistral_large_123b",
    "stablelm-12b": "repro.configs.stablelm_12b",
    "command-r-35b": "repro.configs.command_r_35b",
    "chatglm3-6b": "repro.configs.chatglm3_6b",
    "chameleon-34b": "repro.configs.chameleon_34b",
    "hymba-1.5b": "repro.configs.hymba_1p5b",
    "rwkv6-1.6b": "repro.configs.rwkv6_1p6b",
    "mixtral-8x7b": "repro.configs.mixtral_8x7b",
    "deepseek-v3-671b": "repro.configs.deepseek_v3_671b",
}

ARCH_NAMES = tuple(_MODULES)


def get_config(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch '{name}'; options: {sorted(_MODULES)}")
    return importlib.import_module(_MODULES[name]).CONFIG


def get_smoke_config(name: str) -> ModelConfig:
    return reduced_for_smoke(get_config(name))


def list_configs() -> list[ModelConfig]:
    return [get_config(n) for n in ARCH_NAMES]
