"""mixtral-8x7b [moe]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000, MoE 8 experts top-2, SWA.  [arXiv:2401.04088; hf]
"""

from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,                # per-expert ff dim
    vocab_size=32000,
    rope_theta=1_000_000.0,
    sliding_window=4096,
    norm="rmsnorm",
    activation="silu",
    glu=True,
    moe=MoEConfig(
        num_experts=8,
        top_k=2,
        d_ff_expert=14336,
        router="softmax",
    ),
    source="[arXiv:2401.04088; hf]",
).validate()
