"""deepseek-v3-671b [moe]: 61L d_model=7168 128H (MLA) d_ff=2048 (per routed
expert) vocab=129280, MoE 1 shared + 256 routed top-8, MTP.
[arXiv:2412.19437; hf]

MLA per the paper: q_lora_rank=1536, kv_lora_rank=512, qk_nope=128,
qk_rope=64, v_head=128.  First 3 layers are dense FFN (d_ff=18432).
Router is sigmoid-scored with top-8 renormalization.  MTP depth 1.
"""

from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,          # MLA: kv=128 in the pool spec (no GQA cut)
    d_ff=2048,                 # routed-expert ff dim
    vocab_size=129280,
    rope_theta=10_000.0,
    norm="rmsnorm",
    activation="silu",
    glu=True,
    moe=MoEConfig(
        num_experts=256,
        top_k=8,
        d_ff_expert=2048,
        num_shared_experts=1,
        first_dense_layers=3,
        dense_d_ff=18432,
        router="sigmoid",
    ),
    mla=MLAConfig(
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
    ),
    mtp=True,
    mtp_depth=1,
    source="[arXiv:2412.19437; hf]",
).validate()
