"""chatglm3-6b [dense]: 28L d_model=4096 32H (GQA kv=2) d_ff=13696
vocab=65024 — RoPE 2d (rotary on half the head dims), GQA.
[arXiv:2406.12793; hf]
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b",
    family="dense",
    num_layers=28,
    d_model=4096,
    num_heads=32,
    num_kv_heads=2,
    d_ff=13696,
    vocab_size=65024,
    rope_theta=10_000.0,
    rope_fraction=0.5,         # 2d-RoPE: rotate half of each head's dims
    norm="rmsnorm",
    attn_bias=True,            # chatglm uses qkv bias
    activation="silu",
    glu=True,
    source="[arXiv:2406.12793; hf]",
).validate()
