"""repro — a JAX reproduction + TPU hardware adaptation of GHOST (Afifi et al., 2023).

GHOST is the first silicon-photonic GNN inference accelerator.  This package
implements (a) the paper's GReTA-based GNN dataflow, graph partitioning,
photonic noise/quantization models, and analytic performance simulator, and
(b) a production-grade multi-pod JAX training/serving framework hosting the
assigned LM architecture pool, with Pallas TPU kernels for the paper's two
compute hot-spots (blocked-sparse aggregation and quantized MVM).

Subpackages
-----------
core        GReTA programming model, V x N graph partitioning, phase pipeline.
photonic    Device constants, crosstalk noise models, MR-bank DSE, 8-bit
            sign-split quantization, analytic perf/energy simulator.
gnn         GCN / GraphSAGE / GIN / GAT models, synthetic datasets, trainer.
models      LM architecture zoo (dense / MoE / SSM / hybrid / enc-dec / VLM).
configs     One config per assigned architecture + the paper's GNN configs.
data        Deterministic sharded token pipeline.
optim       AdamW + LR schedules (pure JAX, ZeRO-shardable).
distributed Sharding rules, collective helpers, elastic re-mesh, grad compression.
checkpoint  Sharded, async, atomic checkpointing with elastic restore.
kernels     Pallas TPU kernels (block_spmm, quant_matmul) + jnp oracles.
launch      Production mesh, multi-pod dry-run, train/serve entry points.
roofline    Compiled-HLO roofline analysis (compute / memory / collective).
"""

__version__ = "1.0.0"
