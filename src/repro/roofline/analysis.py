"""Roofline analysis from compiled-HLO artifacts (no hardware required).

Three terms per (arch x shape x mesh) cell, all per-chip (SPMD HLO shapes
are per-partition):

  compute    = HLO_FLOPs / peak_FLOPs            (197 TFLOP/s bf16, v5e-class)
  memory     = HLO_bytes / HBM_bw                (819 GB/s)
  collective = collective_bytes / link_bw        (~50 GB/s/link ICI)

Sources: ``compiled.cost_analysis()`` for FLOPs/bytes; collective bytes are
parsed from the HLO text (result-shape sizes of all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute, including -start forms).

Scan correction: XLA's cost analysis counts a while-loop body ONCE
regardless of trip count (verified empirically — see EXPERIMENTS.md §Dry-run
notes).  Models here scan over layer segments, so the driver lowers
reduced-depth variants and extrapolates:

  corrected = metrics(depth-1 variant)
            + sum_seg (count_seg - 1) * (metrics(seg at depth 2) - metrics(depth-1))

which is exact when per-layer cost within a segment is uniform (it is:
segment = identical layer structure by construction).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Optional

# v5e-class hardware constants (per the assignment).
PEAK_FLOPS = 197e12      # bf16 FLOP/s per chip
HBM_BW = 819e9           # B/s per chip
ICI_LINK_BW = 50e9       # B/s per link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"=\s+((?:\([^)]*\))|(?:[a-z0-9]+\[[\d,]*\][^ ]*))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\("
)


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def parse_collective_bytes(hlo_text: str) -> dict:
    """{collective_kind: per-device result bytes} summed over instructions."""
    out = {k: 0 for k in _COLLECTIVES}
    for m in _INSTR_RE.finditer(hlo_text):
        type_str, kind, _ = m.groups()
        out[kind] += _shape_bytes(type_str)
    return out


@dataclasses.dataclass
class CellMetrics:
    """Raw per-device metrics from one compiled artifact."""

    flops: float
    bytes_accessed: float
    collective: dict                 # kind -> bytes
    temp_bytes: float = 0.0
    argument_bytes: float = 0.0
    output_bytes: float = 0.0

    @property
    def collective_total(self) -> float:
        return float(sum(self.collective.values()))

    def scaled_delta(self, base: "CellMetrics", factor: float) -> "CellMetrics":
        """self + factor * (self - base) element-wise (for scan correction)."""
        coll = {
            k: self.collective[k] + factor * (self.collective[k] - base.collective[k])
            for k in self.collective
        }
        return CellMetrics(
            flops=self.flops + factor * (self.flops - base.flops),
            bytes_accessed=self.bytes_accessed
            + factor * (self.bytes_accessed - base.bytes_accessed),
            collective=coll,
            temp_bytes=self.temp_bytes,
            argument_bytes=self.argument_bytes,
            output_bytes=self.output_bytes,
        )

    @staticmethod
    def accumulate_correction(full: "CellMetrics",
                              base_unrolled: "CellMetrics",
                              seg_variants: list,
                              seg_counts: list) -> "CellMetrics":
        """corrected = full + sum_i (c_i - 1) * (variant_i - base_unrolled).

        ``full`` is the production (rolled-scan) compile, whose cost analysis
        counted each segment body exactly once.  ``base_unrolled`` /
        ``seg_variants`` are fully-unrolled depth-1 / depth-2-on-segment-i
        compiles, so their difference is one true per-layer body cost.
        """
        flops = full.flops
        byt = full.bytes_accessed
        coll = dict(full.collective)
        for variant, count in zip(seg_variants, seg_counts):
            k = count - 1
            flops += k * max(variant.flops - base_unrolled.flops, 0.0)
            byt += k * max(variant.bytes_accessed - base_unrolled.bytes_accessed, 0.0)
            for key in coll:
                coll[key] += k * max(
                    variant.collective[key] - base_unrolled.collective[key], 0.0)
        return CellMetrics(flops=flops, bytes_accessed=byt, collective=coll,
                           temp_bytes=full.temp_bytes,
                           argument_bytes=full.argument_bytes,
                           output_bytes=full.output_bytes)


def metrics_from_compiled(compiled) -> CellMetrics:
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):
        # Older jax returns one cost dict per program.
        ca = ca[0] if ca else {}
    ma = compiled.memory_analysis()
    text = compiled.as_text()
    return CellMetrics(
        flops=float(ca.get("flops", 0.0)),
        bytes_accessed=float(ca.get("bytes accessed", 0.0)),
        collective=parse_collective_bytes(text),
        temp_bytes=float(getattr(ma, "temp_size_in_bytes", 0) or 0),
        argument_bytes=float(getattr(ma, "argument_size_in_bytes", 0) or 0),
        output_bytes=float(getattr(ma, "output_size_in_bytes", 0) or 0),
    )


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops_total: float         # analytic 6ND (or 6 N_active D)
    hlo_flops_per_chip: float
    useful_ratio: float              # model_flops / (hlo_flops * chips)
    bottleneck: str

    @staticmethod
    def from_metrics(m: CellMetrics, model_flops_total: float,
                     num_chips: int) -> "Roofline":
        c = m.flops / PEAK_FLOPS
        mem = m.bytes_accessed / HBM_BW
        coll = m.collective_total / ICI_LINK_BW
        terms = {"compute": c, "memory": mem, "collective": coll}
        bott = max(terms, key=terms.get)
        hlo_total = m.flops * num_chips
        return Roofline(
            compute_s=c, memory_s=mem, collective_s=coll,
            model_flops_total=model_flops_total,
            hlo_flops_per_chip=m.flops,
            useful_ratio=(model_flops_total / hlo_total) if hlo_total else 0.0,
            bottleneck=bott,
        )

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def model_flops(cfg, shape_kind: str, seq_len: int, batch: int,
                tokens_decoded: int = 1) -> float:
    """Analytic MODEL_FLOPS: 6*N*D for training, 2*N*D for inference
    (N = active params, D = tokens processed).

    Enc-dec archs: prefill/decode run the decoder only (the encoder runs
    once at cache-init), so N excludes the encoder stack for those kinds.
    """
    n_active = active_params(cfg)
    if cfg.encoder_layers and shape_kind != "train":
        d = cfg.d_model
        n_active = (cfg.vocab_size * d
                    + cfg.num_layers * (2 * _attn_params(cfg)
                                        + _mlp_params(d, cfg.d_ff, cfg.glu)))
    if shape_kind == "train":
        tokens = seq_len * batch
        return 6.0 * n_active * tokens
    if shape_kind == "prefill":
        tokens = seq_len * batch
        return 2.0 * n_active * tokens
    # decode: one token per sequence per step
    return 2.0 * n_active * batch * tokens_decoded


def active_params(cfg) -> float:
    """Active parameter count (MoE: shared + top-k routed only)."""
    d, v = cfg.d_model, cfg.vocab_size
    total = v * d * (1 if cfg.tie_embeddings else 2)
    if cfg.encoder_layers:
        total += cfg.encoder_layers * _attn_params(cfg)
        total += cfg.encoder_layers * _mlp_params(d, cfg.d_ff, cfg.glu)
        # decoder: self + cross attention + mlp
        total += cfg.num_layers * (2 * _attn_params(cfg)
                                   + _mlp_params(d, cfg.d_ff, cfg.glu))
        return total
    for i in range(cfg.num_layers):
        if cfg.rwkv is not None:
            total += 6 * d * d + 2 * d * cfg.d_ff  # time-mix + channel-mix
            continue
        total += _mla_params(cfg) if cfg.mla else _attn_params(cfg)
        if cfg.ssm is not None:
            di = cfg.ssm.expand * d
            total += d * 2 * di + di * (d // 16 + 2 * cfg.ssm.state_dim) + di * d
        if cfg.moe and i >= cfg.moe.first_dense_layers:
            active_e = cfg.moe.top_k + cfg.moe.num_shared_experts
            total += active_e * _mlp_params(d, cfg.moe.d_ff_expert, cfg.glu)
            total += d * cfg.moe.num_experts  # router
        elif cfg.moe:
            total += _mlp_params(d, cfg.moe.dense_d_ff or cfg.d_ff, cfg.glu)
        else:
            total += _mlp_params(d, cfg.d_ff, cfg.glu)
    return total


def total_params(cfg) -> float:
    """Total parameter count (MoE: all experts)."""
    if not cfg.moe:
        return active_params(cfg)
    d = cfg.d_model
    total = active_params(cfg)
    moe_layers = cfg.num_layers - cfg.moe.first_dense_layers
    extra = (cfg.moe.num_experts - cfg.moe.top_k)
    total += moe_layers * extra * _mlp_params(d, cfg.moe.d_ff_expert, cfg.glu)
    return total


def _attn_params(cfg) -> float:
    d, h, kvh, hd = (cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                     cfg.resolved_head_dim)
    return d * h * hd * 2 + d * kvh * hd * 2


def _mla_params(cfg) -> float:
    d, h, m = cfg.d_model, cfg.num_heads, cfg.mla
    qd = m.qk_nope_head_dim + m.qk_rope_head_dim
    return (d * m.q_lora_rank + m.q_lora_rank * h * qd
            + d * m.kv_lora_rank
            + m.kv_lora_rank * h * (m.qk_nope_head_dim + m.v_head_dim)
            + d * m.qk_rope_head_dim + h * m.v_head_dim * d)


def _mlp_params(d: int, d_ff: int, glu: bool) -> float:
    return d * d_ff * (3 if glu else 2)
