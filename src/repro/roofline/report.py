"""Render the dry-run JSON records into the EXPERIMENTS.md roofline tables.

Usage:
  PYTHONPATH=src python -m repro.roofline.report experiments/dryrun [--mesh single_16x16]
"""

from __future__ import annotations

import argparse
import json
import os
from collections import defaultdict


def load_records(dirname: str) -> list[dict]:
    out = []
    for name in sorted(os.listdir(dirname)):
        if name.endswith(".json"):
            with open(os.path.join(dirname, name)) as f:
                out.append(json.load(f))
    return out


def fmt_s(x: float) -> str:
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.1f}ms"
    return f"{x * 1e6:.0f}us"


def roofline_table(records: list[dict], mesh: str) -> str:
    rows = []
    header = ("| arch | shape | compute | memory | collective | bottleneck | "
              "roofline frac | useful (6ND/HLO) | HBM/dev |")
    sep = "|" + "---|" * 9
    for r in records:
        if r.get("mesh_name") != mesh and r.get("mesh") != mesh:
            continue
        if "skipped" in r:
            rows.append(f"| {r['arch']} | {r['shape']} | — | — | — | SKIP | — | — | — |")
            continue
        if "error" in r:
            rows.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                        f"ERROR: {r['error'][:40]} | — | — | — |")
            continue
        roof = r["roofline"]
        terms = {"compute": roof["compute_s"], "memory": roof["memory_s"],
                 "collective": roof["collective_s"]}
        dom = max(terms.values())
        frac = terms["compute"] / dom if dom else 0.0
        mem = r["memory_analysis"]
        hbm = (mem["temp_bytes"] + r.get("param_bytes_per_device", 0)) / 1e9
        rows.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(roof['compute_s'])} | "
            f"{fmt_s(roof['memory_s'])} | {fmt_s(roof['collective_s'])} | "
            f"{roof['bottleneck']} | {frac:.2f} | "
            f"{min(roof['useful_ratio'], 9.99):.2f} | {hbm:.1f}GB |")
    return "\n".join([header, sep] + rows)


def summary(records: list[dict], mesh: str) -> dict:
    ok = [r for r in records
          if (r.get("mesh_name") == mesh or r.get("mesh") == mesh)]
    done = [r for r in ok if "roofline" in r]
    skipped = [r for r in ok if "skipped" in r]
    errors = [r for r in ok if "error" in r]
    bott = defaultdict(int)
    for r in done:
        bott[r["roofline"]["bottleneck"]] += 1
    return {"cells": len(ok), "compiled": len(done), "skipped": len(skipped),
            "errors": len(errors), "bottlenecks": dict(bott)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("dirname")
    ap.add_argument("--mesh", default="single_16x16")
    args = ap.parse_args()
    records = load_records(args.dirname)
    print(f"## Roofline ({args.mesh})\n")
    print(json.dumps(summary(records, args.mesh)))
    print()
    print(roofline_table(records, args.mesh))


if __name__ == "__main__":
    main()
