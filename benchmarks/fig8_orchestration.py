"""Fig. 8: orchestration & scheduling optimization sensitivity.

Normalized energy for BP / PP / DAC-sharing / WB combinations vs the
unoptimized baseline.  Paper-reported averages: BP+PP+DAC => 4.94x lower
energy, BP+PP+WB => 2.92x.
"""

from __future__ import annotations

import time

from benchmarks.common import cached_json, emit
from repro.gnn import load
from repro.gnn.datasets import TABLE2
from repro.photonic.perf import GhostConfig, GnnModelSpec, OrchFlags, simulate

COMBOS = {
    "baseline": OrchFlags(bp=False, pp=False, dac_sharing=False),
    "BP": OrchFlags(bp=True, pp=False, dac_sharing=False),
    "PP": OrchFlags(bp=False, pp=True, dac_sharing=False),
    "BP+PP": OrchFlags(bp=True, pp=True, dac_sharing=False),
    "BP+DAC": OrchFlags(bp=True, pp=False, dac_sharing=True),
    "BP+PP+DAC": OrchFlags(bp=True, pp=True, dac_sharing=True),
    "BP+PP+WB": OrchFlags(bp=True, pp=True, dac_sharing=False, wb=True),
}


def _workloads(quick: bool):
    if quick:
        pairs = [("gcn", "Cora"), ("gat", "Cora"), ("gin", "Mutag")]
    else:
        pairs = ([(m, d) for m in ("gcn", "sage", "gat")
                  for d in ("Cora", "PubMed", "Citeseer", "Amazon")]
                 + [("gin", d) for d in ("Proteins", "Mutag", "BZR",
                                         "IMDB-binary")])
    out = []
    for m, d in pairs:
        spec = TABLE2[d]
        graphs = (load(d, seed=0) if spec["graphs"] == 1
                  else load(d, seed=0, num_graphs=min(spec["graphs"], 60)))
        builder = {"gcn": GnnModelSpec.gcn, "sage": GnnModelSpec.graphsage,
                   "gat": GnnModelSpec.gat, "gin": GnnModelSpec.gin}[m]
        hidden = 8 if m == "gat" else 64
        out.append((m, d, builder(spec["features"], hidden, spec["labels"]),
                    graphs))
    return out


def run(quick: bool = True):
    cfg = GhostConfig()
    t0 = time.time()

    def compute():
        rows = {}
        for m, d, spec, graphs in _workloads(quick):
            base_e = simulate(spec, graphs, cfg, COMBOS["baseline"], d).energy
            for combo, flags in COMBOS.items():
                e = simulate(spec, graphs, cfg, flags, d).energy
                rows.setdefault(combo, []).append(base_e / e)
        return {combo: sum(v) / len(v) for combo, v in rows.items()}

    ratios = cached_json("fig8" + ("_quick" if quick else ""), compute)
    dt = (time.time() - t0) * 1e6
    for combo, ratio in sorted(ratios.items(), key=lambda kv: -kv[1]):
        paper = {"BP+PP+DAC": 4.94, "BP+PP+WB": 2.92}.get(combo)
        tag = f";paper={paper}x" if paper else ""
        emit(f"fig8/{combo}", dt if combo == "baseline" else 0.0,
             f"energy_reduction={ratio:.2f}x{tag}")
    assert ratios["BP+PP+DAC"] == max(ratios.values()), \
        "BP+PP+DAC must be the best combo (Fig. 8)"
    return ratios
