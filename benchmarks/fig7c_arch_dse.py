"""Fig. 7c + Section 4.3: architecture design-space exploration over
[N, V, R_r, R_c, T_r], objective = mean EPB/GOPS.

Reproduction target: the paper's optimum [20, 20, 18, 7, 17] — we assert the
discovered optimum is in its neighborhood (R_r at the WDM limit, R_c well
below the coherent limit, N=V around 20).
"""

from __future__ import annotations

import time

from benchmarks.common import cached_json, emit
from repro.gnn import load
from repro.gnn.datasets import TABLE2
from repro.photonic.dse import explore
from repro.photonic.perf import GnnModelSpec


def workloads(quick: bool):
    names = ["Cora"] if quick else ["Cora", "PubMed", "Citeseer", "Amazon"]
    out = []
    for ds in names:
        spec = TABLE2[ds]
        g = load(ds, seed=0)
        out.append((GnnModelSpec.gcn(spec["features"], 64, spec["labels"]), g, ds))
        if not quick:
            out.append((GnnModelSpec.gat(spec["features"], 8, spec["labels"]),
                        g, ds))
    return out


def run(quick: bool = True):
    t0 = time.time()

    def compute():
        grid = {
            "n": (12, 16, 20, 24),
            "v": (12, 16, 20, 24),
            "rr": (10, 14, 18),
            "rc": (3, 5, 7, 11, 15, 19),
            "tr": (9, 13, 17, 20),
        }
        top = explore(workloads(quick), grid=grid, top_k=5)
        return [{
            "config": [t.config.n, t.config.v, t.config.rr, t.config.rc,
                       t.config.tr],
            "epb_per_gops": t.mean_epb_per_gops,
            "epb_pj_per_bit": t.mean_epb * 1e12,
            "gops": t.mean_gops,
        } for t in top]

    top = cached_json("fig7c_dse" + ("_quick" if quick else ""), compute)
    dt = (time.time() - t0) * 1e6
    best = top[0]
    emit("fig7c/best_config", dt,
         f"NVRrRcTr={best['config']};epb/gops={best['epb_per_gops']:.3e};"
         f"paper=[20,20,18,7,17]")
    for i, t in enumerate(top[1:4], start=2):
        emit(f"fig7c/rank{i}", 0.0, f"NVRrRcTr={t['config']}")
    return top
