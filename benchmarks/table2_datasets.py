"""Table 2: dataset statistics — verifies the synthetic generators match the
paper's node/edge/feature/label/graph counts."""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.gnn import load
from repro.gnn.datasets import GRAPH_CLASSIFICATION, NODE_CLASSIFICATION, TABLE2


def run(quick: bool = True):
    names = (["Cora", "Mutag"] if quick
             else list(NODE_CLASSIFICATION) + list(GRAPH_CLASSIFICATION))
    for name in names:
        t0 = time.time()
        spec = TABLE2[name]
        if name in NODE_CLASSIFICATION:
            g = load(name, seed=0)
            derived = (f"nodes={g.num_nodes}/{spec['nodes']};"
                       f"edges={g.num_edges}/{spec['edges']};"
                       f"feat={g.num_features}/{spec['features']}")
            assert g.num_nodes == spec["nodes"]
            assert g.num_edges == spec["edges"]
        else:
            graphs = load(name, seed=0, num_graphs=min(spec["graphs"], 80))
            mean_n = np.mean([g.num_nodes for g in graphs])
            mean_e = np.mean([g.num_edges for g in graphs]) / 2  # undirected
            derived = (f"avg_nodes={mean_n:.0f}/{spec['nodes']};"
                       f"avg_und_edges={mean_e:.0f}/{spec['edges']};"
                       f"graphs={len(graphs)}")
        emit(f"table2/{name}", (time.time() - t0) * 1e6, derived)
