"""Serving-throughput benchmark: the engine vs the naive per-request loop.

Emits the usual ``name,us,derived`` CSV lines plus one BENCH JSON document
(req/s, p50/p99 latency, cache hit rate, traces compiled) so the serving
perf trajectory is machine-trackable across PRs:

  BENCH_JSON {"bench": "serving_throughput", ...}

Run:  PYTHONPATH=src python benchmarks/serving_throughput.py [--requests N]
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import numpy as np

try:
    from benchmarks.common import emit
except ModuleNotFoundError:
    # Standalone invocation (python benchmarks/serving_throughput.py):
    # put the repo root on the path so the package import resolves.
    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    from benchmarks.common import emit
from repro.core import Graph, partition_graph, to_blocked
from repro.gnn import build_model
from repro.photonic.perf import GhostConfig, GnnModelSpec
from repro.serving import GnnServeEngine


def _request_stream(num_requests: int, working_set: int, f: int,
                    seed: int = 0) -> list[Graph]:
    rng = np.random.default_rng(seed)
    pool = []
    for _ in range(working_set):
        nv = int(rng.integers(24, 96))
        ne = int(rng.integers(2 * nv, 6 * nv))
        pool.append(Graph(
            edge_src=rng.integers(0, nv, ne).astype(np.int32),
            edge_dst=rng.integers(0, nv, ne).astype(np.int32),
            node_feat=rng.standard_normal((nv, f)).astype(np.float32),
        ).validate())
    return [pool[int(rng.integers(0, working_set))]
            for _ in range(num_requests)]


def _naive_loop(model, params, stream, cfg) -> float:
    """The pre-engine baseline: re-partition + fresh shapes every request."""
    import jax.numpy as jnp

    t0 = time.time()
    for g in stream:
        pg = partition_graph(g, v=cfg.v, n=cfg.n)
        featp = jnp.asarray(pg.pad_features(g.node_feat))
        out = model.apply_blocked(params, to_blocked(pg), featp)
        jax.block_until_ready(out)
    return time.time() - t0


def run(quick: bool = True, requests: int | None = None,
        working_set: int = 10, slots: int = 8, backend: str = "jnp",
        include_naive: bool = True) -> dict:
    requests = requests or (32 if quick else 256)
    f, hidden, classes = 16, 16, 3
    stream = _request_stream(requests, working_set, f)

    model = build_model("gcn", f, classes, hidden=hidden)
    params = model.init(jax.random.PRNGKey(0))
    cfg = GhostConfig()
    spec = GnnModelSpec.gcn(f, hidden, classes)

    engine = GnnServeEngine(model, params, task="node", cfg=cfg, spec=spec,
                            slots=slots, backend=backend,
                            dataset_name="synthetic")
    report = engine.run(stream)
    emit("serving/engine", report.wall_s / requests * 1e6,
         f"req_s={report.req_per_s:.1f};hit={report.cache_hit_rate:.2f};"
         f"traces={report.traces_compiled}")

    doc = {
        "bench": "serving_throughput",
        "requests": requests,
        "working_set": working_set,
        "slots": slots,
        "backend": backend,
        "req_per_s": report.req_per_s,
        "p50_latency_ms": report.p50_latency_ms,
        "p99_latency_ms": report.p99_latency_ms,
        "mean_batch_size": report.mean_batch_size,
        "cache_hit_rate": report.cache_hit_rate,
        "traces_compiled": report.traces_compiled,
        "buckets": report.buckets,
        "hw_req_per_s": report.hw_req_per_s,
        "hw_avg_power_w": report.hw_avg_power_w,
    }
    if include_naive:
        naive_s = _naive_loop(model, params, stream, cfg)
        emit("serving/naive_loop", naive_s / requests * 1e6,
             f"req_s={requests / naive_s:.1f}")
        doc["naive_req_per_s"] = requests / naive_s
        doc["speedup_vs_naive"] = (report.req_per_s * naive_s / requests
                                   if naive_s > 0 else 0.0)
    print("BENCH_JSON " + json.dumps(doc, default=float))
    return doc


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--working-set", type=int, default=10)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--backend", choices=("jnp", "pallas"), default="jnp")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--no-naive", action="store_true",
                    help="skip the naive-loop baseline timing")
    args = ap.parse_args()
    if args.working_set < 1 or args.slots < 1 or (
            args.requests is not None and args.requests < 1):
        ap.error("--requests, --working-set and --slots must be >= 1")
    run(quick=not args.full, requests=args.requests,
        working_set=args.working_set, slots=args.slots,
        backend=args.backend, include_naive=not args.no_naive)


if __name__ == "__main__":
    main()
