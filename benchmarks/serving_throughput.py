"""Serving-throughput benchmark: engine vs naive loop, FIFO vs occupancy,
fused vs unfused Pallas backends.

Three measurements, folded into one BENCH JSON document:

  1. Single-model closed loop (engine vs the naive re-partition-per-request
     baseline) — the PR 3 numbers, kept for trend continuity.
  2. Mixed-catalog open loop: GCN+GAT+SAGE at two feature dims behind one
     engine with a bounded waiting queue, driven by a skewed arrival
     process for a fixed tick budget.  The same trace runs under the FIFO
     and the occupancy-aware scheduler (both engines pre-warmed so jit
     compilation stays out of the timed window); occupancy forms fuller
     batches and therefore serves more requests in the same budget, while
     its age bound keeps the maximum queue wait finite.
  3. Fused-vs-unfused backend A/B: the same closed loop served by
     ``backend="pallas"`` (block_spmm + separate combine) and
     ``backend="pallas_fused"`` (fused aggregate+combine epilogue kernel),
     with the combination-order planner's trace-time decisions attached.

Emits the usual ``name,us,derived`` CSV lines plus a BENCH_JSON line
(``{"bench": "serving_throughput", ..., "mixed": {...},
"fused_vs_unfused": {...}}``) that also persists to BENCH.json at the
repo root, stamped with device kind / jax version / interpret mode (see
benchmarks.common.bench_json).

Run:  PYTHONPATH=src python benchmarks/serving_throughput.py [--requests N]
"""

from __future__ import annotations

import argparse
import os
import sys
import time

import jax
import numpy as np

try:
    from benchmarks.common import bench_json, emit
except ModuleNotFoundError:
    # Standalone invocation (python benchmarks/serving_throughput.py):
    # put the repo root on the path so the package import resolves.
    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    from benchmarks.common import bench_json, emit
from repro.core import (
    Graph,
    clear_planner_log,
    partition_graph,
    planner_decisions,
    to_blocked,
)
from repro.gnn import build_model
from repro.launch.mesh import make_data_mesh
from repro.photonic.perf import GhostConfig, GnnModelSpec
from repro.serving import (
    EngineRouter,
    GnnServeEngine,
    HostGraph,
    make_scheduler,
)


def _graph_pool(count: int, f: int, seed: int) -> list[Graph]:
    rng = np.random.default_rng(seed)
    pool = []
    for _ in range(count):
        nv = int(rng.integers(24, 96))
        ne = int(rng.integers(2 * nv, 6 * nv))
        pool.append(Graph(
            edge_src=rng.integers(0, nv, ne).astype(np.int32),
            edge_dst=rng.integers(0, nv, ne).astype(np.int32),
            node_feat=rng.standard_normal((nv, f)).astype(np.float32),
        ).validate())
    return pool


def _request_stream(num_requests: int, working_set: int, f: int,
                    seed: int = 0) -> list[Graph]:
    rng = np.random.default_rng(seed)
    pool = _graph_pool(working_set, f, seed)
    return [pool[int(rng.integers(0, working_set))]
            for _ in range(num_requests)]


def _naive_loop(model, params, stream, cfg) -> float:
    """The pre-engine baseline: re-partition + fresh shapes every request."""
    import jax.numpy as jnp

    t0 = time.perf_counter()
    for g in stream:
        pg = partition_graph(g, v=cfg.v, n=cfg.n)
        featp = jnp.asarray(pg.pad_features(g.node_feat))
        out = model.apply_blocked(params, to_blocked(pg), featp)
        jax.block_until_ready(out)
    return time.perf_counter() - t0


# ---------------------------------------------------------------------------
# Fused vs unfused Pallas executors: the same closed-loop stream served by a
# backend="pallas" engine (unfused block_spmm + separate combine) and a
# backend="pallas_fused" engine (fused aggregate+combine epilogue kernel with
# combination-order planning).  Both engines are pre-warmed so the timed
# window compares steady-state serving, and the planner's trace-time order
# decisions are snapshotted from the warm-up (traces are cached afterwards).
# CPU note: both backends run the kernels in interpret mode, so the gap
# reflects grid-sweep count + dispatch, not HBM traffic — reported as such.
# ---------------------------------------------------------------------------


def _warmed_engine_run(backend: str, model, params, stream, cfg,
                       slots: int) -> dict:
    engine = GnnServeEngine(cfg=cfg, slots=slots, backend=backend)
    engine.register("gcn", model, params, task="node")
    engine.run(stream)          # warm-up: compile every (bucket) trace
    engine.reset_metrics()
    report = engine.run(stream)
    return {"req_per_s": report.req_per_s,
            "p50_latency_ms": report.p50_latency_ms,
            "traces_compiled": report.traces_compiled}


def run_fused_vs_unfused(requests: int, working_set: int, slots: int,
                         f: int = 136, hidden: int = 136) -> dict:
    # f > one lane tile (128) and hidden >= f so the hot first layer is
    # aggregate-first (fused-kernel territory): the unfused backend sweeps
    # the tile list once per 128-wide feature tile plus a separate combine,
    # the fused backend sweeps it once.  The planner still routes the
    # narrow output layer combine-first on both backends.
    stream = _request_stream(requests, working_set, f, seed=3)
    model = build_model("gcn", f, 3, hidden=hidden)
    params = model.init(jax.random.PRNGKey(1))
    cfg = GhostConfig()

    clear_planner_log()
    results = {}
    for backend in ("pallas", "pallas_fused"):
        results[backend] = _warmed_engine_run(backend, model, params, stream,
                                              cfg, slots)
        emit(f"serving/{backend}",
             0.0 if not results[backend]["req_per_s"] else
             1e6 / results[backend]["req_per_s"],
             f"req_s={results[backend]['req_per_s']:.1f}")
    decisions = planner_decisions()
    return {
        "interpret": True,
        "note": "CPU interpret-mode A/B: the fused epilogue matmuls run "
                "interpreted per destination row while the unfused combine "
                "is one compiled XLA matmul, so ratios near/below 1.0 here "
                "reflect interpreter dispatch, not the HBM-traffic saving "
                "the fusion targets; see kernel_micro BENCH_JSON for the "
                "kernel-level fused-vs-unfused comparison on one shape",
        "requests": requests,
        "pallas": results["pallas"],
        "pallas_fused": results["pallas_fused"],
        "fused_vs_unfused_req_per_s": (
            results["pallas_fused"]["req_per_s"]
            / results["pallas"]["req_per_s"]
            if results["pallas"]["req_per_s"] else 0.0),
        "planner_decisions": decisions,
        "planner_orders": sorted({d["order"] for d in decisions}),
    }


# ---------------------------------------------------------------------------
# Mixed catalog: GCN+GAT+SAGE at two feature dims, FIFO vs occupancy.
# ---------------------------------------------------------------------------

F_SMALL, F_LARGE = 8, 16
CATALOG_WEIGHTS = {"gcn_f8": 0.6, "sage_f8": 0.2, "gat_f16": 0.2}


def _build_catalog():
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    gcn = build_model("gcn", F_SMALL, 3, hidden=8)
    sage = build_model("sage", F_SMALL, 3, hidden=8)
    gat = build_model("gat", F_LARGE, 3, hidden=4, heads=2)
    return {
        "gcn_f8": (gcn, gcn.init(ks[0]), F_SMALL),
        "sage_f8": (sage, sage.init(ks[1]), F_SMALL),
        "gat_f16": (gat, gat.init(ks[2]), F_LARGE),
    }


def _mixed_schedule(num: int, pools: dict, seed: int = 1):
    """Skewed arrival order: 60% of traffic hits the hot model."""
    rng = np.random.default_rng(seed)
    mids = list(CATALOG_WEIGHTS)
    probs = np.array([CATALOG_WEIGHTS[m] for m in mids])
    schedule = []
    for _ in range(num):
        mid = mids[int(rng.choice(len(mids), p=probs))]
        pool = pools[mid]
        schedule.append((mid, pool[int(rng.integers(0, len(pool)))]))
    return schedule


def _mixed_engine(scheduler: str, catalog, slots: int, backend: str,
                  max_waiting: int) -> GnnServeEngine:
    engine = GnnServeEngine(cfg=GhostConfig(), slots=slots, backend=backend,
                            scheduler=scheduler, max_waiting=max_waiting)
    for mid, (model, params, _f) in catalog.items():
        engine.register(mid, model, params, task="node")
    return engine


def _open_loop(engine: GnnServeEngine, pools: dict, schedule,
               ticks: int, arrivals_per_tick: int) -> dict:
    """Warm up (compile every executor), then drive a fixed tick budget."""
    for mid, pool in pools.items():
        for g in pool:
            # Drain per submission: keeps warm-up below any admission bound
            # (a whole pool submitted back-to-back could exceed max_waiting).
            engine.submit(mid, g)
            engine.drain()
    engine.reset_metrics()

    si = 0
    t0 = time.perf_counter()
    for _ in range(ticks):
        for _ in range(arrivals_per_tick):
            if si < len(schedule):
                mid, g = schedule[si]
                si += 1
                engine.try_submit(mid, g)
        engine.step()
    elapsed = time.perf_counter() - t0

    rep = engine.report(elapsed)
    return {
        "scheduler": engine.scheduler.name,
        "served": rep.requests,
        "req_per_s": rep.req_per_s,
        "mean_batch_size": rep.mean_batch_size,
        "max_wait_ticks": rep.max_wait_ticks,
        "admitted": rep.admitted,
        "rejected": rep.rejected,
        "per_model": rep.per_model,
        "traces_compiled": rep.traces_compiled,
    }


def run_mixed(ticks: int, arrivals_per_tick: int, working_set: int,
              slots: int, backend: str, max_waiting: int) -> dict:
    pools = {
        "gcn_f8": _graph_pool(working_set, F_SMALL, seed=10),
        "sage_f8": _graph_pool(working_set, F_SMALL, seed=11),
        "gat_f16": _graph_pool(working_set, F_LARGE, seed=12),
    }
    schedule = _mixed_schedule(ticks * arrivals_per_tick, pools)
    catalog = _build_catalog()

    results = {}
    for scheduler in ("fifo", "occupancy"):
        engine = _mixed_engine(scheduler, catalog, slots, backend,
                               max_waiting)
        results[scheduler] = _open_loop(engine, pools, schedule, ticks,
                                        arrivals_per_tick)
        emit(f"serving/mixed_{scheduler}",
             0.0 if not results[scheduler]["served"] else
             1e6 / results[scheduler]["req_per_s"],
             f"served={results[scheduler]['served']};"
             f"batch={results[scheduler]['mean_batch_size']:.2f};"
             f"max_wait={results[scheduler]['max_wait_ticks']}")

    fifo, occ = results["fifo"], results["occupancy"]
    results["occupancy_vs_fifo_served"] = (
        occ["served"] / fifo["served"] if fifo["served"] else 0.0)
    results["occupancy_vs_fifo_req_per_s"] = (
        occ["req_per_s"] / fifo["req_per_s"] if fifo["req_per_s"] else 0.0)
    results["ticks"] = ticks
    results["arrivals_per_tick"] = arrivals_per_tick
    results["max_waiting"] = max_waiting
    return results


# ---------------------------------------------------------------------------
# Device scaling: the same closed-loop stream served by engines whose
# executor pools partition the combine contraction over 1/2/4/8-device data
# meshes (core.aggregate feature strategy under shard_scope).  On a CPU host
# split into virtual devices (--xla_force_host_platform_device_count=8) the
# devices share the same cores, so req/s is NOT expected to scale — the
# sweep demonstrates the partitioned trace end-to-end and records per-count
# req/s + p99 so real multi-chip hosts have a ledger slot to fill in.
# ---------------------------------------------------------------------------


def run_device_scaling(requests: int, working_set: int, slots: int,
                       counts=(1, 2, 4, 8), f: int = 32,
                       hidden: int = 64) -> dict:
    stream = _request_stream(requests, working_set, f, seed=7)
    model = build_model("gcn", f, 3, hidden=hidden)
    params = model.init(jax.random.PRNGKey(2))
    cfg = GhostConfig()

    visible = len(jax.devices())
    usable = [c for c in counts if c <= visible]
    skipped = [c for c in counts if c > visible]
    if skipped:
        print(f"device_scaling: skipping counts {skipped} "
              f"({visible} devices visible)", flush=True)

    sweep = {}
    top_mesh = None
    for count in usable:
        mesh = make_data_mesh(count) if count > 1 else None
        engine = GnnServeEngine(cfg=cfg, slots=slots, mesh=mesh)
        engine.register("gcn", model, params, task="node")
        engine.run(stream)          # warm-up: compile the sharded trace
        engine.reset_metrics()
        report = engine.run(stream)
        sweep[str(count)] = {
            "num_devices": count,
            "req_per_s": report.req_per_s,
            "p50_latency_ms": report.p50_latency_ms,
            "p99_latency_ms": report.p99_latency_ms,
            "topology": report.topology,
        }
        emit(f"serving/devices_{count}",
             0.0 if not report.req_per_s else 1e6 / report.req_per_s,
             f"req_s={report.req_per_s:.1f};p99={report.p99_latency_ms:.1f}ms")
        if mesh is not None:
            top_mesh = mesh
    doc = {
        "bench": "serving_device_scaling",
        "requests": requests,
        "working_set": working_set,
        "slots": slots,
        "f": f,
        "hidden": hidden,
        "strategy": "feature",
        "counts": usable,
        "sweep": sweep,
        "note": "CPU host-split devices share cores; this sweep validates "
                "the sharded trace end-to-end rather than measuring "
                "multi-chip speedup",
    }
    return bench_json(doc, mesh=top_mesh)


# ---------------------------------------------------------------------------
# Replica router: a skewed hot/cold catalog behind N engine replicas.  The
# hot model registers everywhere (traffic load-balances by queue depth);
# the cold model pins to one replica.  The ledger entry records per-replica
# served counts so placement behavior is visible, not just aggregate req/s.
# ---------------------------------------------------------------------------


def run_router(requests: int, working_set: int, slots: int,
               replicas: int = 2) -> dict:
    hot = build_model("gcn", F_SMALL, 3, hidden=8)
    cold = build_model("sage", F_SMALL, 3, hidden=8)
    ks = jax.random.split(jax.random.PRNGKey(3), 2)
    router = EngineRouter(replicas, cfg=GhostConfig(), slots=slots)
    router.register("hot_gcn", hot, hot.init(ks[0]), hot=True, task="node")
    router.register("cold_sage", cold, cold.init(ks[1]), task="node")

    pools = {
        "hot_gcn": _graph_pool(working_set, F_SMALL, seed=20),
        "cold_sage": _graph_pool(max(2, working_set // 2), F_SMALL, seed=21),
    }
    rng = np.random.default_rng(4)
    stream = []
    for _ in range(requests):
        mid = "hot_gcn" if rng.random() < 0.8 else "cold_sage"
        pool = pools[mid]
        stream.append((mid, pool[int(rng.integers(0, len(pool)))]))

    router.run(stream)          # warm-up: compile every replica's traces
    router.reset_metrics()
    report = router.run(stream)
    per_replica_served = {name: info["served"]
                          for name, info in report.replicas.items()}
    emit("serving/router",
         0.0 if not report.req_per_s else 1e6 / report.req_per_s,
         f"req_s={report.req_per_s:.1f};replicas={per_replica_served}")
    return bench_json({
        "bench": "serving_router",
        "requests": requests,
        "working_set": working_set,
        "slots": slots,
        "num_replicas": replicas,
        "req_per_s": report.req_per_s,
        "p50_latency_ms": report.p50_latency_ms,
        "p99_latency_ms": report.p99_latency_ms,
        "per_model": report.per_model,
        "per_replica_served": per_replica_served,
        "replicas": report.replicas,
        "traces_compiled": report.traces_compiled,
    })


# ---------------------------------------------------------------------------
# Node queries against one large resident HostGraph: the million-node intake
# path.  Per graph size, a skewed (Zipf hot-node) single-seed query stream is
# served open-loop through submit_nodes — each query samples its k-hop
# neighborhood, and hot nodes resample *identical* subgraphs (deterministic
# per-vertex rng), so the partition cache collapses them onto one entry.
# The sweep records queries/s + p99 vs graph size and the subgraph-level
# cache hit counts that make node-query serving viable at all.
# ---------------------------------------------------------------------------


def run_node_queries(sizes=(10_000, 100_000, 1_000_000), queries: int = 48,
                     slots: int = 8, fanouts=(8, 4), avg_degree: int = 6,
                     zipf: float = 1.1, f: int = 16, hidden: int = 16) -> dict:
    model = build_model("sage", f, 3, hidden=hidden)
    params = model.init(jax.random.PRNGKey(5))
    cfg = GhostConfig()
    fan_desc = "x".join("full" if x is None else str(x) for x in fanouts)

    sweep = {}
    for nv in sizes:
        host = HostGraph.synthetic_power_law(
            int(nv), avg_degree=avg_degree, num_features=f, seed=13)
        engine = GnnServeEngine(cfg=cfg, slots=slots)
        engine.register("sage", model, params, task="node")
        engine.register_host_graph("hg", host, fanouts=fanouts, rng_seed=0)

        # Skewed hot-node stream: queries Zipf-concentrate on a hot set, so
        # repeated seeds exercise the subgraph-level partition cache.
        rng = np.random.default_rng(17)
        hot_size = min(int(nv), 10_000)
        p = np.arange(1, hot_size + 1, dtype=np.float64) ** (-zipf)
        p /= p.sum()
        hot_nodes = rng.permutation(int(nv))[:hot_size]
        seeds = hot_nodes[rng.choice(hot_size, size=queries, p=p)]

        # Warm-up: compile the executor traces for the buckets this fanout
        # policy lands in, then measure steady state.
        for s in seeds[: min(slots, queries)]:
            engine.submit_nodes("sage", [int(s)])
        engine.drain()
        engine.reset_metrics()

        t0 = time.perf_counter()
        for i, s in enumerate(seeds):
            engine.submit_nodes("sage", [int(s)])
            if (i + 1) % slots == 0:
                engine.step()
        engine.drain()
        report = engine.report(time.perf_counter() - t0)

        nq = report.node_query_stats
        sweep[str(int(nv))] = {
            "nodes": int(nv),
            "edges": host.num_edges,
            "req_per_s": report.req_per_s,
            "p50_latency_ms": report.p50_latency_ms,
            "p99_latency_ms": report.p99_latency_ms,
            "cache_hits": report.cache_hits,
            "cache_hit_rate": report.cache_hit_rate,
            "sample_p50_ms": nq.get("sample_p50_ms", 0.0),
            "sample_p99_ms": nq.get("sample_p99_ms", 0.0),
            "mean_sampled_nodes": nq.get("mean_sampled_nodes", 0.0),
            "mean_sampled_edges": nq.get("mean_sampled_edges", 0.0),
            "traces_compiled": report.traces_compiled,
        }
        emit(f"serving/node_queries_{int(nv)}",
             0.0 if not report.req_per_s else 1e6 / report.req_per_s,
             f"q_s={report.req_per_s:.1f};"
             f"p99={report.p99_latency_ms:.1f}ms;"
             f"hits={report.cache_hits}")
    return bench_json({
        "bench": "serving_node_queries",
        "queries": queries,
        "slots": slots,
        "fanouts": fan_desc,
        "avg_degree": avg_degree,
        "zipf": zipf,
        "sizes": [int(s) for s in sizes],
        "sweep": sweep,
        "note": "open-loop single-seed node queries against one resident "
                "HostGraph; hot-node Zipf stream -> deterministic resamples "
                "share partition-cache entries (cache_hits are "
                "subgraph-level)",
    })


# ---------------------------------------------------------------------------
# Overload ramp: open-loop Poisson arrivals against the always-on serve loop,
# per arrival rate, per scheduler (fifo / occupancy / deadline).  The catalog
# mixes a hot loose-SLO model with a rare tight-SLO model: FIFO makes the
# tight straggler wait behind the hot backlog, occupancy starves its nearly
# empty group until the age bound, and the deadline scheduler preempts on
# slack — the attainment gap per rate is the ledger claim.  Arrival times are
# pre-generated (one shared schedule per rate, fixed seed) and paced by
# wall-clock sleeps; submission is non-blocking try_submit against a bounded
# queue with deadline-aware shed, so each cell also records where the ramp
# starts shedding.
# ---------------------------------------------------------------------------


def _poisson_schedule(rate_per_s: float, window_s: float, pools: dict,
                      mix: dict, seed: int) -> list:
    """[(arrival_s, model_id, graph)] for one open-loop window."""
    rng = np.random.default_rng(seed)
    mids = list(mix)
    probs = np.array([mix[m] for m in mids])
    schedule, t = [], 0.0
    while True:
        t += float(rng.exponential(1.0 / rate_per_s))
        if t >= window_s:
            return schedule
        mid = mids[int(rng.choice(len(mids), p=probs))]
        pool = pools[mid]
        schedule.append((t, mid, pool[int(rng.integers(0, len(pool)))]))


def _overload_cell(engine: GnnServeEngine, schedule, window_s: float) -> dict:
    """Drive one (scheduler, rate) cell through the running serve loop."""
    engine.reset_metrics()
    engine.start()
    t0 = time.perf_counter()
    for arrival_s, mid, g in schedule:
        lag = arrival_s - (time.perf_counter() - t0)
        if lag > 0:
            time.sleep(lag)
        engine.try_submit(mid, g)     # open loop: overload sheds, never waits
    engine.stop(drain=True)           # leftovers still count against SLOs
    rep = engine.report(time.perf_counter() - t0)
    att = rep.slo_attainment
    return {
        "offered": len(schedule),
        "offered_rate_req_s": len(schedule) / window_s,
        "served": rep.requests,
        "req_per_s": rep.req_per_s,
        "p99_latency_ms": rep.p99_latency_ms,
        "mean_batch_size": rep.mean_batch_size,
        "shed": rep.shed,
        "rejected": rep.rejected,
        "unmeetable": rep.unmeetable,
        "attainment": att.get("attainment", 0.0),
        # Served-but-SLO-missed: the waste service-time admission exists
        # to eliminate (device time spent on answers that arrive late).
        "served_slo_missed": att.get("served", 0) - att.get("met", 0),
        "attainment_per_model": {
            m: v["attainment"] for m, v in att.get("per_model", {}).items()},
        "p99_over_slo_per_model": {
            m: v["p99_over_slo"] for m, v in att.get("per_model", {}).items()},
        "pipeline": rep.pipeline,
    }


def _overload_pool(count: int, nv: int, f: int, seed: int) -> list[Graph]:
    """Fixed-size graphs (one bucket per model: two queue groups total)."""
    rng = np.random.default_rng(seed)
    pool = []
    for _ in range(count):
        ne = 6 * nv
        pool.append(Graph(
            edge_src=rng.integers(0, nv, ne).astype(np.int32),
            edge_dst=rng.integers(0, nv, ne).astype(np.int32),
            node_feat=rng.standard_normal((nv, f)).astype(np.float32),
        ).validate())
    return pool


def _overload_catalog(tight_frac: float):
    """Shared SLO'd two-model catalog for the overload + pipeline benches.

    Heavy enough that one batch costs ~10 ms on a CPU host: the default
    rate ramp then spans under-load (queue mostly empty, every scheduler
    attains) through near-capacity (queueing delay is the differentiator)
    into overload (the bounded queue sheds).
    """
    f, hidden, nv = 32, 128, 256
    hot = build_model("gcn", f, 3, hidden=hidden)
    tight = build_model("sage", f, 3, hidden=hidden)
    ks = jax.random.split(jax.random.PRNGKey(6), 2)
    models = {"hot_loose": (hot, hot.init(ks[0])),
              "rare_tight": (tight, tight.init(ks[1]))}
    pools = {
        "hot_loose": _overload_pool(4, nv, f, seed=30),
        "rare_tight": _overload_pool(4, nv, f, seed=31),
    }
    mix = {"hot_loose": 1.0 - tight_frac, "rare_tight": tight_frac}
    return models, pools, mix


def _overload_engine(models, pools, *, slots, backend, max_waiting,
                     hot_slo_ms, tight_slo_ms, scheduler,
                     pipeline_depth=2,
                     service_time_admission=False) -> GnnServeEngine:
    """One warmed engine (every trace compiled, service EWMAs learned)."""
    engine = GnnServeEngine(
        cfg=GhostConfig(), slots=slots, backend=backend,
        scheduler=scheduler, max_waiting=max_waiting,
        admission_policy="shed-oldest", pipeline_depth=pipeline_depth,
        service_time_admission=service_time_admission)
    hot, hot_params = models["hot_loose"]
    tight, tight_params = models["rare_tight"]
    engine.register("hot_loose", hot, hot_params, task="node",
                    slo_ms=hot_slo_ms)
    engine.register("rare_tight", tight, tight_params, task="node",
                    slo_ms=tight_slo_ms)
    # Warm-up compiles every trace AND (from the second execution per
    # key on) feeds the service-time EWMA, so a service-admission engine
    # starts its first measured cell with a learned model — exactly the
    # steady state a long-running server sits in.
    for mid, pool in pools.items():
        for g in pool:
            engine.submit(mid, g)
            engine.drain()
    return engine


def _deadline_policy():
    # Urgency margin ~= one batch service time + a little headroom:
    # preempting any earlier wastes occupancy, any later turns a
    # meetable tight deadline into a miss.  (With a learned service-time
    # estimate the scheduler takes max(margin, estimate) per group.)
    return make_scheduler("deadline", urgent_slack_s=0.015)


def run_overload(rates=(100, 200, 400, 800), window_s: float = 2.0,
                 slots: int = 8, backend: str = "jnp",
                 max_waiting: int = 64, hot_slo_ms: float = 250.0,
                 tight_slo_ms: float = 30.0, tight_frac: float = 0.15,
                 seed: int = 23) -> dict:
    models, pools, mix = _overload_catalog(tight_frac)
    # One shared arrival schedule per rate: every scheduler sees the exact
    # same offered traffic.
    schedules = {rate: _poisson_schedule(rate, window_s, pools, mix,
                                         seed + int(rate))
                 for rate in rates}

    # The three classic schedulers run with service-time admission OFF
    # (the PR-9 slack-only-shed baseline); "deadline_slo_admission" is the
    # same deadline policy with the learned-EWMA admission ON — the A/B
    # that isolates what enqueue-time infeasibility rejection buys.
    configs = {
        "fifo": ("fifo", False),
        "occupancy": ("occupancy", False),
        "deadline": (_deadline_policy(), False),
        "deadline_slo_admission": (_deadline_policy(), True),
    }
    results: dict[str, dict] = {}
    for name, (policy, slo_admission) in configs.items():
        engine = _overload_engine(
            models, pools, slots=slots, backend=backend,
            max_waiting=max_waiting, hot_slo_ms=hot_slo_ms,
            tight_slo_ms=tight_slo_ms, scheduler=policy,
            service_time_admission=slo_admission)
        per_rate = {}
        for rate in rates:
            cell = _overload_cell(engine, schedules[rate], window_s)
            per_rate[str(rate)] = cell
            emit(f"serving/overload_{name}_{rate}",
                 0.0 if not cell["req_per_s"] else 1e6 / cell["req_per_s"],
                 f"att={cell['attainment']:.3f};"
                 f"p99={cell['p99_latency_ms']:.1f}ms;"
                 f"shed={cell['shed']};unmeet={cell['unmeetable']}")
        results[name] = per_rate

    beats_at = [
        rate for rate in rates
        if (results["deadline"][str(rate)]["attainment"]
            > results["fifo"][str(rate)]["attainment"]
            and results["deadline"][str(rate)]["attainment"]
            > results["occupancy"][str(rate)]["attainment"])
    ]
    first_shed = {
        sched: next((rate for rate in rates
                     if results[sched][str(rate)]["shed"] > 0), None)
        for sched in results
    }
    # Where does admission strictly cut served-but-missed without costing
    # attainment, vs the slack-only deadline baseline?
    slo_admission_reduces_missed_at = [
        rate for rate in rates
        if (results["deadline_slo_admission"][str(rate)]["served_slo_missed"]
            < results["deadline"][str(rate)]["served_slo_missed"]
            and results["deadline_slo_admission"][str(rate)]["attainment"]
            >= results["deadline"][str(rate)]["attainment"])
    ]
    return bench_json({
        "bench": "serving_overload",
        "rates_req_s": list(rates),
        "window_s": window_s,
        "slots": slots,
        "backend": backend,
        "max_waiting": max_waiting,
        "admission_policy": "shed-oldest",
        "slo_ms": {"hot_loose": hot_slo_ms, "rare_tight": tight_slo_ms},
        "traffic_mix": mix,
        "schedulers": results,
        "deadline_beats_fifo_and_occupancy_at": beats_at,
        "first_shed_rate": first_shed,
        "slo_admission_reduces_missed_at": slo_admission_reduces_missed_at,
        "note": "open-loop Poisson arrivals against the always-on serve "
                "loop; identical offered schedule per rate across "
                "schedulers; attainment is over served requests "
                "(shed/rejected requests are counted separately); "
                "deadline_slo_admission = deadline scheduling + learned-"
                "service-time admission (unmeetable SLOs rejected at "
                "enqueue), the others run the PR-9 slack-only-shed "
                "baseline",
    })


def run_pipeline_ab(depths=(0, 2, 4), rate: float = 1200.0,
                    window_s: float = 2.0, slots: int = 8,
                    backend: str = "jnp", max_waiting: int = 64,
                    hot_slo_ms: float = 250.0, tight_slo_ms: float = 30.0,
                    tight_frac: float = 0.15, seed: int = 29) -> dict:
    """Pipelined-vs-serial serve-loop A/B at one fixed offered load.

    Every depth sees the *identical* arrival schedule at a rate chosen to
    saturate the serial loop, so served req/s is the loop's capacity:
    depth 0 serializes stack -> execute -> writeback, depth >= 2 overlaps
    host stacking of batch k+1 with device execution of batch k (plus
    record building of batch k-1 in a second worker).  Outputs are
    bit-exact across depths (tested in tests/test_serving_pipeline.py);
    this measures only the throughput side of the claim.

    The doc stamps ``host_cores``: stage overlap needs a core for the
    host stages to run ON while the device stage computes.  On a 1-core
    host the A/B is parity-within-noise at best — throughput there is
    work-conserving (every thread timeslices the single core; a
    micro-benchmark on such a host shows one concurrent Python thread
    doubling a jitted call's wall time), so ``pipelined_beats_serial``
    is only a meaningful overlap verdict when ``overlap_possible``.
    """
    models, pools, mix = _overload_catalog(tight_frac)
    schedule = _poisson_schedule(rate, window_s, pools, mix, seed)
    cells: dict[str, dict] = {}
    for depth in depths:
        engine = _overload_engine(
            models, pools, slots=slots, backend=backend,
            max_waiting=max_waiting, hot_slo_ms=hot_slo_ms,
            tight_slo_ms=tight_slo_ms, scheduler=_deadline_policy(),
            pipeline_depth=depth, service_time_admission=False)
        cell = _overload_cell(engine, schedule, window_s)
        cells[str(depth)] = cell
        pl = cell["pipeline"]
        emit(f"serving/pipeline_depth{depth}",
             0.0 if not cell["req_per_s"] else 1e6 / cell["req_per_s"],
             f"served={cell['served']};att={cell['attainment']:.3f};"
             f"p99={cell['p99_latency_ms']:.1f}ms;"
             f"exec_busy={pl.get('exec_busy_frac', 0.0):.2f};"
             f"stack_busy={pl.get('stack_busy_frac', 0.0):.2f}")

    try:
        host_cores = len(os.sched_getaffinity(0))
    except AttributeError:            # non-Linux fallback
        host_cores = os.cpu_count() or 1
    doc = {
        "bench": "serving_pipeline",
        "rate_req_s": rate,
        "window_s": window_s,
        "slots": slots,
        "backend": backend,
        "max_waiting": max_waiting,
        "slo_ms": {"hot_loose": hot_slo_ms, "rare_tight": tight_slo_ms},
        "traffic_mix": mix,
        "depths": [int(d) for d in depths],
        "cells": cells,
        "host_cores": host_cores,
        # Stage overlap needs a second core for the host stages to run on
        # while the device computes; on one core throughput is
        # work-conserving and the A/B measures pipeline overhead only.
        "overlap_possible": host_cores > 1,
        "note": "identical offered Poisson schedule per depth at a rate "
                "that saturates the serial loop; served req/s is loop "
                "capacity; depth 0 = serial PR-9 loop, depth N = stacker "
                "+ N executor workers (device stage serialized behind the "
                "engine device lock); outputs are bit-exact across depths "
                "by construction; pipelined_beats_serial is only an "
                "overlap verdict when overlap_possible (host_cores > 1) — "
                "a 1-core host timeslices every stage onto the same core, "
                "so parity there is the physical ceiling",
    }
    pipelined = {d: cells[str(d)]["req_per_s"] for d in depths if d >= 1}
    if pipelined:
        best_depth = max(pipelined, key=pipelined.get)
        doc["best_pipelined_depth"] = int(best_depth)
        doc["best_pipelined_req_per_s"] = pipelined[best_depth]
        if "0" in cells:
            serial = cells["0"]["req_per_s"]
            doc["serial_req_per_s"] = serial
            doc["pipelined_speedup"] = (pipelined[best_depth] / serial
                                        if serial > 0 else 0.0)
            doc["pipelined_beats_serial"] = pipelined[best_depth] > serial
    return bench_json(doc)


def run(quick: bool = True, requests: int | None = None,
        working_set: int = 10, slots: int = 8, backend: str = "jnp",
        include_naive: bool = True, include_mixed: bool = True,
        include_fused: bool = True,
        ticks: int | None = None, arrivals: int | None = None,
        max_waiting: int = 64) -> dict:
    requests = requests or (32 if quick else 256)
    f, hidden, classes = 16, 16, 3
    stream = _request_stream(requests, working_set, f)

    model = build_model("gcn", f, classes, hidden=hidden)
    params = model.init(jax.random.PRNGKey(0))
    cfg = GhostConfig()
    spec = GnnModelSpec.gcn(f, hidden, classes)

    engine = GnnServeEngine(cfg=cfg, slots=slots, backend=backend)
    engine.register("gcn", model, params, task="node", spec=spec,
                    dataset_name="synthetic")
    report = engine.run(stream)
    emit("serving/engine", report.wall_s / requests * 1e6,
         f"req_s={report.req_per_s:.1f};hit={report.cache_hit_rate:.2f};"
         f"traces={report.traces_compiled}")

    doc = {
        "bench": "serving_throughput",
        "requests": requests,
        "working_set": working_set,
        "slots": slots,
        "backend": backend,
        "req_per_s": report.req_per_s,
        "p50_latency_ms": report.p50_latency_ms,
        "p99_latency_ms": report.p99_latency_ms,
        "mean_batch_size": report.mean_batch_size,
        "cache_hit_rate": report.cache_hit_rate,
        "traces_compiled": report.traces_compiled,
        "buckets": report.buckets,
        "hw_req_per_s": report.hw_req_per_s,
        "hw_avg_power_w": report.hw_avg_power_w,
    }
    if include_naive:
        naive_s = _naive_loop(model, params, stream, cfg)
        emit("serving/naive_loop", naive_s / requests * 1e6,
             f"req_s={requests / naive_s:.1f}")
        doc["naive_req_per_s"] = requests / naive_s
        doc["speedup_vs_naive"] = (report.req_per_s * naive_s / requests
                                   if naive_s > 0 else 0.0)
    if include_mixed:
        doc["mixed"] = run_mixed(
            ticks=ticks or (48 if quick else 192),
            arrivals_per_tick=arrivals or 8,
            working_set=max(4, working_set // 2),
            slots=slots, backend=backend, max_waiting=max_waiting)
    if include_fused:
        # Interpret-mode Pallas serving is slow on CPU; keep this closed
        # loop small — it is a backend A/B, not a throughput measurement.
        doc["fused_vs_unfused"] = run_fused_vs_unfused(
            requests=min(requests, 12 if quick else 48),
            working_set=min(working_set, 4), slots=min(slots, 4))
    return bench_json(doc)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--working-set", type=int, default=10)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--backend", choices=("jnp", "pallas", "pallas_fused"),
                    default="jnp")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--no-naive", action="store_true",
                    help="skip the naive-loop baseline timing")
    ap.add_argument("--no-mixed", action="store_true",
                    help="skip the mixed-catalog FIFO-vs-occupancy trace")
    ap.add_argument("--no-fused", action="store_true",
                    help="skip the fused-vs-unfused Pallas backend A/B")
    ap.add_argument("--ticks", type=int, default=None,
                    help="mixed-catalog open-loop tick budget")
    ap.add_argument("--arrivals", type=int, default=None,
                    help="request arrivals per tick in the mixed trace")
    ap.add_argument("--max-waiting", type=int, default=64,
                    help="admission bound for the mixed trace")
    ap.add_argument("--device-scaling", action="store_true",
                    help="run ONLY the 1/2/4/8-device scaling sweep "
                         "(start the process under "
                         "XLA_FLAGS=--xla_force_host_platform_device_count=8 "
                         "on CPU hosts)")
    ap.add_argument("--router", action="store_true",
                    help="run ONLY the replica-router benchmark")
    ap.add_argument("--replicas", type=int, default=2,
                    help="replica count for --router")
    ap.add_argument("--counts", type=str, default="1,2,4,8",
                    help="comma-separated device counts for --device-scaling")
    ap.add_argument("--node-queries", action="store_true",
                    help="run ONLY the node-query (neighborhood-sampled) "
                         "sweep vs resident graph size")
    ap.add_argument("--overload", action="store_true",
                    help="run ONLY the open-loop Poisson overload ramp "
                         "(fifo vs occupancy vs deadline vs deadline+"
                         "service-time-admission SLO attainment) followed "
                         "by the pipelined-vs-serial serve-loop A/B")
    ap.add_argument("--rates", type=str, default="100,200,400,800",
                    help="comma-separated arrival rates (req/s) for "
                         "--overload")
    ap.add_argument("--window", type=float, default=2.0,
                    help="seconds of offered traffic per rate step for "
                         "--overload")
    ap.add_argument("--pipeline-depths", type=str, default="0,2,4",
                    help="comma-separated pipeline depths for the "
                         "pipelined-vs-serial A/B run by --overload "
                         "(0 = serial loop)")
    ap.add_argument("--pipeline-rate", type=float, default=1200.0,
                    help="fixed offered load (req/s) for the pipeline A/B; "
                         "pick a rate that saturates the serial loop")
    ap.add_argument("--pipeline-window", type=float, default=2.0,
                    help="seconds of offered traffic for the pipeline A/B")
    ap.add_argument("--sizes", type=str, default="10000,100000,1000000",
                    help="comma-separated host graph sizes for "
                         "--node-queries")
    ap.add_argument("--queries", type=int, default=None,
                    help="query count per size for --node-queries")
    args = ap.parse_args()
    if args.working_set < 1 or args.slots < 1 or (
            args.requests is not None and args.requests < 1):
        ap.error("--requests, --working-set and --slots must be >= 1")
    if args.overload:
        if args.window <= 0 or args.pipeline_window <= 0:
            ap.error("--window and --pipeline-window must be positive")
        if args.pipeline_rate <= 0:
            ap.error("--pipeline-rate must be positive")
        rates = tuple(int(r) for r in args.rates.split(","))
        depths = tuple(int(d) for d in args.pipeline_depths.split(","))
        if any(d < 0 for d in depths):
            ap.error("--pipeline-depths entries must be >= 0")
        run_overload(rates=rates, window_s=args.window, slots=args.slots,
                     backend=args.backend, max_waiting=args.max_waiting)
        run_pipeline_ab(depths=depths, rate=args.pipeline_rate,
                        window_s=args.pipeline_window, slots=args.slots,
                        backend=args.backend, max_waiting=args.max_waiting)
        return
    if args.device_scaling or args.router or args.node_queries:
        requests = args.requests or (16 if not args.full else 128)
        if args.device_scaling:
            counts = tuple(int(c) for c in args.counts.split(","))
            run_device_scaling(requests, min(args.working_set, 6),
                               args.slots, counts=counts)
        if args.router:
            run_router(requests, min(args.working_set, 6), args.slots,
                       replicas=args.replicas)
        if args.node_queries:
            sizes = tuple(int(s) for s in args.sizes.split(","))
            run_node_queries(sizes=sizes,
                             queries=args.queries
                             or (48 if not args.full else 192),
                             slots=args.slots)
        return
    run(quick=not args.full, requests=args.requests,
        working_set=args.working_set, slots=args.slots,
        backend=args.backend, include_naive=not args.no_naive,
        include_mixed=not args.no_mixed, include_fused=not args.no_fused,
        ticks=args.ticks, arrivals=args.arrivals,
        max_waiting=args.max_waiting)


if __name__ == "__main__":
    main()
