"""Serving-throughput benchmark: engine vs naive loop, FIFO vs occupancy,
fused vs unfused Pallas backends.

Three measurements, folded into one BENCH JSON document:

  1. Single-model closed loop (engine vs the naive re-partition-per-request
     baseline) — the PR 3 numbers, kept for trend continuity.
  2. Mixed-catalog open loop: GCN+GAT+SAGE at two feature dims behind one
     engine with a bounded waiting queue, driven by a skewed arrival
     process for a fixed tick budget.  The same trace runs under the FIFO
     and the occupancy-aware scheduler (both engines pre-warmed so jit
     compilation stays out of the timed window); occupancy forms fuller
     batches and therefore serves more requests in the same budget, while
     its age bound keeps the maximum queue wait finite.
  3. Fused-vs-unfused backend A/B: the same closed loop served by
     ``backend="pallas"`` (block_spmm + separate combine) and
     ``backend="pallas_fused"`` (fused aggregate+combine epilogue kernel),
     with the combination-order planner's trace-time decisions attached.

Emits the usual ``name,us,derived`` CSV lines plus a BENCH_JSON line
(``{"bench": "serving_throughput", ..., "mixed": {...},
"fused_vs_unfused": {...}}``) that also persists to BENCH.json at the
repo root, stamped with device kind / jax version / interpret mode (see
benchmarks.common.bench_json).

Run:  PYTHONPATH=src python benchmarks/serving_throughput.py [--requests N]
"""

from __future__ import annotations

import argparse
import os
import sys
import time

import jax
import numpy as np

try:
    from benchmarks.common import bench_json, emit
except ModuleNotFoundError:
    # Standalone invocation (python benchmarks/serving_throughput.py):
    # put the repo root on the path so the package import resolves.
    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    from benchmarks.common import bench_json, emit
from repro.core import (
    Graph,
    clear_planner_log,
    partition_graph,
    planner_decisions,
    to_blocked,
)
from repro.gnn import build_model
from repro.photonic.perf import GhostConfig, GnnModelSpec
from repro.serving import GnnServeEngine


def _graph_pool(count: int, f: int, seed: int) -> list[Graph]:
    rng = np.random.default_rng(seed)
    pool = []
    for _ in range(count):
        nv = int(rng.integers(24, 96))
        ne = int(rng.integers(2 * nv, 6 * nv))
        pool.append(Graph(
            edge_src=rng.integers(0, nv, ne).astype(np.int32),
            edge_dst=rng.integers(0, nv, ne).astype(np.int32),
            node_feat=rng.standard_normal((nv, f)).astype(np.float32),
        ).validate())
    return pool


def _request_stream(num_requests: int, working_set: int, f: int,
                    seed: int = 0) -> list[Graph]:
    rng = np.random.default_rng(seed)
    pool = _graph_pool(working_set, f, seed)
    return [pool[int(rng.integers(0, working_set))]
            for _ in range(num_requests)]


def _naive_loop(model, params, stream, cfg) -> float:
    """The pre-engine baseline: re-partition + fresh shapes every request."""
    import jax.numpy as jnp

    t0 = time.perf_counter()
    for g in stream:
        pg = partition_graph(g, v=cfg.v, n=cfg.n)
        featp = jnp.asarray(pg.pad_features(g.node_feat))
        out = model.apply_blocked(params, to_blocked(pg), featp)
        jax.block_until_ready(out)
    return time.perf_counter() - t0


# ---------------------------------------------------------------------------
# Fused vs unfused Pallas executors: the same closed-loop stream served by a
# backend="pallas" engine (unfused block_spmm + separate combine) and a
# backend="pallas_fused" engine (fused aggregate+combine epilogue kernel with
# combination-order planning).  Both engines are pre-warmed so the timed
# window compares steady-state serving, and the planner's trace-time order
# decisions are snapshotted from the warm-up (traces are cached afterwards).
# CPU note: both backends run the kernels in interpret mode, so the gap
# reflects grid-sweep count + dispatch, not HBM traffic — reported as such.
# ---------------------------------------------------------------------------


def _warmed_engine_run(backend: str, model, params, stream, cfg,
                       slots: int) -> dict:
    engine = GnnServeEngine(cfg=cfg, slots=slots, backend=backend)
    engine.register("gcn", model, params, task="node")
    engine.run(stream)          # warm-up: compile every (bucket) trace
    engine.reset_metrics()
    report = engine.run(stream)
    return {"req_per_s": report.req_per_s,
            "p50_latency_ms": report.p50_latency_ms,
            "traces_compiled": report.traces_compiled}


def run_fused_vs_unfused(requests: int, working_set: int, slots: int,
                         f: int = 136, hidden: int = 136) -> dict:
    # f > one lane tile (128) and hidden >= f so the hot first layer is
    # aggregate-first (fused-kernel territory): the unfused backend sweeps
    # the tile list once per 128-wide feature tile plus a separate combine,
    # the fused backend sweeps it once.  The planner still routes the
    # narrow output layer combine-first on both backends.
    stream = _request_stream(requests, working_set, f, seed=3)
    model = build_model("gcn", f, 3, hidden=hidden)
    params = model.init(jax.random.PRNGKey(1))
    cfg = GhostConfig()

    clear_planner_log()
    results = {}
    for backend in ("pallas", "pallas_fused"):
        results[backend] = _warmed_engine_run(backend, model, params, stream,
                                              cfg, slots)
        emit(f"serving/{backend}",
             0.0 if not results[backend]["req_per_s"] else
             1e6 / results[backend]["req_per_s"],
             f"req_s={results[backend]['req_per_s']:.1f}")
    decisions = planner_decisions()
    return {
        "interpret": True,
        "note": "CPU interpret-mode A/B: the fused epilogue matmuls run "
                "interpreted per destination row while the unfused combine "
                "is one compiled XLA matmul, so ratios near/below 1.0 here "
                "reflect interpreter dispatch, not the HBM-traffic saving "
                "the fusion targets; see kernel_micro BENCH_JSON for the "
                "kernel-level fused-vs-unfused comparison on one shape",
        "requests": requests,
        "pallas": results["pallas"],
        "pallas_fused": results["pallas_fused"],
        "fused_vs_unfused_req_per_s": (
            results["pallas_fused"]["req_per_s"]
            / results["pallas"]["req_per_s"]
            if results["pallas"]["req_per_s"] else 0.0),
        "planner_decisions": decisions,
        "planner_orders": sorted({d["order"] for d in decisions}),
    }


# ---------------------------------------------------------------------------
# Mixed catalog: GCN+GAT+SAGE at two feature dims, FIFO vs occupancy.
# ---------------------------------------------------------------------------

F_SMALL, F_LARGE = 8, 16
CATALOG_WEIGHTS = {"gcn_f8": 0.6, "sage_f8": 0.2, "gat_f16": 0.2}


def _build_catalog():
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    gcn = build_model("gcn", F_SMALL, 3, hidden=8)
    sage = build_model("sage", F_SMALL, 3, hidden=8)
    gat = build_model("gat", F_LARGE, 3, hidden=4, heads=2)
    return {
        "gcn_f8": (gcn, gcn.init(ks[0]), F_SMALL),
        "sage_f8": (sage, sage.init(ks[1]), F_SMALL),
        "gat_f16": (gat, gat.init(ks[2]), F_LARGE),
    }


def _mixed_schedule(num: int, pools: dict, seed: int = 1):
    """Skewed arrival order: 60% of traffic hits the hot model."""
    rng = np.random.default_rng(seed)
    mids = list(CATALOG_WEIGHTS)
    probs = np.array([CATALOG_WEIGHTS[m] for m in mids])
    schedule = []
    for _ in range(num):
        mid = mids[int(rng.choice(len(mids), p=probs))]
        pool = pools[mid]
        schedule.append((mid, pool[int(rng.integers(0, len(pool)))]))
    return schedule


def _mixed_engine(scheduler: str, catalog, slots: int, backend: str,
                  max_waiting: int) -> GnnServeEngine:
    engine = GnnServeEngine(cfg=GhostConfig(), slots=slots, backend=backend,
                            scheduler=scheduler, max_waiting=max_waiting)
    for mid, (model, params, _f) in catalog.items():
        engine.register(mid, model, params, task="node")
    return engine


def _open_loop(engine: GnnServeEngine, pools: dict, schedule,
               ticks: int, arrivals_per_tick: int) -> dict:
    """Warm up (compile every executor), then drive a fixed tick budget."""
    for mid, pool in pools.items():
        for g in pool:
            # Drain per submission: keeps warm-up below any admission bound
            # (a whole pool submitted back-to-back could exceed max_waiting).
            engine.submit(mid, g)
            engine.drain()
    engine.reset_metrics()

    si = 0
    t0 = time.perf_counter()
    for _ in range(ticks):
        for _ in range(arrivals_per_tick):
            if si < len(schedule):
                mid, g = schedule[si]
                si += 1
                engine.try_submit(mid, g)
        engine.step()
    elapsed = time.perf_counter() - t0

    rep = engine.report(elapsed)
    return {
        "scheduler": engine.scheduler.name,
        "served": rep.requests,
        "req_per_s": rep.req_per_s,
        "mean_batch_size": rep.mean_batch_size,
        "max_wait_ticks": rep.max_wait_ticks,
        "admitted": rep.admitted,
        "rejected": rep.rejected,
        "per_model": rep.per_model,
        "traces_compiled": rep.traces_compiled,
    }


def run_mixed(ticks: int, arrivals_per_tick: int, working_set: int,
              slots: int, backend: str, max_waiting: int) -> dict:
    pools = {
        "gcn_f8": _graph_pool(working_set, F_SMALL, seed=10),
        "sage_f8": _graph_pool(working_set, F_SMALL, seed=11),
        "gat_f16": _graph_pool(working_set, F_LARGE, seed=12),
    }
    schedule = _mixed_schedule(ticks * arrivals_per_tick, pools)
    catalog = _build_catalog()

    results = {}
    for scheduler in ("fifo", "occupancy"):
        engine = _mixed_engine(scheduler, catalog, slots, backend,
                               max_waiting)
        results[scheduler] = _open_loop(engine, pools, schedule, ticks,
                                        arrivals_per_tick)
        emit(f"serving/mixed_{scheduler}",
             0.0 if not results[scheduler]["served"] else
             1e6 / results[scheduler]["req_per_s"],
             f"served={results[scheduler]['served']};"
             f"batch={results[scheduler]['mean_batch_size']:.2f};"
             f"max_wait={results[scheduler]['max_wait_ticks']}")

    fifo, occ = results["fifo"], results["occupancy"]
    results["occupancy_vs_fifo_served"] = (
        occ["served"] / fifo["served"] if fifo["served"] else 0.0)
    results["occupancy_vs_fifo_req_per_s"] = (
        occ["req_per_s"] / fifo["req_per_s"] if fifo["req_per_s"] else 0.0)
    results["ticks"] = ticks
    results["arrivals_per_tick"] = arrivals_per_tick
    results["max_waiting"] = max_waiting
    return results


def run(quick: bool = True, requests: int | None = None,
        working_set: int = 10, slots: int = 8, backend: str = "jnp",
        include_naive: bool = True, include_mixed: bool = True,
        include_fused: bool = True,
        ticks: int | None = None, arrivals: int | None = None,
        max_waiting: int = 64) -> dict:
    requests = requests or (32 if quick else 256)
    f, hidden, classes = 16, 16, 3
    stream = _request_stream(requests, working_set, f)

    model = build_model("gcn", f, classes, hidden=hidden)
    params = model.init(jax.random.PRNGKey(0))
    cfg = GhostConfig()
    spec = GnnModelSpec.gcn(f, hidden, classes)

    engine = GnnServeEngine(cfg=cfg, slots=slots, backend=backend)
    engine.register("gcn", model, params, task="node", spec=spec,
                    dataset_name="synthetic")
    report = engine.run(stream)
    emit("serving/engine", report.wall_s / requests * 1e6,
         f"req_s={report.req_per_s:.1f};hit={report.cache_hit_rate:.2f};"
         f"traces={report.traces_compiled}")

    doc = {
        "bench": "serving_throughput",
        "requests": requests,
        "working_set": working_set,
        "slots": slots,
        "backend": backend,
        "req_per_s": report.req_per_s,
        "p50_latency_ms": report.p50_latency_ms,
        "p99_latency_ms": report.p99_latency_ms,
        "mean_batch_size": report.mean_batch_size,
        "cache_hit_rate": report.cache_hit_rate,
        "traces_compiled": report.traces_compiled,
        "buckets": report.buckets,
        "hw_req_per_s": report.hw_req_per_s,
        "hw_avg_power_w": report.hw_avg_power_w,
    }
    if include_naive:
        naive_s = _naive_loop(model, params, stream, cfg)
        emit("serving/naive_loop", naive_s / requests * 1e6,
             f"req_s={requests / naive_s:.1f}")
        doc["naive_req_per_s"] = requests / naive_s
        doc["speedup_vs_naive"] = (report.req_per_s * naive_s / requests
                                   if naive_s > 0 else 0.0)
    if include_mixed:
        doc["mixed"] = run_mixed(
            ticks=ticks or (48 if quick else 192),
            arrivals_per_tick=arrivals or 8,
            working_set=max(4, working_set // 2),
            slots=slots, backend=backend, max_waiting=max_waiting)
    if include_fused:
        # Interpret-mode Pallas serving is slow on CPU; keep this closed
        # loop small — it is a backend A/B, not a throughput measurement.
        doc["fused_vs_unfused"] = run_fused_vs_unfused(
            requests=min(requests, 12 if quick else 48),
            working_set=min(working_set, 4), slots=min(slots, 4))
    return bench_json(doc)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--working-set", type=int, default=10)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--backend", choices=("jnp", "pallas", "pallas_fused"),
                    default="jnp")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--no-naive", action="store_true",
                    help="skip the naive-loop baseline timing")
    ap.add_argument("--no-mixed", action="store_true",
                    help="skip the mixed-catalog FIFO-vs-occupancy trace")
    ap.add_argument("--no-fused", action="store_true",
                    help="skip the fused-vs-unfused Pallas backend A/B")
    ap.add_argument("--ticks", type=int, default=None,
                    help="mixed-catalog open-loop tick budget")
    ap.add_argument("--arrivals", type=int, default=None,
                    help="request arrivals per tick in the mixed trace")
    ap.add_argument("--max-waiting", type=int, default=64,
                    help="admission bound for the mixed trace")
    args = ap.parse_args()
    if args.working_set < 1 or args.slots < 1 or (
            args.requests is not None and args.requests < 1):
        ap.error("--requests, --working-set and --slots must be >= 1")
    run(quick=not args.full, requests=args.requests,
        working_set=args.working_set, slots=args.slots,
        backend=args.backend, include_naive=not args.no_naive,
        include_mixed=not args.no_mixed, include_fused=not args.no_fused,
        ticks=args.ticks, arrivals=args.arrivals,
        max_waiting=args.max_waiting)


if __name__ == "__main__":
    main()
