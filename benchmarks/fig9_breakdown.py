"""Fig. 9: per-block (aggregate / combine / update) latency breakdown per
GNN model and dataset.

Reproduction targets: aggregate consumes the majority for GCN/GraphSAGE on
the citation graphs; combine (+update/softmax) dominates GAT; combine
dominates GIN on the small graph-classification graphs.
"""

from __future__ import annotations

import time

from benchmarks.common import cached_json, emit
from repro.gnn import load
from repro.gnn.datasets import TABLE2
from repro.photonic.perf import GhostConfig, GnnModelSpec, OrchFlags, simulate


def run(quick: bool = True):
    pairs = ([("gcn", "Cora"), ("sage", "Cora"), ("gat", "Cora"),
              ("gin", "Mutag")] if quick else
             [(m, d) for m in ("gcn", "sage", "gat")
              for d in ("Cora", "PubMed", "Citeseer", "Amazon")]
             + [("gin", d) for d in ("Proteins", "Mutag", "BZR",
                                     "IMDB-binary")])
    cfg = GhostConfig()
    out = {}
    for m, d in pairs:
        t0 = time.time()
        spec_t = TABLE2[d]
        graphs = (load(d, seed=0) if spec_t["graphs"] == 1
                  else load(d, seed=0, num_graphs=min(spec_t["graphs"], 60)))
        builder = {"gcn": GnnModelSpec.gcn, "sage": GnnModelSpec.graphsage,
                   "gat": GnnModelSpec.gat, "gin": GnnModelSpec.gin}[m]
        hidden = 8 if m == "gat" else 64
        r = simulate(builder(spec_t["features"], hidden, spec_t["labels"]),
                     graphs, cfg, OrchFlags(), d)
        tot = sum(c.latency for c in r.breakdown.values()) or 1.0
        fr = {k: r.breakdown[k].latency / tot
              for k in ("aggregate", "combine", "update")}
        dt = (time.time() - t0) * 1e6
        emit(f"fig9/{m}/{d}", dt,
             f"agg={fr['aggregate']:.2f};comb={fr['combine']:.2f};"
             f"upd={fr['update']:.2f};lat_us={r.latency * 1e6:.0f}")
        out[(m, d)] = fr
    return out
