"""Figs. 10-12: GHOST vs GPU / CPU / TPU / prior GNN accelerators.

The paper reports *relative* improvements (its figures are log-scale bars
without absolute axes we can read), so the platform baselines here are
DERIVED from the paper's reported average ratios applied to our simulated
GHOST numbers — documented provenance, not independent measurements:

  GOPS improvements (paper 4.6.1):  GRIP 102.3x, HyGCN 325.3x, EnG 40.5x,
      HW_ACC 10.2x, ReGNN 12.6x, ReGraphX 150.6x, TPU 1699x, CPU 1567.5x,
      GPU 584.4x
  EPB improvements (paper 4.6.2):   GRIP 11.1x, HyGCN 60.5x, EnG 3.8x,
      HW_ACC 85.9x, ReGNN 15.7x, ReGraphX 313.7x, TPU 24276.7x,
      CPU 6178.8x, GPU 2585.3x

What IS independently checked here: our GHOST absolute numbers (GOPS in the
hundreds at ~17 W — consistent with the paper's 18 W power claim and its
headline ">=10.2x throughput, >=3.8x energy efficiency vs the best prior
accelerator"), and the per-model ranking structure.
"""

from __future__ import annotations

import time

from benchmarks.common import emit
from repro.gnn import load
from repro.gnn.datasets import TABLE2
from repro.photonic.perf import GhostConfig, GnnModelSpec, OrchFlags, simulate

PAPER_GOPS_RATIO = {
    "GRIP": 102.3, "HyGCN": 325.3, "EnG": 40.5, "HW_ACC": 10.2,
    "ReGNN": 12.6, "ReGraphX": 150.6, "TPU": 1699.0, "CPU": 1567.5,
    "GPU": 584.4,
}
PAPER_EPB_RATIO = {
    "GRIP": 11.1, "HyGCN": 60.5, "EnG": 3.8, "HW_ACC": 85.9,
    "ReGNN": 15.7, "ReGraphX": 313.7, "TPU": 24276.7, "CPU": 6178.8,
    "GPU": 2585.3,
}


def run(quick: bool = True):
    cfg = GhostConfig()
    pairs = ([("gcn", "Cora"), ("gat", "Cora"), ("gin", "Mutag")] if quick
             else [(m, d) for m in ("gcn", "sage", "gat")
                   for d in ("Cora", "PubMed", "Citeseer", "Amazon")]
             + [("gin", d) for d in ("Proteins", "Mutag")])
    gops_all, epb_all, pw_all = [], [], []
    for m, d in pairs:
        t0 = time.time()
        spec_t = TABLE2[d]
        graphs = (load(d, seed=0) if spec_t["graphs"] == 1
                  else load(d, seed=0, num_graphs=min(spec_t["graphs"], 60)))
        builder = {"gcn": GnnModelSpec.gcn, "sage": GnnModelSpec.graphsage,
                   "gat": GnnModelSpec.gat, "gin": GnnModelSpec.gin}[m]
        hidden = 8 if m == "gat" else 64
        r = simulate(builder(spec_t["features"], hidden, spec_t["labels"]),
                     graphs, cfg, OrchFlags(), d)
        dt = (time.time() - t0) * 1e6
        emit(f"fig10/ghost_gops/{m}/{d}", dt, f"{r.gops:.1f}")
        emit(f"fig11/ghost_epb/{m}/{d}", 0.0, f"{r.epb * 1e12:.2f}pJ/b")
        gops_all.append(r.gops)
        epb_all.append(r.epb)
        pw_all.append(r.power)

    mean_gops = sum(gops_all) / len(gops_all)
    mean_epb = sum(epb_all) / len(epb_all)
    mean_pw = sum(pw_all) / len(pw_all)
    emit("fig10/ghost_mean_gops", 0.0, f"{mean_gops:.1f}")
    emit("fig11/ghost_mean_epb", 0.0, f"{mean_epb * 1e12:.2f}pJ/b")
    emit("power/ghost_mean_watts", 0.0, f"{mean_pw:.1f};paper=18W")

    # Implied platform baselines (paper-ratio-derived; see module docstring).
    for plat, ratio in PAPER_GOPS_RATIO.items():
        emit(f"fig10/implied_{plat.lower()}_gops", 0.0,
             f"{mean_gops / ratio:.3f};paper_ratio={ratio}x")
    for plat, ratio in PAPER_EPB_RATIO.items():
        emit(f"fig12/epb_per_gops_vs_{plat.lower()}", 0.0,
             f"ghost_better_by={PAPER_GOPS_RATIO[plat] * ratio:.3e}x(paper)")
    # Paper's headline claims
    emit("headline/min_gops_improvement", 0.0,
         f"{min(PAPER_GOPS_RATIO.values())}x(>=10.2x)")
    emit("headline/min_epb_improvement", 0.0,
         f"{min(PAPER_EPB_RATIO.values())}x(>=3.8x)")
    return {"gops": mean_gops, "epb": mean_epb, "power": mean_pw}
