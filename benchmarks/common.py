"""Shared benchmark utilities: timing, CSV emission, training cache."""

from __future__ import annotations

import json
import os
import time

import numpy as np

CACHE_DIR = os.path.join(os.path.dirname(__file__), "_cache")
# Perf-trajectory ledger at the repo root: every BENCH_JSON document is
# persisted here (keyed by bench name) so successive runs/PRs accumulate
# comparable numbers instead of scrolling away in CI logs.
BENCH_JSON_PATH = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_PR5.json")
RESULTS: list[str] = []


def emit(name: str, us_per_call: float, derived):
    line = f"{name},{us_per_call:.1f},{derived}"
    RESULTS.append(line)
    print(line, flush=True)


def bench_json(doc: dict) -> dict:
    """Print the ``BENCH_JSON`` line and persist the document to
    ``BENCH_PR5.json`` under its ``bench`` name."""
    print("BENCH_JSON " + json.dumps(doc, default=float), flush=True)
    try:
        with open(BENCH_JSON_PATH) as f:
            ledger = json.load(f)
        if not isinstance(ledger, dict):
            ledger = {}
    except (FileNotFoundError, json.JSONDecodeError):
        ledger = {}
    ledger[str(doc.get("bench", "unnamed"))] = doc
    with open(BENCH_JSON_PATH, "w") as f:
        json.dump(ledger, f, indent=2, default=float, sort_keys=True)
        f.write("\n")
    return doc


def timed(fn, *args, repeats: int = 1, **kw):
    t0 = time.time()
    out = None
    for _ in range(repeats):
        out = fn(*args, **kw)
    dt = (time.time() - t0) / repeats
    return out, dt * 1e6


def cache_path(key: str) -> str:
    os.makedirs(CACHE_DIR, exist_ok=True)
    return os.path.join(CACHE_DIR, key)


def cached_json(key: str, compute):
    path = cache_path(key + ".json")
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    val = compute()
    with open(path, "w") as f:
        json.dump(val, f, default=float)
    return val
