"""Shared benchmark utilities: timing, CSV emission, training cache."""

from __future__ import annotations

import json
import os
import time

import numpy as np

CACHE_DIR = os.path.join(os.path.dirname(__file__), "_cache")
_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
# Perf-trajectory ledger at the repo root: every BENCH_JSON document is
# persisted here (keyed by bench name) so successive runs/PRs accumulate
# comparable numbers instead of scrolling away in CI logs.  PR-agnostic
# name; the PR 5 era wrote BENCH_PR5.json, whose entries are migrated into
# this file on first write (then the legacy file is retired).
BENCH_JSON_PATH = os.path.join(_REPO_ROOT, "BENCH.json")
_LEGACY_BENCH_PATHS = (os.path.join(_REPO_ROOT, "BENCH_PR5.json"),)
RESULTS: list[str] = []


def emit(name: str, us_per_call: float, derived):
    line = f"{name},{us_per_call:.1f},{derived}"
    RESULTS.append(line)
    print(line, flush=True)


def environment_stamp() -> dict:
    """Fields every BENCH_JSON document carries, so ledger entries from
    different machines/backends are never compared as like-for-like:
    device kind + count, jax version, and whether Pallas ran in interpret
    mode.  ``num_devices`` is the *visible* device count (on CPU it
    reflects --xla_force_host_platform_device_count), not what a given
    bench actually sharded over — pass ``mesh=`` to ``bench_json`` for
    that."""
    import jax  # deferred: common.py is imported by non-jax tooling too

    dev = jax.devices()[0]
    return {
        "device_kind": f"{dev.platform}:{dev.device_kind}",
        "num_devices": len(jax.devices()),
        "jax_version": jax.__version__,
        "interpret": jax.default_backend() != "tpu",
    }


def _load_ledger(path: str) -> dict:
    try:
        with open(path) as f:
            ledger = json.load(f)
        return ledger if isinstance(ledger, dict) else {}
    except (FileNotFoundError, json.JSONDecodeError):
        return {}


def bench_json(doc: dict, mesh=None) -> dict:
    """Stamp ``doc`` with the environment, print the ``BENCH_JSON`` line,
    and persist it to ``BENCH.json`` under its ``bench`` name (migrating
    any legacy per-PR ledger entries on the way).  ``mesh`` (a
    jax.sharding.Mesh) additionally stamps the axis->size shape the bench
    actually partitioned over."""
    doc = {**environment_stamp(), **doc}
    if mesh is not None:
        doc["mesh_shape"] = {a: int(s) for a, s in mesh.shape.items()}
    print("BENCH_JSON " + json.dumps(doc, default=float), flush=True)
    ledger = _load_ledger(BENCH_JSON_PATH)
    for legacy in _LEGACY_BENCH_PATHS:
        # Legacy entries only fill holes: the new ledger always wins.
        for name, entry in _load_ledger(legacy).items():
            ledger.setdefault(name, entry)
    ledger[str(doc.get("bench", "unnamed"))] = doc
    with open(BENCH_JSON_PATH, "w") as f:
        json.dump(ledger, f, indent=2, default=float, sort_keys=True)
        f.write("\n")
    for legacy in _LEGACY_BENCH_PATHS:
        if os.path.exists(legacy):
            os.remove(legacy)
    return doc


def timed(fn, *args, repeats: int = 1, **kw):
    t0 = time.time()
    out = None
    for _ in range(repeats):
        out = fn(*args, **kw)
    dt = (time.time() - t0) / repeats
    return out, dt * 1e6


def cache_path(key: str) -> str:
    os.makedirs(CACHE_DIR, exist_ok=True)
    return os.path.join(CACHE_DIR, key)


def cached_json(key: str, compute):
    path = cache_path(key + ".json")
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    val = compute()
    with open(path, "w") as f:
        json.dump(val, f, default=float)
    return val
