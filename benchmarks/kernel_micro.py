"""Kernel microbenchmarks: block_spmm and quant_matmul wall-times on this
host (interpret mode on CPU; the numbers are correctness-path timings, the
TPU roofline story lives in EXPERIMENTS.md §Roofline)."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timed
from repro.core import Graph, ReduceOp, aggregate_blocked, partition_graph, to_blocked
from repro.kernels import aggregate_blocked_kernel, quantized_matmul_kernel
from repro.photonic.quant import quantized_matmul


def run(quick: bool = True):
    rng = np.random.default_rng(0)
    nv, ne, f = (400, 2000, 128) if quick else (2000, 10000, 512)
    g = Graph(edge_src=rng.integers(0, nv, ne).astype(np.int32),
              edge_dst=rng.integers(0, nv, ne).astype(np.int32),
              node_feat=rng.standard_normal((nv, f)).astype(np.float32)
              ).validate()
    pg = partition_graph(g, v=20, n=20)
    featp = jnp.asarray(pg.pad_features(g.node_feat))

    out, us = timed(lambda: np.asarray(
        aggregate_blocked_kernel(pg, featp, block_f=128, interpret=True)),
        repeats=2)
    emit("kernel/block_spmm_interp", us,
         f"tiles={pg.stats.nonzero_tiles};skip={pg.stats.skipped_fraction:.2f}")

    bg = to_blocked(pg)
    out, us = timed(lambda: np.asarray(
        aggregate_blocked(bg, featp, ReduceOp.SUM)), repeats=3)
    emit("kernel/block_spmm_jnp_ref", us, "oracle")

    m, k, n = (128, 256, 128) if quick else (512, 1024, 512)
    x = jnp.asarray(rng.standard_normal((m, k)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal((k, n)).astype(np.float32))
    _, us = timed(lambda: np.asarray(
        quantized_matmul_kernel(x, w, interpret=True)), repeats=2)
    emit("kernel/quant_matmul_interp", us, f"{m}x{k}x{n}")
    _, us = timed(lambda: np.asarray(quantized_matmul(x, w)), repeats=3)
    emit("kernel/quant_matmul_jnp_ref", us, "oracle")
