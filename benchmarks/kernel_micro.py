"""Kernel microbenchmarks: block_spmm (unfused vs fused aggregate+combine),
the int8 fused-vs-unfused quantized combine A/B, the shape-class autotuner
sweep (search trajectory + cache warm-start proof), and quant_matmul
wall-times on this host.

On CPU the Pallas kernels run in *interpret* mode, so these are
correctness-path timings dominated by per-grid-step dispatch — reported
honestly as such (``"interpret": true`` in BENCH_JSON; the TPU roofline
story lives in EXPERIMENTS.md §Roofline).  The fused-vs-unfused comparison
is still meaningful on this axis: fusing the combine into the SpMM epilogue
removes one grid sweep per extra feature tile plus the separate combine
dispatch, the interpret-mode analogue of the HBM round-trip it eliminates
on hardware.

Every variant is timed through ``jax.block_until_ready`` so fused and
unfused numbers compare completed compute, not async dispatch.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_json, cache_path, emit, timed
from repro.core import (
    Graph,
    ReduceOp,
    aggregate_blocked,
    aggregate_combine_blocked,
    aggregate_backend,
    clear_planner_log,
    dense_combine,
    kernel_config_scope,
    partition_graph,
    plan_combine_order,
    planner_decisions,
    to_blocked,
)
from repro.kernels import (
    Autotuner,
    KernelConfig,
    ShapeClass,
    aggregate_blocked_kernel,
    fused_block_spmm_padded,
    quantized_matmul_kernel,
)
from repro.photonic.quant import quantized_matmul


def _make_graph(rng, nv, ne, f):
    return Graph(edge_src=rng.integers(0, nv, ne).astype(np.int32),
                 edge_dst=rng.integers(0, nv, ne).astype(np.int32),
                 node_feat=rng.standard_normal((nv, f)).astype(np.float32)
                 ).validate()


def _timed_blocked(fn, repeats):
    """Time fn with a warm-up call, blocking on the result every iteration."""
    jax.block_until_ready(fn())  # warm-up: compile/trace outside the window
    return timed(lambda: jax.block_until_ready(fn()), repeats=repeats)


def run_fused_comparison(nv, ne, f_in, f_out, v, n, repeats=2) -> dict:
    """Fused vs unfused aggregate+combine on one non-trivial shape.

    ``f_in`` is chosen > one lane tile (128) so the unfused kernel sweeps
    the block list once per feature tile while the fused kernel sweeps it
    once in total; the aggregate-first order is forced for the kernel
    comparison, and the planner's auto decision is reported alongside.
    """
    rng = np.random.default_rng(7)
    g = _make_graph(rng, nv, ne, f_in)
    pg = partition_graph(g, v=v, n=n)
    bg = to_blocked(pg)
    featp = jnp.asarray(pg.pad_features(g.node_feat))
    w = jnp.asarray(rng.standard_normal((f_in, f_out)).astype(np.float32))
    b = jnp.asarray(rng.standard_normal((f_out,)).astype(np.float32))
    shape_tag = f"nv={nv};tiles={pg.stats.nonzero_tiles};f={f_in}->{f_out}"

    # jnp oracle (aggregate-first), the correctness reference.
    ref, us_oracle = _timed_blocked(
        lambda: dense_combine(aggregate_blocked(bg, featp, ReduceOp.SUM), w, b),
        repeats)
    emit("kernel/agg_combine_jnp_oracle", us_oracle, shape_tag)

    # Unfused Pallas: block_spmm kernel + separate dense combine.
    def unfused():
        h = aggregate_blocked_kernel(pg, featp, block_f=128, interpret=True)
        return dense_combine(h, w, b)

    out_unfused, us_unfused = _timed_blocked(unfused, repeats)
    emit("kernel/agg_combine_pallas_unfused", us_unfused, shape_tag)

    # Fused Pallas: combine in the SpMM epilogue, aggregate-first forced.
    def fused():
        return fused_block_spmm_padded(
            bg.blocks, bg.block_row, bg.block_col, featp, w, b, None,
            bg.num_dst_groups, interpret=True)

    out_fused, us_fused = _timed_blocked(fused, repeats)
    speedup = us_unfused / us_fused if us_fused else 0.0
    emit("kernel/agg_combine_pallas_fused", us_fused,
         f"{shape_tag};speedup_vs_unfused={speedup:.2f}")

    max_err = float(jnp.abs(out_fused - ref).max())
    plan = plan_combine_order(bg, f_in, f_out)

    # The planner's auto decision end-to-end (records into the plan log).
    clear_planner_log()
    with aggregate_backend("pallas_fused"):
        _, us_auto = _timed_blocked(
            lambda: aggregate_combine_blocked(bg, featp, w, b,
                                              reduce=ReduceOp.SUM),
            repeats)
    emit("kernel/agg_combine_planner_auto", us_auto,
         f"order={plan.order}")

    return {
        "shape": {"nv": nv, "ne": ne, "f_in": f_in, "f_out": f_out,
                  "v": v, "n": n, "nonzero_tiles": pg.stats.nonzero_tiles},
        "us_jnp_oracle": us_oracle,
        "us_pallas_unfused": us_unfused,
        "us_pallas_fused": us_fused,
        "us_planner_auto": us_auto,
        "fused_vs_unfused_speedup": speedup,
        "fused_max_abs_err_vs_oracle": max_err,
        "planner": plan.to_dict(),
        "planner_decisions": planner_decisions(),
    }


def run_quantized_comparison(nv, ne, f_in, f_out, v, n, repeats=2) -> dict:
    """int8 combine A/B: fused sign-split epilogue vs the unfused quantized
    path (aggregate kernel + per-tensor-scale quantized matmul).

    Both run under backend="pallas_fused"; the unfused arm is forced via an
    explicit kernel-config override (``fused=False``), which is exactly the
    pre-PR-6 behavior quantized models always fell back to.  The fused arm's
    deviation from the jnp quantized oracle is the per-row-block activation
    scale (see fused_block_spmm's tolerance contract) and is reported.
    """
    rng = np.random.default_rng(11)
    g = _make_graph(rng, nv, ne, f_in)
    pg = partition_graph(g, v=v, n=n)
    bg = to_blocked(pg)
    featp = jnp.asarray(pg.pad_features(g.node_feat))
    w = jnp.asarray(rng.standard_normal((f_in, f_out)).astype(np.float32))
    b = jnp.asarray(rng.standard_normal((f_out,)).astype(np.float32))
    shape_tag = f"nv={nv};tiles={pg.stats.nonzero_tiles};f={f_in}->{f_out}"

    with aggregate_backend("jnp"):
        ref = aggregate_combine_blocked(bg, featp, w, b, reduce=ReduceOp.SUM,
                                        quantized=True)

    def quant_fused():
        with aggregate_backend("pallas_fused"):
            return aggregate_combine_blocked(
                bg, featp, w, b, reduce=ReduceOp.SUM, quantized=True)

    out_fused, us_fused = _timed_blocked(quant_fused, repeats)
    emit("kernel/quant_combine_pallas_fused", us_fused, shape_tag)

    unfused_cfg = KernelConfig(fused=False)

    def quant_unfused():
        with aggregate_backend("pallas_fused"), \
                kernel_config_scope(lambda site: unfused_cfg):
            return aggregate_combine_blocked(
                bg, featp, w, b, reduce=ReduceOp.SUM, quantized=True)

    out_unfused, us_unfused = _timed_blocked(quant_unfused, repeats)
    speedup = us_unfused / us_fused if us_fused else 0.0
    emit("kernel/quant_combine_unfused_fallback", us_unfused,
         f"{shape_tag};fused_speedup={speedup:.2f}")

    return {
        "shape": {"nv": nv, "ne": ne, "f_in": f_in, "f_out": f_out,
                  "v": v, "n": n},
        "us_quant_fused": us_fused,
        "us_quant_unfused": us_unfused,
        "fused_vs_unfused_speedup": speedup,
        "unfused_max_abs_err_vs_oracle": float(
            jnp.abs(out_unfused - ref).max()),   # exact: same quant scheme
        "fused_max_abs_diff_vs_oracle": float(
            jnp.abs(out_fused - ref).max()),     # per-row-block-scale drift
    }


def run_autotune_sweep(smoke: bool = False, repeats: int = 2) -> dict:
    """Autotuner search over representative shape classes.

    Searches from a cold cache (the CI gate deletes it first; a stale one
    is re-searched anyway because each run would share the environment
    stamp only on the same host), records the full search trajectory, and
    proves two contracts in-band:

      * the tuned config beats or matches the pre-autotune hardcoded
        default on every class — structural, because the default is always
        candidate 0 and the winner is the argmin over the same run's
        timings;
      * a second tuner warm-started from the persisted cache performs zero
        searches.
    """
    cache_file = cache_path("autotune_cache.json")
    max_candidates = 2 if smoke else None
    tuner = Autotuner(cache_file, repeats=repeats,
                      max_candidates=max_candidates)
    classes = [
        ShapeClass(64, 8, 8, 8, 8, 128, 32, "sum", "float32", False),
        ShapeClass(64, 8, 8, 8, 8, 128, 32, "sum", "float32", True),
        ShapeClass(64, 8, 8, 8, 8, 128, 32, "max", "float32", False),
        ShapeClass(128, 16, 16, 8, 8, 256, 64, "sum", "float32", False),
    ]
    if smoke:
        classes = classes[:2]
    for sc in classes:
        tuner.ensure(sc)
    for t in tuner.trajectory:
        emit("kernel/autotune", t.tuned_us,
             f"{t.shape_class};default={t.baseline_us:.1f}us;"
             f"speedup={t.speedup_vs_baseline:.2f}")

    # Warm-start proof: a fresh tuner over the same classes hits the
    # persisted cache for every one.
    warm = Autotuner(cache_file, repeats=repeats,
                     max_candidates=max_candidates)
    for sc in classes:
        warm.ensure(sc)

    return {
        "cache_path": cache_file,
        "classes": [sc.key() for sc in classes],
        "max_candidates": max_candidates,
        "searches": tuner.searches,
        "warm_searches": warm.searches,   # must be 0 (cache round-trip)
        "tuned_beats_or_matches_default": all(
            t.tuned_us <= t.baseline_us for t in tuner.trajectory),
        "trajectory": [t.to_dict() for t in tuner.trajectory],
    }


def run(quick: bool = True, smoke: bool = False):
    rng = np.random.default_rng(0)
    if smoke:
        nv, ne, f = 120, 600, 16
        fused_shape = (120, 600, 160, 32, 8, 8)
        repeats = 1
    elif quick:
        nv, ne, f = 400, 2000, 128
        fused_shape = (400, 2000, 256, 64, 20, 20)
        repeats = 2
    else:
        nv, ne, f = 2000, 10000, 512
        fused_shape = (2000, 10000, 512, 128, 20, 20)
        repeats = 2
    g = _make_graph(rng, nv, ne, f)
    pg = partition_graph(g, v=20, n=20)
    featp = jnp.asarray(pg.pad_features(g.node_feat))

    _, us_interp = _timed_blocked(
        lambda: aggregate_blocked_kernel(pg, featp, block_f=128,
                                         interpret=True), repeats)
    emit("kernel/block_spmm_interp", us_interp,
         f"tiles={pg.stats.nonzero_tiles};skip={pg.stats.skipped_fraction:.2f}")

    bg = to_blocked(pg)
    _, us_jnp = _timed_blocked(
        lambda: aggregate_blocked(bg, featp, ReduceOp.SUM), repeats + 1)
    emit("kernel/block_spmm_jnp_ref", us_jnp, "oracle")

    fused_doc = run_fused_comparison(*fused_shape, repeats=repeats)
    quant_doc = run_quantized_comparison(*fused_shape, repeats=repeats)
    autotune_doc = run_autotune_sweep(smoke=smoke, repeats=repeats)

    m, k, n = (64, 128, 64) if smoke else (
        (128, 256, 128) if quick else (512, 1024, 512))
    x = jnp.asarray(rng.standard_normal((m, k)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal((k, n)).astype(np.float32))
    _, us = _timed_blocked(
        lambda: quantized_matmul_kernel(x, w, interpret=True), repeats)
    emit("kernel/quant_matmul_interp", us, f"{m}x{k}x{n}")
    _, us = _timed_blocked(lambda: quantized_matmul(x, w), repeats + 1)
    emit("kernel/quant_matmul_jnp_ref", us, "oracle")

    return bench_json({
        "bench": "kernel_micro",
        "note": "CPU interpret-mode timings: per-grid-step dispatch "
                "dominates; fused-vs-unfused compares completed compute "
                "(block_until_ready) on the same shape",
        "us_block_spmm_interp": us_interp,
        "us_block_spmm_jnp_ref": us_jnp,
        "fused": fused_doc,
        "quantized": quant_doc,
        "autotune": autotune_doc,
    })
