"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines (benchmarks.common.emit).
Default is --quick (CPU-friendly subset per figure); --full covers every
(model x dataset) cell the paper reports.
"""

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="all (model x dataset) cells (slow on CPU)")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset, e.g. fig8,table3")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: tiny-size serving benchmark only, so "
                         "BENCH_JSON regressions are caught on every PR")
    args = ap.parse_args()
    quick = not args.full

    from benchmarks import (
        fig7_device_dse,
        fig7c_arch_dse,
        fig8_orchestration,
        fig9_breakdown,
        fig10_12_comparison,
        kernel_micro,
        serving_throughput,
        table2_datasets,
        table3_accuracy,
    )

    suites = {
        "table2": table2_datasets.run,
        "table3": table3_accuracy.run,
        "fig7": fig7_device_dse.run,
        "fig7c": fig7c_arch_dse.run,
        "fig8": fig8_orchestration.run,
        "fig9": fig9_breakdown.run,
        "fig10_12": fig10_12_comparison.run,
        "kernels": kernel_micro.run,
        "serving": serving_throughput.run,
    }
    if args.smoke:
        if args.only or args.full:
            ap.error("--smoke is a fixed tiny suite; drop --only/--full")
        suites = {
            "serving": lambda quick: serving_throughput.run(
                quick=True, requests=12, working_set=4, slots=4,
                ticks=16, arrivals=4),
            # Tiny fused-vs-unfused kernel comparison so BENCH_JSON perf
            # regressions in the Pallas path are caught on every PR too.
            "kernels": lambda quick: kernel_micro.run(quick=True,
                                                      smoke=True),
        }
    selected = (args.only.split(",") if args.only else list(suites))

    print("name,us_per_call,derived")
    t0 = time.time()
    for name in selected:
        try:
            suites[name](quick=quick)
        except Exception as e:  # keep the harness running; report the failure
            print(f"{name}/ERROR,0.0,{type(e).__name__}:{e}", file=sys.stderr)
            raise
    print(f"# total {time.time() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
