"""Table 3: 8-bit vs 32-bit GNN accuracy parity.

Synthetic Table-2 datasets (offline container — DESIGN.md §6); the
reproduction target is the fp32-vs-int8 accuracy DELTA, which the paper
reports as <= ~2 points everywhere.
"""

from __future__ import annotations

import time

from benchmarks.common import cached_json, emit
from repro.gnn import build_model, load
from repro.gnn.datasets import TABLE2
from repro.gnn.train import (
    eval_graph_classifier,
    eval_node_classifier,
    train_graph_classifier,
    train_node_classifier,
)

QUICK_COMBOS = [
    ("gcn", "Cora"), ("sage", "Cora"), ("gat", "Cora"), ("gin", "Mutag"),
]
FULL_COMBOS = [
    (m, d) for m in ("gcn", "sage", "gat")
    for d in ("Cora", "PubMed", "Citeseer", "Amazon")
] + [("gin", d) for d in ("Proteins", "Mutag", "BZR", "IMDB-binary")]


def run_one(model_name: str, dataset: str, steps: int = 120) -> dict:
    spec = TABLE2[dataset]
    if model_name == "gin":
        graphs = load(dataset, seed=0, num_graphs=min(spec["graphs"], 120))
        model = build_model("gin", graphs[0].num_features, spec["labels"],
                            hidden=16, mlp_layers=2)
        params, test_set = train_graph_classifier(model, graphs, steps=steps)
        fp32 = eval_graph_classifier(model, params, test_set)
        int8 = eval_graph_classifier(model, params, test_set, quantized=True)
    else:
        graph = load(dataset, seed=0)
        kw = dict(hidden=8, heads=8) if model_name == "gat" else dict(hidden=64)
        model = build_model(model_name, spec["features"], spec["labels"], **kw)
        params, _ = train_node_classifier(model, graph, steps=steps, lr=0.01)
        fp32 = eval_node_classifier(model, params, graph)
        int8 = eval_node_classifier(model, params, graph, quantized=True)
    return {"fp32": fp32, "int8": int8, "delta": fp32 - int8}


def run(quick: bool = True):
    combos = QUICK_COMBOS if quick else FULL_COMBOS
    worst = 0.0
    for model_name, dataset in combos:
        t0 = time.time()
        r = cached_json(f"table3_{model_name}_{dataset}",
                        lambda m=model_name, d=dataset: run_one(m, d))
        dt = (time.time() - t0) * 1e6
        emit(f"table3/{model_name}/{dataset}", dt,
             f"fp32={r['fp32']:.3f};int8={r['int8']:.3f};delta={r['delta']:+.3f}")
        worst = max(worst, abs(r["delta"]))
    emit("table3/worst_abs_delta", 0.0, f"{worst:.3f}")
    return worst
