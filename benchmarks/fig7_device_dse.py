"""Fig. 7a/b + Section 4.2: MR-bank device-level design-space exploration.

Reproduction targets: coherent banks support 20 MRs at 1520 nm; non-coherent
WDM banks support 18 wavelengths (36 MRs) from 1550 nm at 1 nm spacing;
required SNR ~= 21.2-21.3 dB for N_levels = 2^7.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timed
from repro.photonic.mrbank import (
    coherent_surface,
    noncoherent_surface,
    selected_design,
)
from repro.photonic.noise import MRDesign


def run(quick: bool = True):
    design = MRDesign()

    sel, us = timed(selected_design, design)
    emit("fig7/selected_design", us,
         f"coherent={sel['coherent_bank_limit']}MRs@{sel['coherent_wavelength_nm']:.0f}nm;"
         f"wdm={sel['noncoherent_wdm_limit']}lambda;"
         f"snr_req={sel['required_snr_db']:.2f}dB")

    surf, us = timed(
        coherent_surface, np.arange(1500, 1581, 10.0), range(1, 33), design)
    feas = [p for p in surf if p.feasible]
    emit("fig7a/coherent_surface", us,
         f"points={len(surf)};feasible={len(feas)};"
         f"max_mrs={max((p.num_elements for p in feas), default=0)}")

    surf, us = timed(noncoherent_surface, range(1, 33), design)
    feas = [p for p in surf if p.feasible]
    emit("fig7b/noncoherent_surface", us,
         f"points={len(surf)};max_wavelengths={max((p.num_elements for p in feas), default=0)};"
         f"max_rings={2 * max((p.num_elements for p in feas), default=0)}")
    return sel
