"""Property-based tests (hypothesis only; auto-skipped when absent).

The core equivalence the whole GHOST dataflow rests on: the blocked V x N
aggregation must match the edge-list oracle for *any* multigraph — duplicate
edges, isolated vertices, self loops, and node counts that don't divide the
group sizes — across all three reduce modes.  The fused kernel's int8
sign-split combine epilogue additionally must stay within its *documented*
tolerance of the per-tensor-scale quantized oracle on the same graph space.
"""

import pytest

hypothesis = pytest.importorskip("hypothesis")

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import (
    Graph,
    ReduceOp,
    aggregate_backend,
    aggregate_blocked,
    aggregate_combine_blocked,
    aggregate_edges,
    dense_combine,
    partition_graph,
    to_blocked,
)


@st.composite
def multigraphs(draw):
    """Random multigraph: duplicates and isolated vertices arise naturally
    (endpoints sampled with replacement; nv can exceed touched vertices)."""
    nv = draw(st.integers(1, 60))
    ne = draw(st.integers(0, 150))
    f = draw(st.integers(1, 9))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    src = rng.integers(0, nv, ne).astype(np.int32)
    dst = rng.integers(0, nv, ne).astype(np.int32)
    if ne >= 2 and draw(st.booleans()):
        # Force exact duplicate edges (same src AND dst) into the list.
        k = draw(st.integers(1, min(ne, 10)))
        src = np.concatenate([src, src[:k]])
        dst = np.concatenate([dst, dst[:k]])
    feat = rng.standard_normal((nv, f)).astype(np.float32)
    return Graph(edge_src=src, edge_dst=dst, node_feat=feat).validate()


@settings(deadline=None)
@given(
    multigraphs(),
    st.integers(1, 13),
    st.integers(1, 13),
    st.sampled_from([ReduceOp.SUM, ReduceOp.MEAN, ReduceOp.MAX]),
)
def test_blocked_equals_edge_oracle(g, v, n, reduce):
    pg = partition_graph(g, v=v, n=n)
    bg = to_blocked(pg)
    featp = jnp.asarray(pg.pad_features(g.node_feat))
    ref = aggregate_edges(jnp.asarray(g.edge_src), jnp.asarray(g.edge_dst),
                          jnp.asarray(g.node_feat), g.num_nodes, reduce)
    got = aggregate_blocked(bg, featp, reduce)[: g.num_nodes]
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)


@settings(deadline=None)
@given(multigraphs(), st.integers(1, 13), st.integers(1, 13))
def test_blocked_padding_rows_are_benign(g, v, n):
    """Rows past the true node count never receive aggregation mass (SUM)."""
    pg = partition_graph(g, v=v, n=n)
    bg = to_blocked(pg)
    featp = jnp.asarray(pg.pad_features(g.node_feat))
    out = np.asarray(aggregate_blocked(bg, featp, ReduceOp.SUM))
    np.testing.assert_array_equal(out[g.num_nodes:], 0.0)


@settings(deadline=None, max_examples=20)
@given(
    multigraphs(),
    st.integers(1, 13),       # v: odd group sizes exercise lane padding
    st.integers(1, 13),       # n
    st.integers(1, 9),        # f_out
    st.integers(0, 2**31 - 1),
)
def test_int8_fused_epilogue_within_documented_bound(g, v, n, f_out, wseed):
    """The fused int8 combine epilogue vs the unfused per-tensor-scale
    oracle, across arbitrary multigraphs (including zero-edge graphs, whose
    unvisited rows must come out as exact bias rows in both paths).

    Weight quantization is byte-identical in both paths, so the only
    divergence is activation rounding under two scale granularities: the
    kernel's per-destination-row-block scale vs the oracle's per-tensor
    scale.  Each rounds with error <= scale/2 per element, giving the
    fused kernel's documented bound
        |fused - oracle|[i, j] <= 0.5 * (s_blk(i) + s_tensor)
                                      * sum_k |W_deq[k, j]|.
    """
    from repro.photonic.quant import QuantConfig, quantize_weights

    pg = partition_graph(g, v=v, n=n)
    bg = to_blocked(pg)
    featp = jnp.asarray(pg.pad_features(g.node_feat))
    f_in = g.node_feat.shape[1]
    rng = np.random.default_rng(wseed)
    w = jnp.asarray(rng.standard_normal((f_in, f_out)).astype(np.float32))
    b = jnp.asarray(rng.standard_normal((f_out,)).astype(np.float32))

    h = np.asarray(aggregate_blocked(bg, featp, ReduceOp.SUM))
    ref = np.asarray(dense_combine(jnp.asarray(h), w, b, quantized=True))
    with aggregate_backend("pallas_fused"):
        got = np.asarray(aggregate_combine_blocked(
            bg, featp, w, b, reduce=ReduceOp.SUM, quantized=True))

    s_tensor = max(np.abs(h).max(), 1e-12) / 127.0
    blocks = h.reshape(bg.num_dst_groups, bg.v, f_in)
    s_blk = np.maximum(np.abs(blocks).max(axis=(1, 2)), 1e-12) / 127.0
    wq, sw = quantize_weights(np.asarray(w), QuantConfig())
    colsum = np.abs(np.asarray(wq, np.float32) * np.asarray(sw)).sum(axis=0)
    bound = (0.5 * (np.repeat(s_blk, bg.v) + s_tensor)[:, None]
             * colsum[None, :])
    diff = np.abs(got - ref)
    assert np.all(diff <= bound + 1e-4), float((diff - bound).max())
    if g.num_edges == 0:
        # Every row is an all-zero aggregation: exact bias rows, both paths.
        np.testing.assert_allclose(got, np.broadcast_to(np.asarray(b),
                                                        got.shape), atol=1e-6)


@settings(deadline=None)
@given(multigraphs(), st.integers(1, 13), st.integers(1, 13))
def test_partition_reconstructs_multigraph_dense(g, v, n):
    """Tile values accumulate duplicate-edge multiplicity exactly."""
    pg = partition_graph(g, v=v, n=n)
    dense = np.zeros((g.num_nodes, g.num_nodes), np.float32)
    np.add.at(dense, (g.edge_dst, g.edge_src), 1.0)
    got = pg.reconstruct_dense()[: g.num_nodes, : g.num_nodes]
    np.testing.assert_allclose(got, dense, atol=1e-6)
