"""Always-on serve loop + SLO-aware deadline scheduling.

The load-bearing claims of the async serving refactor:
  * the always-on loop delivers every concurrently-submitted request
    exactly once — no lost rids, no duplicates — while N client threads
    submit against it;
  * async serving is BIT-EXACT vs the tick-driven loop for an identical
    request set, on all three backends (per-request outputs are
    batch-composition-independent, so how batches happen to form cannot
    change any answer);
  * ``DeadlineScheduler`` is occupancy-greedy with slack, preempts to EDF
    when a head deadline is at risk, and its ``max_age_s`` bound keeps
    no-SLO traffic (infinite slack) from starving;
  * admission decisions are atomic with queue mutation (the waiting bound
    cannot overshoot under concurrent submitters) and the shed victim is
    the waiting request with the least salvageable slack;
  * ``slo_ms`` threads end to end: registry validation, per-request
    ``slo_met``, per-model p99-vs-SLO attainment in the report;
  * lifecycle contracts: ``step``/``run`` refuse while the loop runs, a
    crashed loop surfaces its error instead of hanging clients, shed rids
    raise KeyError from blocking pickup.
"""

import math
import threading
import time

import jax
import numpy as np
import pytest

from repro.core.graph import Graph
from repro.gnn import build_model
from repro.photonic.perf import GhostConfig
from repro.serving import (
    DeadlineScheduler,
    GnnServeEngine,
    GroupState,
    RequestRecord,
    SCHEDULERS,
    make_scheduler,
    slo_attainment_from,
)

CFG = GhostConfig(v=8, n=8)


def make_graph(seed, nv, ne, f=5):
    rng = np.random.default_rng(seed)
    return Graph(
        edge_src=rng.integers(0, nv, ne).astype(np.int32),
        edge_dst=rng.integers(0, nv, ne).astype(np.int32),
        node_feat=rng.standard_normal((nv, f)).astype(np.float32),
    ).validate()


def build(f=5, seed=0):
    model = build_model("gcn", f, 2, hidden=4)
    return model, model.init(jax.random.PRNGKey(seed))


# ---------------------------------------------------------------------------
# DeadlineScheduler policy (pure unit tests: no engine, no clocks).
# ---------------------------------------------------------------------------


def g_state(key, size, head_seq, age_s=0.0,
            deadline=math.inf, slack=math.inf):
    return GroupState(key=key, size=size, head_seq=head_seq,
                      head_wait_ticks=0, head_age_s=age_s,
                      head_deadline_s=deadline, head_slack_s=slack)


def test_deadline_relaxed_is_occupancy_greedy():
    sched = DeadlineScheduler(urgent_slack_s=0.01)
    groups = [g_state("small", size=2, head_seq=0, deadline=5.0, slack=4.0),
              g_state("full", size=8, head_seq=3, deadline=9.0, slack=8.0)]
    assert sched.select(groups, slots=8) == "full"


def test_deadline_relaxed_ties_break_by_earliest_deadline():
    sched = DeadlineScheduler(urgent_slack_s=0.01)
    # Both fill the batch; the earlier head deadline wins.
    groups = [g_state("late", size=9, head_seq=0, deadline=9.0, slack=8.0),
              g_state("soon", size=4, head_seq=5, deadline=5.0, slack=4.0)]
    assert sched.select(groups, slots=4) == "soon"


def test_deadline_urgent_preempts_occupancy():
    sched = DeadlineScheduler(urgent_slack_s=0.01)
    # A lone at-risk request beats a full relaxed group.
    groups = [g_state("full", size=8, head_seq=0, deadline=9.0, slack=8.0),
              g_state("risk", size=1, head_seq=7, deadline=1.0, slack=0.005)]
    assert sched.select(groups, slots=8) == "risk"


def test_deadline_urgent_is_edf_among_urgent():
    sched = DeadlineScheduler(urgent_slack_s=0.01)
    groups = [
        g_state("blown", size=1, head_seq=9, deadline=2.0, slack=-0.5),
        g_state("closer", size=1, head_seq=5, deadline=1.0, slack=0.002),
        g_state("calm", size=8, head_seq=0, deadline=9.0, slack=8.0),
    ]
    # Both urgent; 'closer' has the earlier absolute deadline.
    assert sched.select(groups, slots=8) == "closer"


def test_deadline_max_age_rescues_no_slo_traffic():
    sched = DeadlineScheduler(urgent_slack_s=0.01, max_age_s=0.5)
    # Infinite slack (no SLO) but past the age bound -> urgent.
    groups = [g_state("hot", size=8, head_seq=5, deadline=4.0, slack=3.0),
              g_state("noslo", size=1, head_seq=0, age_s=0.6)]
    assert sched.select(groups, slots=8) == "noslo"
    # Under the age bound it stays occupancy-greedy.
    calm = [g_state("hot", size=8, head_seq=5, deadline=4.0, slack=3.0),
            g_state("noslo", size=1, head_seq=0, age_s=0.1)]
    assert sched.select(calm, slots=8) == "hot"


def test_deadline_factory_and_validation():
    assert "deadline" in SCHEDULERS
    sched = make_scheduler("deadline", urgent_slack_s=0.02)
    assert sched.name == "deadline" and sched.urgent_slack_s == 0.02
    with pytest.raises(ValueError):
        DeadlineScheduler(urgent_slack_s=-1.0)
    with pytest.raises(ValueError):
        DeadlineScheduler(max_age_s=0.0)


# ---------------------------------------------------------------------------
# Attainment math (pure accounting).
# ---------------------------------------------------------------------------


def _rec(model_id, lat_ms, slo_ms, rid=0):
    return RequestRecord(
        rid=rid, model_id=model_id, num_nodes=4, num_edges=4, bucket="b",
        cache_hit=False, latency_s=lat_ms / 1e3, batch_size=1,
        slo_ms=slo_ms,
        slo_met=(lat_ms <= slo_ms) if slo_ms else None)


def test_slo_attainment_math():
    records = (
        [_rec("tight", 5.0, 10.0)] * 3 + [_rec("tight", 50.0, 10.0)]
        + [_rec("loose", 40.0, 100.0)] * 2
        + [_rec("free", 7.0, 0.0)] * 5      # no SLO: excluded entirely
    )
    att = slo_attainment_from(records)
    assert att["served"] == 6 and att["met"] == 5
    assert att["attainment"] == pytest.approx(5 / 6)
    tight = att["per_model"]["tight"]
    assert tight["served"] == 4 and tight["met"] == 3
    assert tight["attainment"] == pytest.approx(0.75)
    assert tight["slo_ms"] == 10.0
    assert tight["p99_latency_ms"] > 10.0      # the miss dominates p99
    assert tight["p99_over_slo"] > 1.0
    loose = att["per_model"]["loose"]
    assert loose["attainment"] == 1.0 and loose["p99_over_slo"] < 1.0
    assert "free" not in att["per_model"]
    assert slo_attainment_from([_rec("free", 7.0, 0.0)]) == {}


def test_registry_slo_validation_and_engine_threading():
    g = make_graph(0, nv=12, ne=20)
    model, params = build()
    eng = GnnServeEngine(cfg=CFG, slots=2, scheduler="deadline")
    entry = eng.register("slo", model, params, slo_ms=10_000.0)
    assert entry.slo_ms == 10_000.0
    eng.register("free", model, params)
    with pytest.raises(ValueError):
        eng.register("bad", model, params, slo_ms=0.0)

    r_slo = eng.submit("slo", g)
    r_free = eng.submit("free", g)
    eng.drain()
    rec_slo = next(r for r in eng.records if r.rid == r_slo)
    rec_free = next(r for r in eng.records if r.rid == r_free)
    assert rec_slo.slo_ms == 10_000.0 and rec_slo.slo_met is True
    assert math.isfinite(rec_slo.deadline_s)
    assert rec_free.slo_ms == 0.0 and rec_free.slo_met is None
    assert rec_free.deadline_s == math.inf
    rep = eng.report(1.0)
    assert rep.slo_attainment["per_model"]["slo"]["attainment"] == 1.0
    assert "free" not in rep.slo_attainment["per_model"]
    assert "SLO attainment" in rep.pretty()


# ---------------------------------------------------------------------------
# Async vs tick bit-exactness.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["jnp", "pallas", "pallas_fused"])
def test_async_loop_bit_exact_vs_tick_loop(backend):
    """Identical request set, identical per-request outputs — regardless of
    how the always-on loop happened to slice batches."""
    graphs = [make_graph(s, nv=12 + 4 * (s % 3), ne=30) for s in range(6)]
    model, params = build()

    def fresh():
        eng = GnnServeEngine(cfg=CFG, slots=4, backend=backend,
                             scheduler="deadline")
        eng.register("a", model, params, slo_ms=50.0)
        eng.register("b", model, params)
        return eng

    tick = fresh()
    for i, g in enumerate(graphs):
        tick.submit("a" if i % 2 else "b", g)
    tick.drain()

    async_eng = fresh().start()
    rids = [async_eng.submit("a" if i % 2 else "b", g)
            for i, g in enumerate(graphs)]
    async_eng.stop(drain=True)

    assert rids == list(range(len(graphs)))  # same rid space as tick mode
    for rid in rids:
        np.testing.assert_array_equal(async_eng.results[rid],
                                      tick.results[rid])


# ---------------------------------------------------------------------------
# Concurrent submitters against the running loop.
# ---------------------------------------------------------------------------


def test_concurrent_submitters_exactly_once_delivery():
    n_threads, per_thread = 6, 8
    graphs = [make_graph(s, nv=10 + 4 * s, ne=25) for s in range(3)]
    model, params = build()
    eng = GnnServeEngine(cfg=CFG, slots=4, scheduler="deadline")
    eng.register("m", model, params, slo_ms=60_000.0)
    eng.start()

    rid_lists = [[] for _ in range(n_threads)]
    errors = []

    def client(t):
        try:
            for j in range(per_thread):
                rid_lists[t].append(
                    eng.submit("m", graphs[(t + j) % len(graphs)]))
        except BaseException as e:  # surfaced below, not swallowed
            errors.append(e)

    threads = [threading.Thread(target=client, args=(t,))
               for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    eng.stop(drain=True)

    assert not errors
    all_rids = [rid for rids in rid_lists for rid in rids]
    total = n_threads * per_thread
    # No lost or duplicated rids...
    assert len(all_rids) == total
    assert len(set(all_rids)) == total
    # ...every one delivered exactly once...
    for rid in all_rids:
        out = eng.take_result(rid)
        assert out.shape[1] == 2
        with pytest.raises(KeyError):
            eng.take_result(rid)
    # ...and accounting agrees.
    assert sorted(r.rid for r in eng.records) == sorted(all_rids)
    assert eng.admission.stats.admitted == total
    rep = eng.report(1.0)
    assert rep.requests == total
    assert rep.slo_attainment["served"] == total


def test_blocking_result_pickup_and_lifecycle_contracts():
    g = make_graph(1, nv=12, ne=20)
    model, params = build()
    eng = GnnServeEngine(cfg=CFG, slots=2)
    eng.register("m", model, params)
    eng.start()
    with pytest.raises(RuntimeError):
        eng.start()                      # already running
    with pytest.raises(RuntimeError):
        eng.step()                       # the loop owns batch formation
    with pytest.raises(RuntimeError):
        eng.run([g])
    rid = eng.submit("m", g)
    out = eng.result(rid, timeout=60.0)  # blocking pickup pops
    assert out.shape[0] == g.num_nodes
    with pytest.raises(KeyError):
        eng.take_result(rid)             # already taken
    eng.stop()
    eng.stop()                           # idempotent
    with pytest.raises(KeyError):
        eng.result(rid, timeout=0.1)     # loop idle + unknown -> immediate
    # Restartable: the queue and executors survive a stop/start cycle.
    eng.start()
    rid2 = eng.submit("m", g)
    np.testing.assert_array_equal(eng.result(rid2, timeout=60.0), out)
    eng.stop()


def test_serve_loop_crash_surfaces_to_clients():
    g = make_graph(2, nv=12, ne=20)
    model, params = build()
    eng = GnnServeEngine(cfg=CFG, slots=2)
    eng.register("m", model, params)

    def boom(*a, **kw):
        raise RuntimeError("executor exploded")

    eng.pool.executor = boom
    eng.start()
    rid = eng.submit("m", g)
    with pytest.raises(RuntimeError, match="serve loop failed"):
        eng.result(rid, timeout=30.0)
    with pytest.raises(RuntimeError, match="serve loop failed"):
        eng.stop()


# ---------------------------------------------------------------------------
# Admission under concurrency + deadline-aware shed.
# ---------------------------------------------------------------------------


def test_admission_bound_never_overshoots_under_concurrency():
    """Many threads race a bounded queue with no consumer: exactly
    max_waiting admissions may land, no matter the interleaving."""
    bound, n_threads, per_thread = 4, 8, 6
    g = make_graph(3, nv=12, ne=20)
    model, params = build()
    eng = GnnServeEngine(cfg=CFG, slots=2, max_waiting=bound)
    eng.register("m", model, params)

    outcomes = []
    lock = threading.Lock()

    def client():
        for _ in range(per_thread):
            rid = eng.try_submit("m", g)
            with lock:
                outcomes.append(rid)

    threads = [threading.Thread(target=client) for _ in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()

    admitted = [r for r in outcomes if r is not None]
    assert len(admitted) == bound
    assert eng.num_waiting == bound
    assert eng.admission.stats.admitted == bound
    assert eng.admission.stats.rejected == n_threads * per_thread - bound
    eng.drain()
    assert sorted(eng.results) == sorted(admitted)


def test_shed_victim_has_least_salvageable_slack():
    g = make_graph(4, nv=12, ne=20)
    model, params = build()
    eng = GnnServeEngine(cfg=CFG, slots=2, max_waiting=2,
                         admission_policy="shed-oldest")
    eng.register("tight", model, params, slo_ms=5.0)
    eng.register("loose", model, params, slo_ms=60_000.0)
    r_loose = eng.submit("loose", g)   # oldest, but its deadline is far
    r_tight = eng.submit("tight", g)   # nearest deadline = least slack
    r_new = eng.submit("loose", g)     # queue full -> shed decides
    assert eng.shed_rids == [r_tight]
    eng.drain()
    assert r_loose in eng.results and r_new in eng.results
    # Blocking pickup tells the truth about the victim.
    with pytest.raises(KeyError, match="shed"):
        eng.result(r_tight, timeout=1.0)


def test_deadline_scheduler_preempts_in_engine():
    """End to end: a tight-SLO straggler jumps a full loose-SLO group the
    moment its slack is gone."""
    hot = make_graph(5, nv=16, ne=40)
    cold = make_graph(6, nv=60, ne=150)    # different bucket
    model, params = build()
    eng = GnnServeEngine(
        cfg=CFG, slots=4,
        scheduler=DeadlineScheduler(urgent_slack_s=10.0, max_age_s=None))
    eng.register("loose", model, params, slo_ms=60_000.0)
    eng.register("tight", model, params, slo_ms=1_000.0)  # slack < 10s now
    for _ in range(4):
        eng.submit("loose", hot)
    tight_rid = eng.submit("tight", cold)
    eng.step()
    # The tight request was urgent on arrival (1s deadline vs 10s margin),
    # so it preempted the full loose batch.
    assert tight_rid in eng.results
    assert eng.records[0].rid == tight_rid
