"""Pallas kernels vs pure-jnp oracles (interpret mode on CPU).

Per the deliverable contract: sweep shapes/dtypes and assert_allclose
against ref.py for each kernel.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Graph, partition_graph
from repro.kernels import (
    aggregate_blocked_kernel,
    block_spmm_padded,
    quantized_matmul_kernel,
)
from repro.kernels.ref import block_spmm_ref, quant_matmul_ref
from repro.kernels.quant_matmul import quant_matmul
from repro.photonic.quant import quantized_matmul as quant_ref_float


def make_partitioned(seed, nv, ne, f, v, n):
    rng = np.random.default_rng(seed)
    g = Graph(
        edge_src=rng.integers(0, nv, ne).astype(np.int32),
        edge_dst=rng.integers(0, nv, ne).astype(np.int32),
        node_feat=rng.standard_normal((nv, f)).astype(np.float32),
    ).validate()
    return g, partition_graph(g, v=v, n=n)


@pytest.mark.parametrize("nv,ne,f,v,n,bf", [
    (64, 200, 32, 8, 8, 32),
    (100, 450, 48, 16, 4, 16),
    (37, 90, 20, 5, 7, 64),     # padding path (f < bf)
    (128, 700, 128, 8, 16, 128),
])
def test_block_spmm_shapes(nv, ne, f, v, n, bf):
    g, pg = make_partitioned(0, nv, ne, f, v, n)
    featp = jnp.asarray(pg.pad_features(g.node_feat))
    got = block_spmm_padded(
        jnp.asarray(pg.blocks), jnp.asarray(pg.block_row),
        jnp.asarray(pg.block_col), featp, pg.num_dst_groups,
        block_f=bf, interpret=True)
    ref = block_spmm_ref(
        jnp.asarray(pg.blocks), jnp.asarray(pg.block_row),
        jnp.asarray(pg.block_col), featp, pg.num_dst_groups)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_block_spmm_dtypes(dtype):
    g, pg = make_partitioned(3, 60, 250, 32, 8, 8)
    featp = jnp.asarray(pg.pad_features(g.node_feat)).astype(dtype)
    got = aggregate_blocked_kernel(pg, featp, block_f=32, interpret=True)
    ref = block_spmm_ref(
        jnp.asarray(pg.blocks), jnp.asarray(pg.block_row),
        jnp.asarray(pg.block_col), featp, pg.num_dst_groups)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


def test_block_spmm_empty_rows():
    """Destination groups with no tiles must come out zero."""
    nv = 40
    src = np.arange(10, dtype=np.int32)
    dst = np.full(10, 39, np.int32)  # everything lands in the last group
    g = Graph(edge_src=src, edge_dst=dst,
              node_feat=np.random.default_rng(0)
              .standard_normal((nv, 8)).astype(np.float32)).validate()
    pg = partition_graph(g, v=8, n=8)
    featp = jnp.asarray(pg.pad_features(g.node_feat))
    got = aggregate_blocked_kernel(pg, featp, block_f=8, interpret=True)
    ref = block_spmm_ref(jnp.asarray(pg.blocks), jnp.asarray(pg.block_row),
                         jnp.asarray(pg.block_col), featp, pg.num_dst_groups)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-5)
    assert np.abs(np.asarray(got[:32])).max() == 0.0


@pytest.mark.parametrize("m,k,n,bm,bn,bk", [
    (64, 64, 64, 32, 32, 32),
    (128, 256, 64, 64, 64, 128),
    (70, 130, 50, 32, 32, 64),   # ragged -> padding path
    (16, 16, 16, 16, 16, 16),
])
def test_quant_matmul_shapes(m, k, n, bm, bn, bk):
    rng = np.random.default_rng(m + k + n)
    x = jnp.asarray(rng.standard_normal((m, k)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal((k, n)).astype(np.float32))
    got = quantized_matmul_kernel(x, w, block_m=bm, block_n=bn, block_k=bk,
                                  interpret=True)
    ref = quant_ref_float(x, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=1e-3, rtol=1e-3)


def test_quant_matmul_int8_exact_vs_ref():
    """The int8 kernel accumulation is EXACT vs the int32 oracle."""
    rng = np.random.default_rng(9)
    xq = jnp.asarray(rng.integers(-127, 128, (32, 64)), jnp.int8)
    wq = jnp.asarray(rng.integers(-127, 128, (64, 32)), jnp.int8)
    sx = jnp.asarray([0.013], jnp.float32)
    sw = jnp.asarray(rng.random(32), jnp.float32)
    got = quant_matmul(xq, wq, sx, sw, block_m=16, block_n=16, block_k=32,
                       interpret=True)
    ref = quant_matmul_ref(xq, wq, sx, sw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-6)


def test_kernel_agrees_with_gnn_aggregate():
    """End-to-end: kernel path == core.aggregate_blocked on GCN-normalized
    weights (the serving configuration)."""
    from repro.core import ReduceOp, aggregate_blocked, to_blocked
    g, _ = make_partitioned(5, 80, 320, 16, 8, 8)
    g = g.with_self_loops()
    pg = partition_graph(g, v=8, n=8, edge_weights=g.gcn_edge_weights())
    featp = jnp.asarray(pg.pad_features(g.node_feat))
    a = aggregate_blocked_kernel(pg, featp, block_f=16, interpret=True)
    b = aggregate_blocked(to_blocked(pg), featp, ReduceOp.SUM)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)
