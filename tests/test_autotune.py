"""Shape-class kernel autotuner: search, cache round-trip, serving wiring.

The load-bearing claims:
  * the candidate list always leads with the pre-autotune hardcoded
    behavior, so the tuned winner beats or matches it by construction (and
    the CI smoke budget truncates from the back, never dropping it);
  * winners persist to JSON and a warm-started tuner performs ZERO
    searches (the ``searches`` counter is the proof, not timing);
  * a cache written under another jax version / device kind is discarded
    wholesale on load (stale winners are re-searched, never reused);
  * resolved configs actually steer the lowering (a forced ``fused=False``
    config must reproduce the unfused path bit-for-bit);
  * the executor pool resolves configs at trace-build time through an
    abstract recording pre-pass that does NOT inflate the trace count, and
    an explicit ``kernel_config`` override beats the tuner.
"""

import json

import jax
import numpy as np
import pytest

from repro.core import Graph, ReduceOp, aggregate_combine_blocked, \
    aggregate_backend, aggregate_blocked, dense_combine, kernel_config_scope, \
    partition_graph, to_blocked
from repro.core.aggregate import KernelSite
from repro.gnn import build_model
from repro.kernels import (
    Autotuner,
    AutotuneCache,
    KernelConfig,
    ShapeClass,
    candidate_configs,
    synthesize_problem,
)
from repro.kernels.autotune import baseline_config
from repro.serving.bucketing import next_pow2


SITE = KernelSite(num_blocks=50, num_dst_groups=6, num_src_groups=6,
                  v=8, n=8, f_in=24, f_out=16, reduce="sum",
                  dtype="float32", quantized=False, backend="pallas_fused")
TINY = ShapeClass(8, 2, 2, 4, 4, 8, 8, "sum", "float32", False)


def make_tuner(tmp_path, **kw):
    kw.setdefault("repeats", 1)
    kw.setdefault("max_candidates", 2)
    return Autotuner(str(tmp_path / "autotune.json"), **kw)


# ---------------------------------------------------------------------------
# Shape classes and candidate enumeration (pure, no timing).
# ---------------------------------------------------------------------------


def test_shape_class_buckets_match_serving_rounding():
    sc = ShapeClass.from_site(SITE)
    assert sc.num_blocks == next_pow2(SITE.num_blocks) == 64
    assert sc.num_dst_groups == next_pow2(SITE.num_dst_groups) == 8
    assert sc.f_in == next_pow2(SITE.f_in) == 32
    assert (sc.v, sc.n) == (SITE.v, SITE.n)   # group geometry stays raw
    # Same bucket -> same class; different reduce -> different class.
    assert ShapeClass.from_site(SITE._replace(num_blocks=33)) == sc
    assert ShapeClass.from_site(SITE._replace(reduce="max")) != sc
    assert "q8" in ShapeClass.from_site(SITE._replace(quantized=True)).key()


def test_candidates_lead_with_hardcoded_default():
    sc = ShapeClass.from_site(SITE)
    cands = candidate_configs(sc)
    assert cands[0] == baseline_config(sc)
    assert cands[0].fused is True                  # linear stage: fused
    assert cands[1].fused is False                 # primary alternative
    # MAX and quantized pinned the unfused fallback pre-autotune.
    for pin in (SITE._replace(reduce="max"), SITE._replace(quantized=True)):
        pinned = candidate_configs(ShapeClass.from_site(pin))
        assert pinned[0].fused is False
        assert all(c.order == "aggregate_first" for c in pinned)
    # The smoke budget truncates from the back: baseline always survives.
    capped = candidate_configs(sc, max_candidates=1)
    assert capped == [baseline_config(sc)]


def test_wide_tile_candidates_gated_on_feature_width():
    narrow = candidate_configs(ShapeClass.from_site(SITE))
    assert all(c.lane != 256 and c.block_f != 256 for c in narrow)
    wide = candidate_configs(
        ShapeClass.from_site(SITE._replace(f_in=200, f_out=150)))
    assert any(c.lane == 256 for c in wide)
    assert any(c.block_f == 256 for c in wide)


def test_synthesized_problem_matches_class_geometry():
    sc = ShapeClass.from_site(SITE)
    bg, featp, w, bias = synthesize_problem(sc)
    assert bg.blocks.shape == (sc.num_blocks, sc.v, sc.n)
    assert featp.shape == (sc.num_src_groups * sc.n, sc.f_in)
    assert w.shape == (sc.f_in, sc.f_out)
    rows = np.asarray(bg.block_row)
    assert (np.diff(rows) >= 0).all()              # CSR-sorted (kernel req)


# ---------------------------------------------------------------------------
# Cache round-trip and stale invalidation.
# ---------------------------------------------------------------------------


def test_cache_round_trip_skips_research(tmp_path):
    tuner = make_tuner(tmp_path)
    cfg = tuner.ensure(TINY)
    assert tuner.searches == 1 and cfg is not None
    tuner.ensure(TINY)                             # in-process hit
    assert tuner.searches == 1

    warm = make_tuner(tmp_path)                    # fresh process analogue
    assert warm.ensure(TINY) == cfg
    assert warm.searches == 0                      # pure cache lookup
    assert warm.trajectory == []


def test_cache_stale_on_environment_change(tmp_path):
    tuner = make_tuner(tmp_path)
    tuner.ensure(TINY)
    path = str(tmp_path / "autotune.json")
    for field in ("jax_version", "device_kind", "cache_version"):
        raw = json.load(open(path))
        assert raw["entries"]                      # sanity: winner persisted
        stale = dict(raw)
        stale[field] = "elsewhere-0.0"
        json.dump(stale, open(path, "w"))
        cache = AutotuneCache.load(path)
        assert cache.stale_discarded and not cache.entries
        json.dump(raw, open(path, "w"))            # restore for next field
    # A stale cache means the tuner re-searches rather than trusting it.
    json.dump({**json.load(open(path)), "device_kind": "tpu:v9"},
              open(path, "w"))
    research = make_tuner(tmp_path)
    research.ensure(TINY)
    assert research.searches == 1


def test_cache_validate_rejects_malformed(tmp_path):
    cache = AutotuneCache(path=str(tmp_path / "c.json"))
    cache.entries["k"] = KernelConfig()            # no fused decision
    with pytest.raises(ValueError):
        cache.validate()


def test_tuner_without_cache_path_stays_in_process():
    tuner = Autotuner(None, repeats=1, max_candidates=2)
    tuner.ensure(TINY)
    assert tuner.searches == 1
    assert tuner.cache.path is None                # nothing persisted


def test_tune_on_miss_disabled_returns_none(tmp_path):
    tuner = make_tuner(tmp_path, tune_on_miss=False)
    assert tuner.ensure(TINY) is None and tuner.searches == 0


def test_search_winner_beats_or_matches_default(tmp_path):
    tuner = make_tuner(tmp_path, max_candidates=None)
    tuner.ensure(TINY)
    (t,) = tuner.trajectory
    assert t.candidates[0]["config"] == baseline_config(TINY).to_dict()
    assert t.tuned_us <= t.baseline_us             # argmin over same run
    assert t.chosen in [c["config"] for c in t.candidates]


# ---------------------------------------------------------------------------
# Resolved configs steer the lowering.
# ---------------------------------------------------------------------------


def test_forced_unfused_config_is_honored():
    rng = np.random.default_rng(0)
    nv, ne, f_in, f_out = 40, 160, 12, 8
    g = Graph(edge_src=rng.integers(0, nv, ne).astype(np.int32),
              edge_dst=rng.integers(0, nv, ne).astype(np.int32),
              node_feat=rng.standard_normal((nv, f_in)).astype(np.float32)
              ).validate()
    pg = partition_graph(g, v=8, n=8)
    bg = to_blocked(pg)
    featp = np.asarray(pg.pad_features(g.node_feat))
    w = rng.standard_normal((f_in, f_out)).astype(np.float32)
    b = rng.standard_normal((f_out,)).astype(np.float32)
    ref = dense_combine(aggregate_blocked(bg, featp, ReduceOp.SUM), w, b,
                        quantized=True)
    seen = []

    def resolver(site):
        seen.append(site)
        return KernelConfig(fused=False)

    with aggregate_backend("pallas_fused"), kernel_config_scope(resolver):
        got = aggregate_combine_blocked(bg, featp, w, b,
                                        reduce=ReduceOp.SUM, quantized=True)
    # fused=False reproduces the unfused quantized oracle exactly — proof
    # the resolver's decision (not the backend default) chose the lowering.
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-6)
    assert seen and seen[0].quantized and seen[0].backend == "pallas_fused"


# ---------------------------------------------------------------------------
# Serving wiring: trace-build-time resolution.
# ---------------------------------------------------------------------------


def _serve(graphs, **engine_kw):
    from repro.photonic.perf import GhostConfig
    from repro.serving import GnnServeEngine

    f_in = graphs[0].node_feat.shape[1]
    model = build_model("gcn", f_in, 4, hidden=8)
    params = model.init(jax.random.PRNGKey(0))
    eng = GnnServeEngine(cfg=GhostConfig(v=8, n=8), slots=2, **engine_kw)
    eng.register("gcn", model, params)
    report = eng.run(("gcn", g) for g in graphs)
    return eng, report


def _graphs(k=3, f=6):
    rng = np.random.default_rng(1)
    out = []
    for i in range(k):
        nv = 20 + 5 * i
        out.append(Graph(
            edge_src=rng.integers(0, nv, 3 * nv).astype(np.int32),
            edge_dst=rng.integers(0, nv, 3 * nv).astype(np.int32),
            node_feat=rng.standard_normal((nv, f)).astype(np.float32),
        ).validate())
    return out


def test_pool_resolves_tuner_configs_at_trace_build(tmp_path):
    graphs = _graphs()
    tuner = make_tuner(tmp_path)
    eng, report = _serve(graphs, backend="pallas_fused", tuner=tuner)
    # Two GCN layers -> two shape classes, searched once each; the
    # abstract recording pre-pass must not inflate the trace count.
    assert tuner.searches == 2
    assert report.traces_compiled == len(eng.pool)
    assert set(report.kernel_configs) == set(tuner.live_configs())
    assert len(report.kernel_configs) == 2

    # Same catalog against a warm cache: zero searches, same outputs.
    warm = make_tuner(tmp_path)
    eng2, report2 = _serve(graphs, backend="pallas_fused", tuner=warm)
    assert warm.searches == 0
    assert report2.kernel_configs == report.kernel_configs
    for rid in range(len(graphs)):
        np.testing.assert_array_equal(eng.results[rid], eng2.results[rid])

    # Tuned numerics match the jnp-backend engine within kernel tolerance.
    eng3, _ = _serve(graphs, backend="jnp")
    for rid in range(len(graphs)):
        np.testing.assert_allclose(eng.results[rid], eng3.results[rid],
                                   atol=1e-4, rtol=1e-4)


def test_pool_explicit_config_override_beats_tuner(tmp_path):
    graphs = _graphs()
    tuner = make_tuner(tmp_path)
    override = KernelConfig(fused=False)
    eng, report = _serve(graphs, backend="pallas_fused", tuner=tuner,
                         kernel_config=override)
    assert tuner.searches == 0                     # override short-circuits
    assert report.kernel_configs == {"*": override.to_dict()}
    eng2, _ = _serve(graphs, backend="pallas")     # unfused kernel backend
    for rid in range(len(graphs)):
        np.testing.assert_allclose(eng.results[rid], eng2.results[rid],
                                   atol=1e-5, rtol=1e-5)


def test_report_without_tuner_has_no_kernel_configs():
    _, report = _serve(_graphs(1), backend="pallas_fused")
    assert report.kernel_configs == {}
