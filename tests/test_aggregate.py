"""Blocked (GHOST) aggregation == edge-list oracle, all reduce ops."""

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, st

from repro.core import (
    Graph,
    ReduceOp,
    aggregate_blocked,
    aggregate_edges,
    attention_aggregate_blocked,
    partition_graph,
    to_blocked,
)


def make_graph(seed, nv, ne, f):
    rng = np.random.default_rng(seed)
    return Graph(
        edge_src=rng.integers(0, nv, ne).astype(np.int32),
        edge_dst=rng.integers(0, nv, ne).astype(np.int32),
        node_feat=rng.standard_normal((nv, f)).astype(np.float32),
    ).validate()


@pytest.mark.parametrize("reduce", [ReduceOp.SUM, ReduceOp.MEAN, ReduceOp.MAX])
@pytest.mark.parametrize("v,n", [(8, 8), (5, 11), (16, 3)])
def test_blocked_matches_edges(reduce, v, n):
    g = make_graph(0, nv=73, ne=300, f=9)
    pg = partition_graph(g, v=v, n=n)
    bg = to_blocked(pg)
    featp = jnp.asarray(pg.pad_features(g.node_feat))
    ref = aggregate_edges(jnp.asarray(g.edge_src), jnp.asarray(g.edge_dst),
                          jnp.asarray(g.node_feat), g.num_nodes, reduce)
    got = aggregate_blocked(bg, featp, reduce)[:g.num_nodes]
    np.testing.assert_allclose(got, ref, atol=1e-4)


@given(st.integers(0, 500))
def test_blocked_sum_property(seed):
    rng = np.random.default_rng(seed)
    nv = int(rng.integers(5, 60))
    ne = int(rng.integers(1, 150))
    g = make_graph(seed, nv, ne, 5)
    v, n = int(rng.integers(1, 12)), int(rng.integers(1, 12))
    pg = partition_graph(g, v=v, n=n)
    bg = to_blocked(pg)
    featp = jnp.asarray(pg.pad_features(g.node_feat))
    ref = aggregate_edges(jnp.asarray(g.edge_src), jnp.asarray(g.edge_dst),
                          jnp.asarray(g.node_feat), nv, ReduceOp.SUM)
    got = aggregate_blocked(bg, featp, ReduceOp.SUM)[:nv]
    np.testing.assert_allclose(got, ref, atol=1e-4)


def test_weighted_sum_gcn_norm():
    g = make_graph(1, 40, 150, 6).with_self_loops()
    w = g.gcn_edge_weights()
    pg = partition_graph(g, v=8, n=8, edge_weights=w)
    bg = to_blocked(pg)
    featp = jnp.asarray(pg.pad_features(g.node_feat))
    ref = aggregate_edges(jnp.asarray(g.edge_src), jnp.asarray(g.edge_dst),
                          jnp.asarray(g.node_feat), g.num_nodes,
                          ReduceOp.SUM, jnp.asarray(w))
    got = aggregate_blocked(bg, featp, ReduceOp.SUM)[:g.num_nodes]
    np.testing.assert_allclose(got, ref, atol=1e-4)


def test_attention_aggregate_matches_segment_softmax():
    """Blocked GAT softmax-aggregation == explicit edge-list computation."""
    g = make_graph(2, 30, 120, 4)
    heads, f = 3, 4
    rng = np.random.default_rng(0)
    vals = rng.standard_normal((g.num_nodes, heads, f)).astype(np.float32)
    s_src = rng.standard_normal((g.num_nodes, heads)).astype(np.float32)
    s_dst = rng.standard_normal((g.num_nodes, heads)).astype(np.float32)

    # edge-list reference
    import jax
    logits = jax.nn.leaky_relu(
        jnp.asarray(s_dst)[g.edge_dst] + jnp.asarray(s_src)[g.edge_src], 0.2)
    m = jax.ops.segment_max(logits, jnp.asarray(g.edge_dst), num_segments=g.num_nodes)
    m = jnp.where(jnp.isfinite(m), m, 0.0)
    z = jnp.exp(logits - m[g.edge_dst])
    denom = jax.ops.segment_sum(z, jnp.asarray(g.edge_dst), num_segments=g.num_nodes)
    alpha = z / jnp.maximum(denom[g.edge_dst], 1e-30)
    ref = jax.ops.segment_sum(alpha[..., None] * jnp.asarray(vals)[g.edge_src],
                              jnp.asarray(g.edge_dst), num_segments=g.num_nodes)

    pg = partition_graph(g, v=7, n=9)
    bg = to_blocked(pg)
    pad_src = pg.padded_src
    pad_dst = pg.padded_dst
    vals_p = jnp.asarray(np.pad(vals, ((0, pad_src - g.num_nodes), (0, 0), (0, 0))))
    ssrc_p = jnp.asarray(np.pad(s_src, ((0, pad_src - g.num_nodes), (0, 0))))
    sdst_p = jnp.asarray(np.pad(s_dst, ((0, pad_dst - g.num_nodes), (0, 0))))
    got = attention_aggregate_blocked(bg, vals_p, ssrc_p, sdst_p)[:g.num_nodes]
    np.testing.assert_allclose(got, ref, atol=1e-4)
