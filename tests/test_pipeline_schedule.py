"""Invariants of the two-level pipelining model (core/pipeline.py).

The analytic flow-shop schedule must satisfy the classic bounds regardless
of the load matrix: pipelining can only help, nothing can beat the
busiest stage, and turning both levels off is exactly the sequential sum.
"""

import numpy as np
import pytest

from _hypothesis_compat import given, st
from repro.core.pipeline import (
    StageLoad,
    grouped_latency,
    pipelined_latency,
    sequential_latency,
)

STAGE_NAMES = ("reduce", "transform", "update")


def random_loads(seed, max_groups=6, max_stages=3):
    rng = np.random.default_rng(seed)
    groups = int(rng.integers(1, max_groups + 1))
    stages = int(rng.integers(1, max_stages + 1))
    out = []
    for _ in range(groups):
        out.append([
            # tiles >= 1, matching the domain perf.py produces (every stage
            # of a scheduled group has at least one mapping); zero-tile
            # stages legitimately pay pipeline fill time under PP.
            StageLoad(STAGE_NAMES[s % len(STAGE_NAMES)],
                      int(rng.integers(1, 12)),
                      float(rng.random()) * 2.0)
            for s in range(stages)
        ])
    return out


@given(st.integers(0, 500))
def test_pipelining_never_hurts(seed):
    loads = random_loads(seed)
    full = grouped_latency(loads, pipeline_within=True, pipeline_across=True)
    within_only = grouped_latency(loads, pipeline_within=True,
                                  pipeline_across=False)
    across_only = grouped_latency(loads, pipeline_within=False,
                                  pipeline_across=True)
    none = grouped_latency(loads, pipeline_within=False,
                           pipeline_across=False)
    eps = 1e-9
    assert full <= within_only + eps
    assert full <= across_only + eps
    assert within_only <= none + eps
    assert across_only <= none + eps


@given(st.integers(0, 500))
def test_latency_lower_bounded_by_busiest_stage(seed):
    """No schedule can finish before its busiest stage unit finishes all its
    work — each stage is a single dedicated hardware unit."""
    loads = random_loads(seed)
    num_stages = max(len(g) for g in loads)
    stage_work = [
        sum(g[s].total for g in loads if s < len(g))
        for s in range(num_stages)
    ]
    bound = max(stage_work)
    for within in (False, True):
        for across in (False, True):
            lat = grouped_latency(loads, pipeline_within=within,
                                  pipeline_across=across)
            assert lat >= bound - 1e-9


@given(st.integers(0, 500))
def test_no_pp_equals_sequential_sum_over_groups(seed):
    """Both pipelining levels off == the paper's no-PP baseline: every group
    drains fully, stage by stage."""
    loads = random_loads(seed)
    none = grouped_latency(loads, pipeline_within=False,
                           pipeline_across=False)
    expected = sum(sequential_latency(g) for g in loads)
    assert none == pytest.approx(expected, rel=1e-12)


def test_single_group_pipelined_matches_grouped():
    stages = [StageLoad("reduce", 4, 1.0), StageLoad("transform", 2, 0.5),
              StageLoad("update", 1, 0.25)]
    assert pipelined_latency(stages) == pytest.approx(
        grouped_latency([stages], pipeline_within=True,
                        pipeline_across=False))


def test_pipelined_single_group_bounds():
    """Within-group pipelining sits between the busiest stage and the sum."""
    stages = [StageLoad("reduce", 5, 0.7), StageLoad("transform", 3, 1.1),
              StageLoad("update", 2, 0.3)]
    lat = pipelined_latency(stages)
    assert lat <= sequential_latency(stages)
    assert lat >= max(s.total for s in stages)


def test_empty_and_zero_loads():
    assert grouped_latency([]) == 0.0
    zero = [[StageLoad("reduce", 0, 1.0), StageLoad("transform", 0, 1.0)]]
    assert grouped_latency(zero, pipeline_within=False,
                           pipeline_across=False) == 0.0
