"""Fused aggregate+combine kernel + combination-order planner tests.

Covers the fused-kernel contract: the fused Pallas kernel (interpret mode
on CPU) against the unfused jnp oracle across reduce ops and padding
shapes, combine-first vs aggregate-first numerical equivalence, in-kernel
MAX reduce, the int8 sign-split combine epilogue (within its documented
per-row-block-scale tolerance; exact when forced unfused), zero-edge
graphs, degree hoisting, thread-local backend selection, and the four GNN
layer types end-to-end.
"""

import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    Graph,
    ReduceOp,
    active_aggregate_backend,
    aggregate_backend,
    aggregate_blocked,
    aggregate_combine_blocked,
    blocked_degrees,
    clear_planner_log,
    dense_combine,
    partition_graph,
    plan_combine_order,
    planner_decisions,
    to_blocked,
    with_degrees,
)
from repro.gnn import build_model
from repro.kernels import fused_block_spmm_padded


def _setup(seed, nv, ne, f_in, f_out, v=8, n=8, gcn_norm=False):
    rng = np.random.default_rng(seed)
    g = Graph(
        edge_src=rng.integers(0, nv, ne).astype(np.int32),
        edge_dst=rng.integers(0, nv, ne).astype(np.int32),
        node_feat=rng.standard_normal((nv, f_in)).astype(np.float32),
    ).validate()
    if gcn_norm:
        g = g.with_self_loops()
        pg = partition_graph(g, v=v, n=n, edge_weights=g.gcn_edge_weights())
    else:
        pg = partition_graph(g, v=v, n=n)
    bg = to_blocked(pg)
    featp = jnp.asarray(pg.pad_features(g.node_feat))
    w = jnp.asarray(rng.standard_normal((f_in, f_out)).astype(np.float32))
    b = jnp.asarray(rng.standard_normal((f_out,)).astype(np.float32))
    return g, pg, bg, featp, w, b


def _oracle(bg, featp, w, b, reduce):
    """The unfused jnp reference: aggregate, then densely combine."""
    return dense_combine(aggregate_blocked(bg, featp, reduce), w, b)


# ---------------------------------------------------------------------------
# Fused kernel vs the jnp oracle.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("reduce", [ReduceOp.SUM, ReduceOp.MEAN])
@pytest.mark.parametrize("nv,ne,f_in,f_out", [
    (64, 280, 32, 16),
    (50, 200, 20, 48),    # f_out > f_in (aggregate-first territory)
    (37, 90, 13, 7),      # odd widths: both dims exercise lane padding
    (100, 500, 129, 5),   # f_in just past one lane tile
])
def test_fused_matches_oracle(reduce, nv, ne, f_in, f_out):
    _, _, bg, featp, w, b = _setup(0, nv, ne, f_in, f_out)
    ref = _oracle(bg, featp, w, b, reduce)
    with aggregate_backend("pallas_fused"):
        got = aggregate_combine_blocked(bg, featp, w, b, reduce=reduce,
                                        order="aggregate_first")
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)


def test_fused_no_bias_and_relu_epilogue():
    _, _, bg, featp, w, _ = _setup(1, 48, 220, 16, 12)
    ref = jax.nn.relu(dense_combine(aggregate_blocked(
        bg, featp, ReduceOp.SUM), w))
    with aggregate_backend("pallas_fused"):
        got = aggregate_combine_blocked(bg, featp, w, reduce=ReduceOp.SUM,
                                        activation="relu",
                                        order="aggregate_first")
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-5)


def test_fused_direct_wrapper_unvisited_rows_get_bias():
    """Destination groups with no tiles must come out as act(bias), exactly
    like the oracle's all-zero aggregation rows."""
    nv = 40
    src = np.arange(10, dtype=np.int32)
    dst = np.full(10, 39, np.int32)   # everything lands in the last group
    g = Graph(edge_src=src, edge_dst=dst,
              node_feat=np.random.default_rng(2)
              .standard_normal((nv, 6)).astype(np.float32)).validate()
    pg = partition_graph(g, v=8, n=8)
    bg = to_blocked(pg)
    featp = jnp.asarray(pg.pad_features(g.node_feat))
    rng = np.random.default_rng(3)
    w = jnp.asarray(rng.standard_normal((6, 4)).astype(np.float32))
    b = jnp.asarray(rng.standard_normal((4,)).astype(np.float32))
    got = fused_block_spmm_padded(bg.blocks, bg.block_row, bg.block_col,
                                  featp, w, b, None, bg.num_dst_groups,
                                  interpret=True)
    ref = _oracle(bg, featp, w, b, ReduceOp.SUM)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-5)
    # The first four groups hold no edges: bias rows exactly.
    np.testing.assert_array_equal(np.asarray(got[:32]),
                                  np.broadcast_to(np.asarray(b), (32, 4)))


@pytest.mark.parametrize("reduce", [ReduceOp.SUM, ReduceOp.MEAN])
def test_fused_zero_edge_graph(reduce):
    g = Graph(edge_src=np.zeros(0, np.int32), edge_dst=np.zeros(0, np.int32),
              node_feat=np.random.default_rng(4)
              .standard_normal((11, 5)).astype(np.float32)).validate()
    pg = partition_graph(g, v=4, n=4)
    assert pg.stats.nonzero_tiles == 0
    bg = to_blocked(pg)
    featp = jnp.asarray(pg.pad_features(g.node_feat))
    rng = np.random.default_rng(5)
    w = jnp.asarray(rng.standard_normal((5, 3)).astype(np.float32))
    b = jnp.asarray(rng.standard_normal((3,)).astype(np.float32))
    ref = _oracle(bg, featp, w, b, reduce)     # == bias everywhere
    with aggregate_backend("pallas_fused"):
        got = aggregate_combine_blocked(bg, featp, w, b, reduce=reduce,
                                        order="aggregate_first")
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-6)
    np.testing.assert_allclose(np.asarray(got),
                               np.broadcast_to(np.asarray(b), ref.shape),
                               atol=1e-6)


def test_max_reduce_runs_fused_and_matches_oracle():
    """MAX now lowers inside the fused kernel (-inf-seeded accumulator,
    maximum merge, masked against structural zeros): the comparator path
    is exact arithmetic, so fused MAX must equal the oracle exactly."""
    _, _, bg, featp, w, b = _setup(6, 45, 180, 10, 6)
    ref = _oracle(bg, featp, w, b, ReduceOp.MAX)
    with aggregate_backend("pallas_fused"):
        got = aggregate_combine_blocked(bg, featp, w, b, reduce=ReduceOp.MAX)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-6)


def _int8_epilogue_bound(bg, featp, w):
    """The fused kernel's documented int8 activation-rounding bound.

    Fused and unfused quantize the *weights* identically, so the only
    divergence is the activation scale: per destination row-block (the
    in-kernel reality) vs per-tensor (the oracle).  Each path's rounding
    error on activations is at most scale/2 per element, so
    ``|fused - oracle|[i, j] <= 0.5 * (s_blk(i) + s_tensor) * sum_k
    |W_deq[k, j]|`` — see fused_block_spmm's docstring.
    """
    from repro.photonic.quant import QuantConfig, quantize_weights

    h = np.asarray(aggregate_blocked(bg, featp, ReduceOp.SUM))
    s_tensor = max(np.abs(h).max(), 1e-12) / 127.0
    groups = h.reshape(bg.num_dst_groups, bg.v, h.shape[1])
    s_blk = np.maximum(np.abs(groups).max(axis=(1, 2)), 1e-12) / 127.0
    wq, sw = quantize_weights(w, QuantConfig())
    w_deq_colsum = np.abs(np.asarray(wq, np.float32)
                          * np.asarray(sw)).sum(axis=0)   # [F_out]
    s_rows = np.repeat(s_blk, bg.v) + s_tensor             # [G_dst * V]
    return 0.5 * s_rows[:, None] * w_deq_colsum[None, :]


def test_quantized_fused_epilogue_within_documented_tolerance():
    """quantized=True no longer forces the unfused fallback: the fused int8
    sign-split epilogue must agree with the per-tensor-scale oracle within
    the analytic per-row-block-scale bound (and the plan must be pinned to
    aggregate-first — int8 quantization is nonlinear)."""
    _, _, bg, featp, w, b = _setup(7, 45, 180, 12, 8)
    ref = dense_combine(aggregate_blocked(bg, featp, ReduceOp.SUM), w, b,
                        quantized=True)
    clear_planner_log()
    with aggregate_backend("pallas_fused"):
        got = aggregate_combine_blocked(bg, featp, w, b,
                                        reduce=ReduceOp.SUM, quantized=True)
    bound = _int8_epilogue_bound(bg, featp, w)
    diff = np.abs(np.asarray(got) - np.asarray(ref))
    assert np.all(diff <= bound + 1e-5), float((diff - bound).max())
    (decision,) = planner_decisions()
    assert decision["quantized"] is True
    assert decision["order"] == "aggregate_first"


def test_quantized_forced_unfused_matches_oracle_exactly():
    """The explicit kernel-config override (fused=False) restores the
    pre-fusion quantized lowering bit-for-bit — the deterministic escape
    hatch tests and serving can pin."""
    from repro.core import kernel_config_scope
    from repro.kernels import KernelConfig

    _, _, bg, featp, w, b = _setup(7, 45, 180, 12, 8)
    ref = dense_combine(aggregate_blocked(bg, featp, ReduceOp.SUM), w, b,
                        quantized=True)
    with aggregate_backend("pallas_fused"), \
            kernel_config_scope(lambda site: KernelConfig(fused=False)):
        got = aggregate_combine_blocked(bg, featp, w, b,
                                        reduce=ReduceOp.SUM, quantized=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-6)


# ---------------------------------------------------------------------------
# Combination-order planning.
# ---------------------------------------------------------------------------


def test_combine_first_equals_aggregate_first():
    for reduce in (ReduceOp.SUM, ReduceOp.MEAN):
        _, _, bg, featp, w, b = _setup(8, 60, 300, 24, 10)
        ref = aggregate_combine_blocked(bg, featp, w, b, reduce=reduce,
                                        order="aggregate_first")
        got = aggregate_combine_blocked(bg, featp, w, b, reduce=reduce,
                                        order="combine_first")
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   atol=1e-5, rtol=1e-5)
        with aggregate_backend("pallas_fused"):
            got_fused = aggregate_combine_blocked(bg, featp, w, b,
                                                  reduce=reduce,
                                                  order="combine_first")
        np.testing.assert_allclose(np.asarray(got_fused), np.asarray(ref),
                                   atol=1e-5, rtol=1e-5)


def test_planner_prefers_narrow_spmm_width():
    _, _, bg, featp, _, _ = _setup(9, 60, 300, 32, 8)
    shrink = plan_combine_order(bg, f_in=32, f_out=8)
    grow = plan_combine_order(bg, f_in=8, f_out=32)
    assert shrink.order == "combine_first"
    assert grow.order == "aggregate_first"
    # Override wins regardless of cost.
    forced = plan_combine_order(bg, f_in=32, f_out=8, order="aggregate_first")
    assert forced.order == "aggregate_first"
    with pytest.raises(ValueError):
        plan_combine_order(bg, 8, 8, order="bogus")
    # The FLOP model is symmetric in the SpMM widths it trades.
    assert shrink.flops_aggregate_first > shrink.flops_combine_first
    assert grow.flops_aggregate_first < grow.flops_combine_first


def test_planner_decisions_are_recorded_and_deduped():
    clear_planner_log()
    _, _, bg, featp, w, b = _setup(10, 40, 160, 16, 4)
    for _ in range(3):  # repeats must not grow the log
        aggregate_combine_blocked(bg, featp, w, b)
    decisions = planner_decisions()
    assert len(decisions) == 1
    d = decisions[0]
    assert d["order"] == "combine_first"       # 16 -> 4 shrinks the width
    assert d["f_in"] == 16 and d["f_out"] == 4
    assert d["fused_hbm_bytes_saved"] == bg.num_dst_groups * bg.v * 16 * 4 * 2
    clear_planner_log()
    assert planner_decisions() == []


# ---------------------------------------------------------------------------
# Degree hoisting.
# ---------------------------------------------------------------------------


def test_to_blocked_precomputes_degrees():
    g, pg, bg, featp, _, _ = _setup(11, 50, 260, 8, 8)
    assert bg.deg is not None
    # Hoisted degrees == the edge-list in-degree count (multiplicity-aware).
    deg_ref = np.zeros(pg.padded_dst, np.float32)
    np.add.at(deg_ref, g.edge_dst, 1.0)
    np.testing.assert_allclose(np.asarray(bg.deg), deg_ref, atol=1e-6)
    # MEAN through the precomputed path == MEAN with degrees re-derived.
    bare = bg._replace(deg=None)
    np.testing.assert_allclose(
        np.asarray(aggregate_blocked(bg, featp, ReduceOp.MEAN)),
        np.asarray(aggregate_blocked(bare, featp, ReduceOp.MEAN)),
        atol=1e-6)
    np.testing.assert_allclose(np.asarray(blocked_degrees(bare)),
                               deg_ref, atol=1e-6)
    assert with_degrees(bare).deg is not None
    assert with_degrees(bg) is bg  # no-op when already attached


# ---------------------------------------------------------------------------
# Thread-local backend selection.
# ---------------------------------------------------------------------------


def test_backend_selection_is_thread_local():
    seen = {}
    barrier = threading.Barrier(2)

    def worker(name, backend):
        with aggregate_backend(backend):
            barrier.wait(timeout=10)       # both threads inside their ctx
            seen[name] = active_aggregate_backend()
            barrier.wait(timeout=10)

    t1 = threading.Thread(target=worker, args=("a", "pallas"))
    t2 = threading.Thread(target=worker, args=("b", "pallas_fused"))
    t1.start(); t2.start(); t1.join(); t2.join()
    assert seen == {"a": "pallas", "b": "pallas_fused"}
    assert active_aggregate_backend() == "jnp"  # main thread untouched


def test_backend_in_spawned_thread_defaults_to_jnp():
    result = {}
    with aggregate_backend("pallas_fused"):
        t = threading.Thread(
            target=lambda: result.update(b=active_aggregate_backend()))
        t.start(); t.join()
    assert result["b"] == "jnp"


# ---------------------------------------------------------------------------
# Layer-level equivalence: the four model types under the fused backend.
# ---------------------------------------------------------------------------


def test_serving_engine_pallas_fused_backend_bit_exact():
    """An engine on backend='pallas_fused' serves values bit-identical to
    the jitted unbatched apply_blocked under the same backend (batching and
    bucket padding add no drift, same as the other backends)."""
    from repro.photonic.perf import GhostConfig
    from repro.serving import GnnServeEngine

    rng = np.random.default_rng(13)
    f = 6
    model = build_model("sage", f, 3, hidden=8)   # MEAN: exercises inv_deg
    params = model.init(jax.random.PRNGKey(0))
    eng = GnnServeEngine(cfg=GhostConfig(n=8, v=8), slots=2,
                         backend="pallas_fused")
    eng.register("sage", model, params, task="node", f_in=f)
    graphs = []
    for seed in range(3):
        nv = 10 + 7 * seed
        ne = 4 * nv
        graphs.append(Graph(
            edge_src=rng.integers(0, nv, ne).astype(np.int32),
            edge_dst=rng.integers(0, nv, ne).astype(np.int32),
            node_feat=rng.standard_normal((nv, f)).astype(np.float32),
        ).validate())
        eng.submit("sage", graphs[-1])
    eng.drain()
    for i, g in enumerate(graphs):
        pg = partition_graph(g, v=8, n=8)
        featp = jnp.asarray(pg.pad_features(g.node_feat))
        bgs = to_blocked(pg)  # closed over: its geometry stays static
        with aggregate_backend("pallas_fused"):
            ref = np.asarray(jax.jit(
                lambda p, f: model.apply_blocked(p, bgs, f)
            )(params, featp))[: g.num_nodes]
        np.testing.assert_array_equal(eng.results[i], ref)


@pytest.mark.parametrize("name,kw", [
    ("gcn", dict(hidden=16)),
    ("sage", dict(hidden=16)),
    ("gin", dict(hidden=8)),
    ("gat", dict(hidden=4, heads=2)),
])
def test_layer_types_fused_vs_jnp_oracle(name, kw):
    f_in, nv, ne = 12, 50, 240
    rng = np.random.default_rng(12)
    g = Graph(edge_src=rng.integers(0, nv, ne).astype(np.int32),
              edge_dst=rng.integers(0, nv, ne).astype(np.int32),
              node_feat=rng.standard_normal((nv, f_in)).astype(np.float32)
              ).validate()
    if name == "gcn":
        g = g.with_self_loops()
        pg = partition_graph(g, v=8, n=8, edge_weights=g.gcn_edge_weights())
    else:
        pg = partition_graph(g, v=8, n=8)
    bg = to_blocked(pg)
    featp = jnp.asarray(pg.pad_features(g.node_feat))
    model = build_model(name, f_in, 3, **kw)
    params = model.init(jax.random.PRNGKey(0))
    ref = model.apply_blocked(params, bg, featp)
    with aggregate_backend("pallas_fused"):
        got = model.apply_blocked(params, bg, featp)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)
