"""Photonic 8-bit sign-split quantization properties (Section 3.2 / C4)."""

import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, hnp, st

from repro.photonic.quant import (
    QuantConfig,
    compute_scale,
    dequantize,
    fake_quant,
    fake_quant_ste,
    quantize,
    quantized_matmul,
    sign_merge,
    sign_split,
)

floats = hnp.arrays(np.float32, st.integers(2, 64).map(lambda n: (n,)),
                    elements=st.floats(-100, 100, width=32))


@given(floats)
def test_roundtrip_error_bounded_by_half_scale(x):
    x = jnp.asarray(x)
    s = compute_scale(x)
    q = quantize(x, s)
    err = jnp.abs(dequantize(q, s) - jnp.clip(x, -127 * s, 127 * s))
    assert float(err.max()) <= float(s) * 0.5 + 1e-7


@given(floats)
def test_sign_split_polarities_are_7bit(x):
    """Each polarity uses N_levels = 2^7 levels (paper Eq. 12 input)."""
    x = jnp.asarray(x)
    q = quantize(x, compute_scale(x))
    pos, neg = sign_split(q)
    assert int(jnp.max(pos)) <= 127 and int(jnp.min(pos)) >= 0
    assert int(jnp.max(neg)) <= 127 and int(jnp.min(neg)) >= 0
    np.testing.assert_array_equal(np.asarray(sign_merge(pos, neg)), np.asarray(q))
    # BPD subtraction linearity: (p_x - n_x) recovers q exactly
    assert QuantConfig().n_levels == 128


def test_quantized_matmul_close_to_fp32():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((32, 64)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal((64, 16)).astype(np.float32))
    y = quantized_matmul(x, w)
    ref = x @ w
    rel = float(jnp.abs(y - ref).max() / jnp.abs(ref).max())
    assert rel < 0.03  # 8-bit accumulation error bound (Table 3 territory)


def test_quantized_matmul_equals_sign_split_form():
    """(p_x - n_x)(p_w - n_w) == q_x q_w: the BPD identity."""
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((8, 12)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal((12, 5)).astype(np.float32))
    cfg = QuantConfig()
    sx = compute_scale(x)
    qx = quantize(x, sx)
    from repro.photonic.quant import quantize_weights
    qw, sw = quantize_weights(w, cfg)
    px, nx = sign_split(qx)
    pw, nw = sign_split(qw)
    acc_split = (
        px.astype(jnp.int32) @ pw.astype(jnp.int32)
        - px.astype(jnp.int32) @ nw.astype(jnp.int32)
        - nx.astype(jnp.int32) @ pw.astype(jnp.int32)
        + nx.astype(jnp.int32) @ nw.astype(jnp.int32)
    )
    acc_direct = qx.astype(jnp.int32) @ qw.astype(jnp.int32)
    np.testing.assert_array_equal(np.asarray(acc_split), np.asarray(acc_direct))


def test_fake_quant_idempotent():
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((20,)).astype(np.float32))
    y = fake_quant(x)
    z = fake_quant(y)
    np.testing.assert_allclose(np.asarray(y), np.asarray(z), atol=1e-6)


def test_ste_gradient_passes_through():
    g = jax.grad(lambda x: fake_quant_ste(x).sum())(jnp.ones((5,)))
    np.testing.assert_allclose(np.asarray(g), np.ones(5))
