"""Layer-level unit tests: rope, attention variants, MLA, SSM, RWKV, MoE."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MLAConfig, ModelConfig, MoEConfig, RWKVConfig, SSMConfig
from repro.models.layers.attention import attn_apply, attn_init, chunked_attention
from repro.models.layers.mla import init_mla_cache, mla_decode, mla_init, mla_prefill
from repro.models.layers.moe import moe_apply, moe_init
from repro.models.layers.rope import apply_rope, rope_angles
from repro.models.layers.rwkv import (
    init_rwkv_cache,
    rwkv_time_mix,
    rwkv_time_mix_init,
)
from repro.models.layers.ssm import SSMConfig as _S, init_ssm_cache, ssm_apply, ssm_init


def naive_attention(q, k, v, causal=True, window=0):
    b, sq, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    kk = jnp.repeat(k, g, axis=2)
    vv = jnp.repeat(v, g, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kk) / hd ** 0.5
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones((sq, k.shape[1]), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, vv)


@pytest.mark.parametrize("sq,h,kvh,chunk,qchunk", [
    (16, 4, 4, 8, 8), (32, 8, 2, 8, 16), (24, 6, 3, 32, 512), (16, 4, 1, 4, 4),
])
def test_chunked_attention_matches_naive(sq, h, kvh, chunk, qchunk):
    rng = np.random.default_rng(0)
    hd = 16
    q = jnp.asarray(rng.standard_normal((2, sq, h, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, sq, kvh, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, sq, kvh, hd)), jnp.float32)
    got = chunked_attention(q, k, v, causal=True, chunk_size=chunk,
                            q_chunk_size=qchunk)
    ref = naive_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=2e-5)


def test_sliding_window_matches_naive():
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.standard_normal((1, 24, 4, 8)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 24, 2, 8)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 24, 2, 8)), jnp.float32)
    got = chunked_attention(q, k, v, causal=True, window=5, chunk_size=8,
                            q_chunk_size=8)
    ref = naive_attention(q, k, v, causal=True, window=5)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=2e-5)


def test_gqa_equals_mha_when_kv_heads_match():
    """GQA with kv=H and repeated kv == plain MHA."""
    rng = np.random.default_rng(2)
    q = jnp.asarray(rng.standard_normal((1, 8, 4, 8)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 8, 4, 8)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 8, 4, 8)), jnp.float32)
    a = chunked_attention(q, k, v, chunk_size=4)
    k2 = k[:, :, :2].repeat(2, 2)  # fake 2-kv-head tensors expanded back
    # instead: verify bitwise equal path with kvh=h vs manual naive
    ref = naive_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(a), np.asarray(ref), atol=2e-5)


def test_rope_preserves_norm_and_relative_phase():
    pos = jnp.arange(12)
    x = jnp.asarray(np.random.default_rng(3).standard_normal((1, 12, 2, 16)),
                    jnp.float32)
    y = apply_rope(x, pos, theta=10000.0, fraction=1.0)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1), rtol=1e-5)
    # dot(q_i, k_j) depends only on i - j:
    q = apply_rope(jnp.ones((1, 12, 1, 16)), pos, 10000.0)
    k = apply_rope(jnp.ones((1, 12, 1, 16)), pos, 10000.0)
    d1 = jnp.einsum("d,d->", q[0, 5, 0], k[0, 3, 0])
    d2 = jnp.einsum("d,d->", q[0, 9, 0], k[0, 7, 0])
    assert abs(float(d1 - d2)) < 1e-4


def test_partial_rope_leaves_tail_untouched():
    pos = jnp.arange(6)
    x = jnp.asarray(np.random.default_rng(4).standard_normal((1, 6, 1, 16)),
                    jnp.float32)
    y = apply_rope(x, pos, theta=10000.0, fraction=0.5)
    np.testing.assert_allclose(np.asarray(y[..., 8:]), np.asarray(x[..., 8:]))
    assert not np.allclose(np.asarray(y[..., :8]), np.asarray(x[..., :8]))


def test_mla_prefill_decode_consistency():
    cfg = MLAConfig(q_lora_rank=32, kv_lora_rank=16, qk_nope_head_dim=8,
                    qk_rope_head_dim=4, v_head_dim=8)
    d, h, s, b = 32, 2, 10, 2
    p = mla_init(jax.random.PRNGKey(0), d, h, cfg)
    x = jnp.asarray(np.random.default_rng(5).standard_normal((b, s, d)),
                    jnp.float32)
    full, _ = mla_prefill(p, x, h, cfg, jnp.arange(s), 10000.0)
    cache = init_mla_cache(b, s, cfg, jnp.float32)
    _, cache = mla_prefill(p, x[:, :5], h, cfg, jnp.arange(5), 10000.0,
                           cache=cache)
    outs = []
    for t in range(5, s):
        o, cache = mla_decode(p, x[:, t:t + 1], h, cfg,
                              jnp.asarray([t]), 10000.0, cache)
        outs.append(o)
    got = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(full[:, 5:]),
                               atol=2e-4)


def test_ssm_chunked_equals_stepwise():
    cfg = _S(state_dim=4, expand=2, conv_width=3)
    d, b, s = 8, 2, 13
    p = ssm_init(jax.random.PRNGKey(1), d, cfg)
    x = jnp.asarray(np.random.default_rng(6).standard_normal((b, s, d)) * 0.3,
                    jnp.float32)
    full, _ = ssm_apply(p, x, cfg, cache=None, chunk_size=4)
    cache = init_ssm_cache(b, d, cfg)
    outs = []
    for t in range(s):
        o, cache = ssm_apply(p, x[:, t:t + 1], cfg, cache=cache, chunk_size=4)
        outs.append(o)
    got = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(full), atol=1e-4)


def test_rwkv_wkv_recurrence_manual():
    """One head, tiny dims: scan output == hand-rolled recurrence."""
    cfg = RWKVConfig(head_dim=4, decay_lora=4, gate_lora=2)
    d, b, s = 4, 1, 6
    p = rwkv_time_mix_init(jax.random.PRNGKey(2), d, cfg)
    x = jnp.asarray(np.random.default_rng(7).standard_normal((b, s, d)) * 0.5,
                    jnp.float32)
    y, _ = rwkv_time_mix(p, x, cfg)
    assert y.shape == (b, s, d)
    assert bool(jnp.all(jnp.isfinite(y)))
    # streaming == full
    cache = init_rwkv_cache(b, d, cfg)
    outs = []
    for t in range(s):
        class C:  # minimal cache adapter
            pass
        o, (st, last) = rwkv_time_mix(p, x[:, t:t + 1], cfg, cache)
        cache = cache._replace(wkv_state=st, tm_last=last,
                               length=cache.length + 1)
        outs.append(o)
    got = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(y), atol=1e-4)


def test_moe_routing_invariants():
    cfg = MoEConfig(num_experts=4, top_k=2, d_ff_expert=16,
                    capacity_factor=8.0)
    d, b, s = 8, 2, 6
    p = moe_init(jax.random.PRNGKey(3), d, cfg)
    x = jnp.asarray(np.random.default_rng(8).standard_normal((b, s, d)),
                    jnp.float32)
    out, aux = moe_apply(p, x, cfg)
    assert out.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(out)))


def test_moe_identical_experts_equal_dense_ffn():
    """With all experts identical and ample capacity, MoE == one dense FFN
    (routing weights sum to 1)."""
    cfg = MoEConfig(num_experts=4, top_k=2, d_ff_expert=16, capacity_factor=16.0)
    d, b, s = 8, 2, 5
    p = moe_init(jax.random.PRNGKey(4), d, cfg)
    p = dict(p)
    for k in ("w_gate", "w_up", "w_down"):
        p[k] = jnp.broadcast_to(p[k][0:1], p[k].shape)
    x = jnp.asarray(np.random.default_rng(9).standard_normal((b, s, d)),
                    jnp.float32)
    out, _ = moe_apply(p, x, cfg)
    xt = x.reshape(-1, d)
    h = jax.nn.silu(xt @ p["w_gate"][0]) * (xt @ p["w_up"][0])
    ref = (h @ p["w_down"][0]).reshape(b, s, d)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


def test_moe_capacity_drops_tokens():
    cfg = MoEConfig(num_experts=2, top_k=1, d_ff_expert=8, capacity_factor=0.1)
    d = 4
    p = moe_init(jax.random.PRNGKey(5), d, cfg)
    x = jnp.asarray(np.random.default_rng(10).standard_normal((1, 32, d)),
                    jnp.float32)
    out, _ = moe_apply(p, x, cfg)
    # capacity 0.1 -> most tokens dropped -> many exactly-zero outputs
    zero_rows = np.sum(np.abs(np.asarray(out)).sum(-1) < 1e-9)
    assert zero_rows > 16
