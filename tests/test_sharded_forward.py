"""Multi-device sharded fused forward (core.aggregate sharded execution).

The load-bearing claims:
  * ``shard_blocked`` partitions the CSR-sorted tile list owner-exclusively
    (contiguous destination slices, per-shard CSR sortedness, inert padding
    tiles);
  * the destination-block strategy is BIT-EXACT vs the single-device
    blocked forward on every backend and reduce mode — including the fused
    quantized epilogue, whose int8 activation scales are per-row-block and
    therefore shard cleanly;
  * the feature-dim strategy matches to documented few-ULP tolerance
    (psum association order) and routes transparently through
    ``shard_scope`` — including inside vmapped serving executors — while
    quantized sites are left single-device (per-tensor int8 scale is a
    global reduction);
  * strategy planning (``plan_shard_strategy``) and the engine-level mesh
    topology surface behave as documented.

Device-mesh tests need >= 8 visible devices — on CPU hosts run under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the CI shard-smoke
job does).  Host-side prep tests run everywhere.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    Graph,
    ReduceOp,
    ShardedBlockedGraph,
    aggregate_backend,
    aggregate_combine_blocked,
    aggregate_combine_sharded,
    kernel_config_scope,
    partition_graph,
    plan_shard_strategy,
    shard_blocked,
    shard_scope,
    to_blocked,
)
from repro.core.aggregate import active_shard_context
from repro.kernels.autotune import KernelConfig
from repro.launch.mesh import make_data_mesh

needs_devices = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs 8 devices "
           "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")


def make_graph(seed, nv=70, ne=320, f=12):
    rng = np.random.default_rng(seed)
    return Graph(
        edge_src=rng.integers(0, nv, ne).astype(np.int32),
        edge_dst=rng.integers(0, nv, ne).astype(np.int32),
        node_feat=rng.standard_normal((nv, f)).astype(np.float32),
    ).validate()


def blocked_fixture(seed=0, nv=70, ne=320, f=12, v=8, n=8):
    g = make_graph(seed, nv, ne, f)
    pg = partition_graph(g, v=v, n=n)
    bg = to_blocked(pg)
    feat = jnp.asarray(pg.pad_features(g.node_feat))
    return bg, feat


def make_weights(f_in, f_out, seed=1):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.standard_normal((f_in, f_out)).astype(np.float32))
    b = jnp.asarray(rng.standard_normal((f_out,)).astype(np.float32))
    return w, b


# ---------------------------------------------------------------------------
# Host-side prep (shard_blocked): no devices needed.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("num_shards", [1, 2, 3, 4])
def test_shard_blocked_geometry(num_shards):
    bg, _ = blocked_fixture()
    sbg = shard_blocked(bg, num_shards)
    assert isinstance(sbg, ShardedBlockedGraph)
    assert sbg.num_shards == num_shards
    assert sbg.blocks.shape[0] == num_shards
    local = sbg.local_dst_groups
    assert local * num_shards >= bg.num_dst_groups
    assert sbg.num_blocks == int(bg.blocks.shape[0])

    row_g = np.asarray(bg.block_row)
    sr = np.asarray(sbg.block_row)
    sc = np.asarray(sbg.block_col)
    sb = np.asarray(sbg.blocks)
    total_real = 0
    for d in range(num_shards):
        # Per-shard CSR sortedness (the Pallas kernels' precondition).
        assert (np.diff(sr[d]) >= 0).all()
        assert (0 <= sr[d]).all() and (sr[d] < local).all()
        assert (0 <= sc[d]).all() and (sc[d] < bg.num_src_groups).all()
        # Real tiles carry exactly the global tiles this owner holds.
        owner = np.minimum(row_g // local, num_shards - 1)
        k = int((owner == d).sum())
        total_real += k
        np.testing.assert_array_equal(
            sr[d, :k], row_g[owner == d] - d * local)
        # Padding tiles are all-zero (numerically inert).
        assert not sb[d, k:].any()
    assert total_real == sbg.num_blocks
    # Degrees cover the global groups and pad with zeros.
    assert sbg.deg.shape == (num_shards, local * bg.v)


def test_shard_blocked_tile_cap():
    bg, _ = blocked_fixture()
    sbg = shard_blocked(bg, 2)
    bigger = shard_blocked(bg, 2, tile_cap=sbg.tile_cap + 3)
    assert bigger.tile_cap == sbg.tile_cap + 3
    with pytest.raises(ValueError, match="tile_cap"):
        shard_blocked(bg, 2, tile_cap=max(sbg.tile_cap - 1, 0))
    with pytest.raises(ValueError, match="num_shards"):
        shard_blocked(bg, 0)


def test_plan_shard_strategy():
    # No prepped graph -> feature (needs no resharding, pays a psum).
    plan = plan_shard_strategy(6, 8, 16, 4)
    assert plan.strategy == "feature"
    assert plan.psum_bytes == 6 * 8 * 16 * 4 * 3
    assert not plan.bit_exact
    # Prepped graph -> dst_block: no collective, bit-exact.
    plan = plan_shard_strategy(6, 8, 16, 4, sharded_graph=True)
    assert plan.strategy == "dst_block"
    assert plan.psum_bytes == 0 and plan.bit_exact
    # Quantized stages only shard destination-wise.
    plan = plan_shard_strategy(6, 8, 16, 4, quantized=True)
    assert plan.strategy == "dst_block"
    assert not plan.bit_exact  # int8 epilogue exactness is backend-specific
    with pytest.raises(ValueError, match="quantized"):
        plan_shard_strategy(6, 8, 16, 4, quantized=True, strategy="feature")
    with pytest.raises(ValueError, match="unknown shard strategy"):
        plan_shard_strategy(6, 8, 16, 4, strategy="rows")


def test_shard_scope_stack():
    assert active_shard_context() is None
    mesh = object.__new__(object)  # never consulted below

    class StubMesh:
        axis_names = ("data",)
        shape = {"data": 2}

    mesh = StubMesh()
    with shard_scope(mesh):
        ctx = active_shard_context()
        assert ctx is not None and ctx.num_shards == 2
        with shard_scope(None):       # suppression for nested lowerings
            assert active_shard_context() is None
        assert active_shard_context() is ctx
    assert active_shard_context() is None
    with pytest.raises(ValueError, match="axis"):
        with shard_scope(mesh, "model"):
            pass


# ---------------------------------------------------------------------------
# Destination-block strategy: bit-exact on an 8-device host mesh.
# ---------------------------------------------------------------------------


@needs_devices
@pytest.mark.parametrize("backend", ["jnp", "pallas", "pallas_fused"])
@pytest.mark.parametrize("reduce", [ReduceOp.SUM, ReduceOp.MEAN, ReduceOp.MAX])
def test_dst_block_bit_exact(backend, reduce):
    bg, feat = blocked_fixture()
    w, b = make_weights(feat.shape[-1], 16)
    mesh = make_data_mesh(4)
    sbg = shard_blocked(bg, 4)
    with aggregate_backend(backend):
        ref = aggregate_combine_blocked(bg, feat, w, b, reduce=reduce,
                                        activation="relu")
        got = aggregate_combine_sharded(sbg, feat, w, b, mesh=mesh,
                                        reduce=reduce, activation="relu")
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))


@needs_devices
def test_dst_block_quantized_fused_bit_exact():
    """The fused int8 epilogue's activation scales are per destination
    row-block, so the owner partition reproduces them exactly."""
    bg, feat = blocked_fixture(seed=5)
    w, b = make_weights(feat.shape[-1], 8, seed=6)
    mesh = make_data_mesh(4)
    sbg = shard_blocked(bg, 4)
    with aggregate_backend("pallas_fused"):
        ref = aggregate_combine_blocked(bg, feat, w, b, quantized=True)
        got = aggregate_combine_sharded(sbg, feat, w, b, mesh=mesh,
                                        quantized=True)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))


@needs_devices
def test_dst_block_order_resolved_globally():
    """Wide-in/narrow-out geometry favors combine-first globally; the
    sharded forward must lower the same order on every device (a per-shard
    plan could flip it) and still match bit-exactly."""
    bg, feat = blocked_fixture(f=64)
    w, b = make_weights(64, 2)
    mesh = make_data_mesh(8)
    sbg = shard_blocked(bg, 8)
    ref = aggregate_combine_blocked(bg, feat, w, b, order="auto")
    got = aggregate_combine_sharded(sbg, feat, w, b, mesh=mesh, order="auto")
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))


@needs_devices
def test_sharded_api_errors():
    bg, feat = blocked_fixture()
    w, _ = make_weights(feat.shape[-1], 4)
    mesh = make_data_mesh(4)
    sbg = shard_blocked(bg, 4)
    with pytest.raises(ValueError, match="plain BlockedGraph"):
        aggregate_combine_sharded(sbg, feat, w, mesh=mesh,
                                  strategy="feature")
    with pytest.raises(ValueError, match="ShardedBlockedGraph"):
        aggregate_combine_sharded(bg, feat, w, mesh=mesh,
                                  strategy="dst_block")
    with pytest.raises(ValueError, match="mesh"):
        aggregate_combine_sharded(shard_blocked(bg, 2), feat, w, mesh=mesh)


# ---------------------------------------------------------------------------
# Feature-dim strategy: few-ULP tolerance, shard_scope routing.
# ---------------------------------------------------------------------------


@needs_devices
@pytest.mark.parametrize("reduce", [ReduceOp.SUM, ReduceOp.MEAN, ReduceOp.MAX])
def test_feature_strategy_tolerance(reduce):
    bg, feat = blocked_fixture()
    w, b = make_weights(feat.shape[-1], 16)
    mesh = make_data_mesh(8)
    ref = aggregate_combine_blocked(bg, feat, w, b, reduce=reduce,
                                    activation="relu")
    got = aggregate_combine_sharded(bg, feat, w, b, mesh=mesh,
                                    reduce=reduce, activation="relu")
    np.testing.assert_allclose(np.asarray(ref), np.asarray(got),
                               rtol=0, atol=1e-5)


@needs_devices
def test_feature_strategy_f_in_not_divisible():
    """F_in=12 over 8 devices: zero-padded columns/rows are exact no-ops."""
    bg, feat = blocked_fixture(f=12)
    assert feat.shape[-1] % 8 != 0
    w, b = make_weights(12, 8)
    mesh = make_data_mesh(8)
    ref = aggregate_combine_blocked(bg, feat, w, b)
    got = aggregate_combine_sharded(bg, feat, w, b, mesh=mesh)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(got),
                               rtol=0, atol=1e-5)


@needs_devices
def test_shard_scope_routes_and_quantized_stays_single_device():
    bg, feat = blocked_fixture()
    w, b = make_weights(feat.shape[-1], 16)
    mesh = make_data_mesh(4)
    ref = aggregate_combine_blocked(bg, feat, w, b)
    with shard_scope(mesh):
        got = aggregate_combine_blocked(bg, feat, w, b)
        # Quantized sites must bypass the feature router entirely: their
        # output is bit-identical to the unsharded quantized forward.
        q_ref = None
        with shard_scope(None):
            q_ref = aggregate_combine_blocked(bg, feat, w, b, quantized=True)
        q_got = aggregate_combine_blocked(bg, feat, w, b, quantized=True)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(got),
                               rtol=0, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(q_ref), np.asarray(q_got))


@needs_devices
def test_kernel_config_shard_none_vetoes_routing():
    bg, feat = blocked_fixture()
    w, _ = make_weights(feat.shape[-1], 16)
    ref = aggregate_combine_blocked(bg, feat, w)
    cfg = KernelConfig(shard="none")
    with shard_scope(make_data_mesh(4)), kernel_config_scope(lambda s: cfg):
        got = aggregate_combine_blocked(bg, feat, w)
    # Bit-identical: the veto keeps the site on the single-device lowering.
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))


# ---------------------------------------------------------------------------
# Engine integration: mesh-backed executor pool.
# ---------------------------------------------------------------------------


@needs_devices
def test_mesh_engine_matches_meshless():
    from repro.gnn import build_model
    from repro.serving import GnnServeEngine, gcn_prepare

    model = build_model("gcn", 12, 3, hidden=16)
    params = model.init(jax.random.PRNGKey(0))
    graphs = [make_graph(s, nv=40, ne=150) for s in range(5)]

    def serve(mesh):
        eng = GnnServeEngine(slots=4, mesh=mesh)
        eng.register("m", model, params, prepare_fn=gcn_prepare)
        rids = [eng.submit("m", g) for g in graphs]
        eng.drain()
        return eng, [eng.take_result(r) for r in rids]

    eng0, ref = serve(None)
    eng1, got = serve(make_data_mesh(4))
    for a, b in zip(ref, got):
        np.testing.assert_allclose(a, b, rtol=0, atol=1e-5)
    rep0, rep1 = eng0.report(1.0), eng1.report(1.0)
    assert rep0.topology == {}
    assert rep1.topology["num_devices"] == 4
    assert rep1.topology["mesh_shape"] == {"data": 4}
    assert rep1.topology["strategy"] == "feature"
    assert "mesh: 4 devices" in rep1.pretty()


@needs_devices
def test_executor_pool_mesh_validation():
    from repro.serving import ExecutorPool

    with pytest.raises(ValueError, match="axis"):
        ExecutorPool(slots=2, backend="jnp", mesh=make_data_mesh(2),
                     shard_axis="model")
