"""Multi-model serving: registry, executor pool, schedulers, admission.

The load-bearing claims of the multi-model refactor:
  * one engine serves a heterogeneous catalog (>= 3 models, >= 2 feature
    dims) with every per-request output bit-exact vs that model's own
    unbatched ``apply_blocked`` at fp32;
  * the jit-trace count stays <= |models| x |buckets observed|;
  * the FIFO scheduler preserves head-of-line order; the occupancy-aware
    scheduler serves the fullest group and its age bound prevents
    starvation under sustained load;
  * admission control bounds the waiting queue with working reject and
    shed-oldest policies, surfaced in the report;
  * MAX-reduce models ride the jnp fallback inside a Pallas-backend
    executor, and zero-edge graphs serve through the catalog path.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    Graph,
    ReduceOp,
    aggregate_backend,
    aggregate_blocked,
    partition_graph,
    to_blocked,
)
from repro.gnn import build_model
from repro.photonic.perf import GhostConfig
from repro.serving import (
    FifoScheduler,
    GnnServeEngine,
    GroupState,
    OccupancyScheduler,
    QueueFullError,
    gcn_prepare,
    make_scheduler,
)


def make_graph(seed, nv=None, ne=None, f=7):
    rng = np.random.default_rng(seed)
    nv = nv or int(rng.integers(6, 70))
    ne = ne or int(rng.integers(1, 200))
    return Graph(
        edge_src=rng.integers(0, nv, ne).astype(np.int32),
        edge_dst=rng.integers(0, nv, ne).astype(np.int32),
        node_feat=rng.standard_normal((nv, f)).astype(np.float32),
    ).validate()


# ---------------------------------------------------------------------------
# Scheduler policies (pure unit tests: no engine, no clocks).
# ---------------------------------------------------------------------------


def g_state(key, size, head_seq, wait_ticks=0, age_s=0.0):
    return GroupState(key=key, size=size, head_seq=head_seq,
                      head_wait_ticks=wait_ticks, head_age_s=age_s)


def test_fifo_picks_globally_oldest_group():
    groups = [g_state("a", size=8, head_seq=5),
              g_state("b", size=1, head_seq=2),
              g_state("c", size=3, head_seq=9)]
    assert FifoScheduler().select(groups, slots=4) == "b"


def test_occupancy_picks_fullest_group():
    groups = [g_state("a", size=2, head_seq=0),
              g_state("b", size=7, head_seq=3),
              g_state("c", size=4, head_seq=1)]
    assert OccupancyScheduler().select(groups, slots=8) == "b"


def test_occupancy_saturates_at_slots_and_breaks_ties_by_age():
    # Both a and b fill a 4-slot batch; a's head is older -> a wins.
    groups = [g_state("a", size=5, head_seq=1),
              g_state("b", size=20, head_seq=6),
              g_state("c", size=3, head_seq=0)]
    assert OccupancyScheduler().select(groups, slots=4) == "a"


def test_occupancy_starvation_override_by_ticks():
    groups = [g_state("hot", size=8, head_seq=50, wait_ticks=0),
              g_state("cold", size=1, head_seq=3, wait_ticks=4),
              g_state("colder", size=1, head_seq=1, wait_ticks=6)]
    sched = OccupancyScheduler(starvation_ticks=4)
    # Both cold groups are past the bound; the oldest head wins.
    assert sched.select(groups, slots=8) == "colder"


def test_occupancy_starvation_override_by_age():
    groups = [g_state("hot", size=8, head_seq=50),
              g_state("cold", size=1, head_seq=3, age_s=1.5)]
    sched = OccupancyScheduler(starvation_ticks=1000, starvation_age_s=1.0)
    assert sched.select(groups, slots=8) == "cold"


def test_make_scheduler_factory():
    assert make_scheduler("fifo").name == "fifo"
    assert make_scheduler("occupancy", starvation_ticks=5).starvation_ticks == 5
    custom = OccupancyScheduler()
    assert make_scheduler(custom) is custom
    with pytest.raises(ValueError):
        make_scheduler("lifo")
    with pytest.raises(ValueError):
        OccupancyScheduler(starvation_ticks=0)


# ---------------------------------------------------------------------------
# Heterogeneous catalog: bit-exactness and the trace bound.
# ---------------------------------------------------------------------------


def _catalog(key=0):
    """GCN+SAGE at f=5, GAT+GIN at f=12: 4 models, 2 feature dims."""
    ks = jax.random.split(jax.random.PRNGKey(key), 4)
    gcn = build_model("gcn", 5, 3, hidden=8)
    sage = build_model("sage", 5, 2, hidden=4)
    gat = build_model("gat", 12, 2, hidden=4, heads=2)
    gin = build_model("gin", 12, 2, hidden=8, mlp_layers=2)
    return {
        "gcn_f5": (gcn, gcn.init(ks[0]), "node", gcn_prepare),
        "sage_f5": (sage, sage.init(ks[1]), "node", None),
        "gat_f12": (gat, gat.init(ks[2]), "node", None),
        "gin_f12": (gin, gin.init(ks[3]), "graph", None),
    }


@pytest.mark.parametrize("scheduler", ["fifo", "occupancy"])
def test_multimodel_catalog_bit_exact(scheduler):
    catalog = _catalog()
    eng = GnnServeEngine(cfg=GhostConfig(v=8, n=8), slots=3,
                         scheduler=scheduler)
    for mid, (model, params, task, prep) in catalog.items():
        eng.register(mid, model, params, task=task, prepare_fn=prep)

    pool5 = [make_graph(s, f=5) for s in range(4)]
    pool12 = [make_graph(100 + s, f=12) for s in range(4)]
    requests = []
    for g5, g12 in zip(pool5, pool12):
        requests += [("gcn_f5", g5), ("gat_f12", g12),
                     ("sage_f5", g5), ("gin_f12", g12)]
    rep = eng.run(requests)

    assert rep.requests == len(requests)
    assert set(rep.per_model) == set(catalog)
    feat_dims = {catalog[mid][0].f_in for mid in catalog}
    assert len(feat_dims) >= 2 and len(catalog) >= 3

    rid = 0
    for g5, g12 in zip(pool5, pool12):
        for mid, g in (("gcn_f5", g5), ("gat_f12", g12),
                       ("sage_f5", g5), ("gin_f12", g12)):
            model, params, task, prep = catalog[mid]
            if prep is not None:
                g2, w = prep(g)
            else:
                g2, w = g, None
            pg = partition_graph(g2, v=8, n=8, edge_weights=w)
            featp = jnp.asarray(pg.pad_features(g.node_feat))
            # The reference is the *jitted* unbatched blocked forward —
            # what an unbatched deployment would actually run.  (Eager
            # execution can differ from any jitted run by 1 ULP in GAT's
            # fused softmax; that is an XLA property, not engine drift.)
            bgs = to_blocked(pg)
            ref = np.asarray(jax.jit(
                lambda p, f, m=model, bgs=bgs: m.apply_blocked(p, bgs, f)
            )(params, featp))
            if task == "node":
                ref = ref[: g.num_nodes]
            np.testing.assert_array_equal(eng.results[rid], ref,
                                          err_msg=f"{mid} rid={rid}")
            rid += 1

    # One jit trace per (model, bucket): bounded by the observed product.
    distinct_buckets = len(rep.buckets)
    assert rep.traces_compiled == len(eng.pool)
    assert rep.traces_compiled <= len(catalog) * distinct_buckets


def test_models_share_preprocessing_across_catalog():
    """Two models with the same prepare transform share one partition."""
    g = make_graph(1, nv=20, ne=50, f=5)
    gcn = build_model("gcn", 5, 2, hidden=4)
    sage = build_model("sage", 5, 2, hidden=4)
    eng = GnnServeEngine(cfg=GhostConfig(v=8, n=8), slots=2)
    eng.register("gcn", gcn, gcn.init(jax.random.PRNGKey(0)))
    eng.register("sage", sage, sage.init(jax.random.PRNGKey(1)))
    eng.submit("gcn", g)
    eng.submit("sage", g)   # same structure, same (empty) salt -> cache hit
    eng.drain()
    assert eng.cache.stats.misses == 1
    assert eng.cache.stats.hits == 1


# ---------------------------------------------------------------------------
# Anti-starvation under sustained load.
# ---------------------------------------------------------------------------


def test_occupancy_antistarvation_serves_cold_group():
    """A lone cold request is served within the starvation bound even while
    a hot group stays permanently full."""
    hot = make_graph(2, nv=16, ne=40, f=5)
    cold = make_graph(3, nv=60, ne=150, f=5)   # different bucket
    model = build_model("gcn", 5, 2, hidden=4)
    params = model.init(jax.random.PRNGKey(0))
    bound = 3
    eng = GnnServeEngine(
        cfg=GhostConfig(v=8, n=8), slots=4,
        # age bound off: this tick-driven test wants the tick bound to be
        # what serves the cold group, deterministically.
        scheduler=OccupancyScheduler(starvation_ticks=bound,
                                     starvation_age_s=None))
    eng.register("m", model, params)

    cold_rid = eng.submit("m", cold)
    served_at = None
    for tick in range(10):
        for _ in range(4):
            eng.submit("m", hot)   # keep the hot group full every tick
        eng.step()
        if cold_rid in eng.results and served_at is None:
            served_at = tick
    assert served_at is not None, "cold request starved"
    assert served_at <= bound
    cold_rec = next(r for r in eng.records if r.rid == cold_rid)
    assert cold_rec.wait_ticks <= bound
    # Sanity: the hot group was indeed preferred before the bound hit.
    assert eng.records[0].rid != cold_rid


# ---------------------------------------------------------------------------
# Admission control.
# ---------------------------------------------------------------------------


def test_admission_reject_policy():
    g = make_graph(4, nv=12, ne=20, f=5)
    model = build_model("gcn", 5, 2, hidden=4)
    params = model.init(jax.random.PRNGKey(0))
    eng = GnnServeEngine(cfg=GhostConfig(v=8, n=8), slots=2, max_waiting=2)
    eng.register("m", model, params)
    assert eng.try_submit("m", g) == 0
    assert eng.try_submit("m", g) == 1
    assert eng.try_submit("m", g) is None   # queue full -> rejected
    with pytest.raises(QueueFullError):
        eng.submit("m", g)
    eng.drain()
    rep = eng.report(1.0)
    assert rep.requests == 2
    assert rep.admitted == 2 and rep.rejected == 2 and rep.shed == 0
    assert rep.reject_rate == pytest.approx(0.5)
    # Queue drained: the next submission is admitted again.
    assert eng.try_submit("m", g) is not None


def test_admission_shed_oldest_policy():
    g = make_graph(5, nv=12, ne=20, f=5)
    model = build_model("gcn", 5, 2, hidden=4)
    params = model.init(jax.random.PRNGKey(0))
    eng = GnnServeEngine(cfg=GhostConfig(v=8, n=8), slots=2, max_waiting=2,
                         admission_policy="shed-oldest")
    eng.register("m", model, params)
    r0 = eng.submit("m", g)
    r1 = eng.submit("m", g)
    r2 = eng.submit("m", g)   # sheds r0 to make room
    eng.drain()
    assert eng.shed_rids == [r0]
    assert r0 not in eng.results
    assert r1 in eng.results and r2 in eng.results
    rep = eng.report(1.0)
    assert rep.requests == 2
    assert rep.admitted == 3 and rep.shed == 1 and rep.rejected == 0


def test_run_interleaves_serving_with_bounded_queue():
    """Closed-loop run() makes progress instead of rejecting at the bound."""
    graphs = [make_graph(10 + s, nv=16, ne=30, f=5) for s in range(8)]
    model = build_model("gcn", 5, 2, hidden=4)
    params = model.init(jax.random.PRNGKey(0))
    eng = GnnServeEngine(cfg=GhostConfig(v=8, n=8), slots=2, max_waiting=2)
    eng.register("m", model, params)
    rep = eng.run(graphs)
    assert rep.requests == len(graphs)
    assert rep.rejected == 0
    assert len(eng.results) == len(graphs)


def test_shed_is_not_performed_when_preprocessing_fails():
    """A full queue must not lose a healthy victim to a doomed submission."""
    g = make_graph(6, nv=12, ne=20, f=5)
    model = build_model("gcn", 5, 2, hidden=4)
    params = model.init(jax.random.PRNGKey(0))
    eng = GnnServeEngine(cfg=GhostConfig(v=8, n=8), slots=2, max_waiting=2,
                         admission_policy="shed-oldest")
    eng.register("m", model, params)
    eng.submit("m", g)
    eng.submit("m", g)

    def boom(*a, **kw):
        raise RuntimeError("preprocessing exploded")

    eng.cache.get_or_partition = boom
    with pytest.raises(RuntimeError):
        eng.submit("m", g)
    # No victim shed, queue intact, and the failed admission rolled back.
    assert eng.shed_rids == []
    assert eng.num_waiting == 2
    assert eng.admission.stats.admitted == 2
    assert eng.admission.stats.shed == 0


def test_report_max_wait_sees_waiting_and_shed_requests():
    """The starvation gauge must not be blind to never-served requests."""
    hot = make_graph(7, nv=16, ne=40, f=5)
    cold = make_graph(8, nv=60, ne=150, f=5)   # different bucket
    model = build_model("gcn", 5, 2, hidden=4)
    params = model.init(jax.random.PRNGKey(0))
    eng = GnnServeEngine(
        cfg=GhostConfig(v=8, n=8), slots=4,
        # neither bound may trip: the point is the *gauge*, not a rescue
        scheduler=OccupancyScheduler(starvation_ticks=100,
                                     starvation_age_s=None))
    eng.register("m", model, params)
    cold_rid = eng.submit("m", cold)
    for _ in range(3):
        for _ in range(4):
            eng.submit("m", hot)
        eng.step()
    assert cold_rid not in eng.results          # still starving
    assert eng.report(1.0).max_wait_ticks >= 3  # ...and the gauge shows it

    # Shedding the starved request must keep its wait in the gauge too.
    eng2 = GnnServeEngine(cfg=GhostConfig(v=8, n=8), slots=2, max_waiting=2,
                          admission_policy="shed-oldest")
    eng2.register("m", model, params)
    eng2.submit("m", cold)
    eng2.submit("m", hot)
    eng2.step()                   # tick 1: serves one group
    eng2.submit("m", hot)
    eng2.submit("m", hot)         # queue full again -> sheds the oldest
    shed_wait = eng2.report(1.0).max_wait_ticks
    eng2.drain()
    assert eng2.shed_rids and shed_wait >= 1


def test_take_result_reclaims_memory():
    g = make_graph(9, nv=12, ne=20, f=5)
    model = build_model("gcn", 5, 2, hidden=4)
    params = model.init(jax.random.PRNGKey(0))
    eng = GnnServeEngine(cfg=GhostConfig(v=8, n=8), slots=2)
    eng.register("m", model, params)
    rid = eng.submit("m", g)
    eng.drain()
    out = eng.take_result(rid)
    assert out.shape[0] == g.num_nodes
    assert rid not in eng.results
    with pytest.raises(KeyError):
        eng.take_result(rid)


# ---------------------------------------------------------------------------
# Backend fallbacks and degenerate graphs through the catalog path.
# ---------------------------------------------------------------------------


class _MaxPoolModel:
    """Minimal MAX-reduce node model: aggregate(MAX) then a linear head.

    MAX has no Pallas SpMM analogue (the optical comparator is not an
    MXU contraction), so inside a Pallas-backend executor this must take
    the jnp fallback path of aggregate_blocked.
    """

    f_in = 6

    def init(self, key):
        return {"w": jax.random.normal(key, (self.f_in, 3), jnp.float32)}

    def apply_blocked(self, params, bg, feat_padded, quantized=False):
        h = aggregate_blocked(bg, feat_padded, ReduceOp.MAX)
        return h @ params["w"]


def test_max_reduce_model_uses_jnp_fallback_in_pallas_executor():
    graphs = [make_graph(20 + s, nv=14, ne=30, f=6) for s in range(3)]
    model = _MaxPoolModel()
    params = model.init(jax.random.PRNGKey(7))
    eng = GnnServeEngine(cfg=GhostConfig(v=4, n=4), slots=2,
                         backend="pallas")
    eng.register("maxpool", model, params, task="node")
    eng.run(graphs)
    for i, g in enumerate(graphs):
        pg = partition_graph(g, v=4, n=4)
        featp = jnp.asarray(pg.pad_features(g.node_feat))
        with aggregate_backend("pallas"):
            ref = np.asarray(model.apply_blocked(params, to_blocked(pg),
                                                 featp))[: g.num_nodes]
        np.testing.assert_array_equal(eng.results[i], ref)


def test_zero_edge_graphs_through_multimodel_engine():
    rng = np.random.default_rng(0)
    z5 = Graph(edge_src=np.zeros(0, np.int32), edge_dst=np.zeros(0, np.int32),
               node_feat=rng.standard_normal((7, 5)).astype(np.float32)
               ).validate()
    z12 = Graph(edge_src=np.zeros(0, np.int32),
                edge_dst=np.zeros(0, np.int32),
                node_feat=rng.standard_normal((9, 12)).astype(np.float32)
                ).validate()
    gcn = build_model("gcn", 5, 2, hidden=4)
    gin = build_model("gin", 12, 2, hidden=4, mlp_layers=2)
    eng = GnnServeEngine(cfg=GhostConfig(v=4, n=4), slots=2,
                         backend="pallas")
    eng.register("gcn", gcn, gcn.init(jax.random.PRNGKey(0)))
    eng.register("gin", gin, gin.init(jax.random.PRNGKey(1)), task="graph")
    rep = eng.run([("gcn", z5), ("gin", z12)])
    assert rep.requests == 2
    for mid, g, rid, task in (("gcn", z5, 0, "node"), ("gin", z12, 1, "graph")):
        model = {"gcn": gcn, "gin": gin}[mid]
        params = eng.registry[mid].params
        pg = partition_graph(g, v=4, n=4)
        featp = jnp.asarray(pg.pad_features(g.node_feat))
        with aggregate_backend("pallas"):
            ref = np.asarray(model.apply_blocked(params, to_blocked(pg),
                                                 featp))
        if task == "node":
            ref = ref[: g.num_nodes]
        np.testing.assert_array_equal(eng.results[rid], ref)
