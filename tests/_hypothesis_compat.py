"""Optional-hypothesis shim for the test suite.

When ``hypothesis`` is installed, this module re-exports the real
``given`` / ``strategies`` / ``hypothesis.extra.numpy`` so the property
tests run at full strength (the CI profile is registered in conftest.py).

When it is missing (the minimal container), a deterministic fallback keeps
the same tests running instead of killing collection: ``given`` replays a
fixed number of seeded examples per test, with the first examples pinned to
the strategy's boundary values.  Only the small strategy surface this repo
uses is implemented (integers, floats, .map, hypothesis.extra.numpy.arrays).
"""

from __future__ import annotations

import hashlib

import numpy as np

try:
    from hypothesis import given, strategies as st
    from hypothesis.extra import numpy as hnp

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    _NUM_EXAMPLES = 10

    class _Strategy:
        def __init__(self, sample, boundaries=()):
            self._sample = sample
            self._boundaries = tuple(boundaries)

        def map(self, fn):
            return _Strategy(lambda rng: fn(self._sample(rng)),
                             [fn(b) for b in self._boundaries])

        def example(self, rng, index: int):
            if index < len(self._boundaries):
                return self._boundaries[index]
            return self._sample(rng)

    class _Integers:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)),
                [min_value, max_value],
            )

        @staticmethod
        def floats(min_value, max_value, **_kw):
            return _Strategy(
                lambda rng: float(rng.uniform(min_value, max_value)),
                [float(min_value), float(max_value)],
            )

    st = _Integers()

    class _Hnp:
        @staticmethod
        def arrays(dtype, shape, elements=None):
            def sample_shape(rng, index):
                if isinstance(shape, _Strategy):
                    return shape.example(rng, index)
                return shape

            def sample(rng, index=10**9):
                shp = sample_shape(rng, index)
                if isinstance(shp, (int, np.integer)):
                    shp = (int(shp),)
                size = int(np.prod(shp)) if shp else 1
                if elements is None:
                    flat = rng.standard_normal(size)
                else:
                    flat = np.array(
                        [elements.example(rng, 10**9) for _ in range(size)])
                return flat.reshape(shp).astype(dtype)

            strat = _Strategy(sample)
            strat.example = lambda rng, index: sample(rng, index)
            return strat

    hnp = _Hnp()

    def _stable_seed(name: str, index: int) -> int:
        digest = hashlib.sha1(f"{name}:{index}".encode()).digest()
        return int.from_bytes(digest[:4], "little")

    def given(*strats):
        def decorator(fn):
            # No functools.wraps: pytest must see a zero-arg signature, not
            # the wrapped function's strategy parameters (it would go
            # looking for fixtures of the same names).
            def wrapper():
                for i in range(_NUM_EXAMPLES):
                    rng = np.random.default_rng(
                        _stable_seed(fn.__qualname__, i))
                    values = [s.example(rng, i) for s in strats]
                    fn(*values)
            wrapper.__name__ = fn.__name__
            wrapper.__qualname__ = fn.__qualname__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper
        return decorator
