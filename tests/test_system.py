"""End-to-end behaviour tests for the paper's system: train a GNN, quantize
it to the photonic 8-bit format, serve it through the GHOST blocked dataflow
(Pallas kernel), and evaluate the analytic performance model on it —
the full paper pipeline in one test module."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ReduceOp, aggregate_blocked, partition_graph, to_blocked
from repro.gnn import build_model
from repro.gnn.datasets import TABLE2, make_node_classification
from repro.gnn.train import (
    eval_node_classifier,
    node_graph_arrays,
    train_node_classifier,
)
from repro.kernels import aggregate_blocked_kernel
from repro.photonic.perf import GhostConfig, GnnModelSpec, OrchFlags, simulate

TABLE2["SysTest"] = dict(nodes=260, edges=1100, features=64, labels=4, graphs=1)


@pytest.fixture(scope="module")
def trained():
    graph = make_node_classification("SysTest", seed=11)
    model = build_model("gcn", 64, 4, hidden=16)
    params, _ = train_node_classifier(model, graph, steps=100, lr=0.02)
    return graph, model, params


def test_end_to_end_photonic_serving(trained):
    """fp32 training -> int8 photonic serving via the blocked dataflow +
    Pallas kernel: accuracy survives and all three backends agree."""
    graph, model, params = trained
    acc_fp32 = eval_node_classifier(model, params, graph)
    assert acc_fp32 > 0.6

    arrs = node_graph_arrays(graph)
    g = arrs["graph"]
    pg = partition_graph(g, v=20, n=20, edge_weights=g.gcn_edge_weights())
    bg = to_blocked(pg)
    featp = jnp.asarray(pg.pad_features(g.node_feat))

    # serving path 1: blocked jnp backend, quantized combine
    logits_q = model.apply_blocked(params, bg, featp, quantized=True)
    pred_q = np.asarray(jnp.argmax(logits_q[:g.num_nodes], -1))
    mask = np.asarray(arrs["test_mask"])
    labels = np.asarray(arrs["labels"])
    acc_q = (pred_q[mask] == labels[mask]).mean()
    assert abs(acc_fp32 - acc_q) < 0.06  # Table 3 parity claim

    # serving path 2: the Pallas kernel computes the same aggregation
    agg_kernel = aggregate_blocked_kernel(pg, featp, block_f=16, interpret=True)
    agg_jnp = aggregate_blocked(bg, featp, ReduceOp.SUM)
    np.testing.assert_allclose(np.asarray(agg_kernel), np.asarray(agg_jnp),
                               atol=1e-4)


def test_perf_model_on_served_workload(trained):
    """The analytic model runs on the exact served graph and produces
    self-consistent numbers (energy = power x latency; GOPS > 0)."""
    graph, _, _ = trained
    spec = GnnModelSpec.gcn(64, 16, 4)
    r = simulate(spec, graph, GhostConfig(), OrchFlags())
    assert r.latency > 0 and r.energy > 0
    assert r.power == pytest.approx(r.energy / r.latency, rel=1e-6)
    assert r.gops > 10
    assert r.epb > 0
    # optimized config beats a deliberately bad one on EPB/GOPS
    bad = simulate(spec, graph, GhostConfig(n=4, v=4, rr=4, rc=2, tr=4),
                   OrchFlags())
    assert r.epb_per_gops < bad.epb_per_gops


def test_noise_faithful_inference(trained):
    """Inject calibrated crosstalk-level noise into the quantized forward
    pass; accuracy should be robust at the paper's SNR (21+ dB) and degrade
    at hostile SNR."""
    graph, model, params = trained
    arrs = node_graph_arrays(graph)

    def noisy_eval(snr_db, seed=0):
        rng = np.random.default_rng(seed)
        frac = 10 ** (-snr_db / 10)
        noisy = jax.tree.map(
            lambda p: p + jnp.asarray(
                rng.standard_normal(p.shape).astype(np.float32)
            ) * jnp.std(p) * np.sqrt(frac),
            params)
        return eval_node_classifier(model, noisy, graph, quantized=True)

    clean = eval_node_classifier(model, params, graph, quantized=True)
    at_design_snr = noisy_eval(21.3)
    hostile = np.mean([noisy_eval(-3.0, s) for s in range(3)])
    assert abs(clean - at_design_snr) < 0.1
    assert hostile < clean - 0.15
