"""Photonic noise models (Eqs. 2-13): physics invariants + the paper's
reported device-DSE results (Section 4.2)."""

import math

import numpy as np
import pytest
from _hypothesis_compat import given, st

from repro.photonic import noise as nz

D = nz.MRDesign()


def test_required_snr_matches_paper():
    # Paper: ~21.3 dB for N_levels=2^7 at the selected design.
    assert abs(nz.required_snr_db(128, 1520, 3100) - 21.16) < 0.2
    assert abs(nz.required_snr_db(128, 1550, 3100) - 21.07) < 0.2


def test_coherent_bank_limit_is_20_at_1520nm():
    assert nz.max_coherent_mrs(1520.0, D) == 20


def test_1520nm_is_coherent_optimum():
    best = max(np.arange(1500, 1581, 5.0), key=lambda l: nz.max_coherent_mrs(l, D))
    assert best == 1520.0


def test_noncoherent_limit_is_18_wavelengths():
    assert nz.max_noncoherent_wavelengths(D) == 18


def test_fwhm_eq5():
    assert nz.fwhm_nm(1550, 3100) == pytest.approx(0.5)
    assert nz.tunable_range_nm(1550, 3100) == pytest.approx(1.0)


@given(st.integers(1, 40))
def test_homodyne_noise_monotone_in_bank_size(n):
    a = nz.homodyne_noise_fraction(n, 1520.0, D)
    b = nz.homodyne_noise_fraction(n + 1, 1520.0, D)
    assert b > a >= 0.0


@given(st.floats(1000, 10000), st.floats(0.2, 5.0))
def test_heterodyne_noise_decreases_with_spacing_and_q(q, spacing):
    lam = 1550 + spacing * np.arange(8)
    tight = nz.heterodyne_noise_fraction(lam, q, 2.0)
    wide = nz.heterodyne_noise_fraction(1550 + 2 * spacing * np.arange(8), q, 2.0)
    assert wide <= tight + 1e-12
    higher_q = nz.heterodyne_noise_fraction(lam, q * 2, 2.0)
    assert higher_q <= tight + 1e-12


def test_snr_db_definition():
    assert nz.snr_db(0.01) == pytest.approx(20.0)


def test_q_factor_eq7_increases_with_weaker_coupling():
    q1 = nz.q_factor_from_coupling(0.3, 0.99, 1550, D)
    q2 = nz.q_factor_from_coupling(0.1, 0.99, 1550, D)
    assert q2 > q1 > 0


def test_ted_cancels_thermal_crosstalk():
    rng = np.random.default_rng(0)
    n = 12
    k = np.eye(n) + 0.08 * rng.random((n, n))
    k = (k + k.T) / 2
    t = rng.random(n)
    naive = nz.thermal_crosstalk_error(k, t, use_ted=False)
    ted = nz.thermal_crosstalk_error(k, t, use_ted=True)
    assert ted < 1e-9
    assert naive > 1e-3


def test_ted_singular_coupling_raises():
    k = np.ones((4, 4))  # rank-1: physically undecomposable
    with pytest.raises(ValueError):
        nz.ted_drive_levels(k, np.ones(4))
