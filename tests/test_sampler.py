"""Neighborhood-sampled node-query serving (serving/sampler.py + engine).

The load-bearing claims:
  * ``HostGraph`` is a faithful CSR in-adjacency store with a
    structure-only fingerprint;
  * ``sample_khop`` is deterministic per (rng_seed, vertex), respects the
    per-layer fanout budget, and under full fanout covers the whole k-hop
    in-neighborhood;
  * the exactness contract: a full-fanout sample served through
    ``submit_nodes`` reproduces the full-graph forward BIT-EXACTLY at the
    seed rows, on all three backends, for both plain (SAGE/mean) and
    host-degree-normalized (GCN) models;
  * determinism feeds the cache: identical queries hash to one partition
    entry;
  * zero-edge / isolated-seed edge cases serve cleanly.
"""

import numpy as np
import pytest

import jax

from repro.core.graph import Graph
from repro.gnn import build_model
from repro.photonic.perf import GhostConfig
from repro.serving import (
    GnnServeEngine,
    HostGraph,
    gcn_prepare,
    gcn_sample_prepare,
    sample_khop,
)

from tests._hypothesis_compat import given, st

CFG = GhostConfig()  # v=20, n=20 -> sampler align = lcm = 20


def power_law_host(nv=400, deg=5, f=6, seed=0):
    return HostGraph.synthetic_power_law(
        nv, avg_degree=deg, num_features=f, seed=seed)


def full_graph_of(host: HostGraph) -> Graph:
    """The host graph as an ordinary edge-list Graph (reference forward)."""
    dst = np.repeat(np.arange(host.num_nodes, dtype=np.int64),
                    np.diff(host.indptr))
    return Graph(edge_src=host.indices.astype(np.int32),
                 edge_dst=dst.astype(np.int32),
                 node_feat=host.features)


# ---------------------------------------------------------------------------
# HostGraph store.
# ---------------------------------------------------------------------------


def test_host_graph_csr_roundtrip():
    src = np.array([1, 2, 2, 0, 3, 3])
    dst = np.array([0, 0, 1, 2, 3, 3])  # 3 has a (parallel) self-loop
    feat = np.arange(8, dtype=np.float32).reshape(4, 2)
    host = HostGraph.from_edges(src, dst, feat)
    assert host.num_nodes == 4 and host.num_edges == 6
    np.testing.assert_array_equal(host.in_degrees(), [2, 1, 1, 2])
    np.testing.assert_array_equal(host.in_neighbors(0), [1, 2])
    np.testing.assert_array_equal(host.has_loop, [False, False, False, True])
    # Parallel edges are kept (the partitioner accumulates them).
    np.testing.assert_array_equal(host.in_neighbors(3), [3, 3])


def test_host_graph_fingerprint_is_structure_only():
    src = np.array([1, 2]); dst = np.array([0, 1])
    f1 = np.zeros((3, 4), np.float32)
    f2 = np.ones((3, 4), np.float32)
    a = HostGraph.from_edges(src, dst, f1)
    b = HostGraph.from_edges(src, dst, f2)
    c = HostGraph.from_edges(np.array([2, 1]), dst, f1)
    assert a.fingerprint == b.fingerprint  # features don't enter
    assert a.fingerprint != c.fingerprint  # structure does


def test_host_graph_from_graph_matches_from_edges():
    g = full_graph_of(power_law_host(nv=60))
    host = HostGraph.from_graph(g)
    assert host.num_edges == g.num_edges
    np.testing.assert_array_equal(host.in_degrees(), g.in_degrees())


# ---------------------------------------------------------------------------
# sample_khop mechanics.
# ---------------------------------------------------------------------------


def test_sample_determinism_and_block_alignment():
    host = power_law_host()
    a = sample_khop(host, [3, 77], (4, 2), rng_seed=9, align=20)
    b = sample_khop(host, [3, 77], (4, 2), rng_seed=9, align=20)
    np.testing.assert_array_equal(a.graph.edge_src, b.graph.edge_src)
    np.testing.assert_array_equal(a.graph.edge_dst, b.graph.edge_dst)
    np.testing.assert_array_equal(a.host_ids, b.host_ids)
    np.testing.assert_array_equal(a.seed_rows, b.seed_rows)
    # Block alignment: every real row keeps its host position mod align.
    real = a.real_rows
    np.testing.assert_array_equal(real % 20, a.host_ids[real] % 20)
    # Ghost rows carry no features and no edges.
    ghosts = np.flatnonzero(a.host_ids < 0)
    assert not np.isin(a.graph.edge_src, ghosts).any()
    assert not np.isin(a.graph.edge_dst, ghosts).any()
    np.testing.assert_array_equal(a.graph.node_feat[ghosts], 0.0)


def test_sample_rng_seed_changes_subsample():
    host = power_law_host(nv=300, deg=12)
    a = sample_khop(host, [5], (3,), rng_seed=0)
    b = sample_khop(host, [5], (3,), rng_seed=1)
    # The seed vertex has >3 in-neighbors with overwhelming probability;
    # different policies should pick different subsets at least once.
    assert (a.graph.num_edges != b.graph.num_edges
            or not np.array_equal(np.sort(a.host_ids[a.real_rows]),
                                  np.sort(b.host_ids[b.real_rows])))


def test_sample_respects_fanout_budget():
    host = power_law_host(nv=300, deg=12)
    s = sample_khop(host, [5, 9], (3, 2), rng_seed=0)
    # Layer budgets bound the per-destination edge counts: seeds get <= 3
    # in-edges, frontier vertices <= 2 (a vertex reached at layer 1 that is
    # also a seed keeps its seed-layer sample).
    deg = np.zeros(s.graph.num_nodes, np.int64)
    np.add.at(deg, s.graph.edge_dst, 1)
    assert deg[s.seed_rows].max() <= 3
    assert deg.max() <= 3
    assert s.num_sampled_edges == s.graph.num_edges


def test_sample_full_fanout_covers_khop():
    host = power_law_host(nv=200, deg=4)
    seeds = [0, 111]
    s = sample_khop(host, seeds, (None, None))
    # BFS the in-adjacency 2 deep on the host and compare edge sets.
    lvl0 = np.unique(seeds)
    e1_src = np.concatenate([host.in_neighbors(v) for v in lvl0])
    lvl1 = np.setdiff1d(np.unique(e1_src), lvl0)
    want_edges = set()
    for v in lvl0:
        want_edges.update((int(u), int(v)) for u in host.in_neighbors(v))
    for v in lvl1:
        want_edges.update((int(u), int(v)) for u in host.in_neighbors(v))
    got_edges = set(zip(s.host_ids[s.graph.edge_src].tolist(),
                        s.host_ids[s.graph.edge_dst].tolist()))
    assert got_edges == want_edges
    assert s.num_sampled_edges == len(s.graph.edge_src)


def test_sample_zero_edge_and_isolated_seed():
    # A host with no edges at all: the sample is just the seed blocks.
    feat = np.random.default_rng(0).standard_normal((50, 3)).astype(np.float32)
    empty = HostGraph.from_edges(np.zeros(0, np.int64), np.zeros(0, np.int64),
                                 feat)
    s = sample_khop(empty, [7], (5, 5), align=20)
    assert s.graph.num_edges == 0
    assert s.num_sampled_nodes == 1
    np.testing.assert_array_equal(
        s.graph.node_feat[s.seed_rows[0]], feat[7])
    # An isolated seed in a connected graph behaves the same.
    host = power_law_host(nv=100, deg=3, seed=1)
    iso = int(np.flatnonzero(host.in_degrees() == 0)[0]) \
        if (host.in_degrees() == 0).any() else None
    if iso is not None:
        s2 = sample_khop(host, [iso], (4,))
        assert s2.graph.num_edges == 0
        assert s2.num_sampled_nodes == 1


def test_sample_input_validation():
    host = power_law_host(nv=50)
    with pytest.raises(ValueError):
        sample_khop(host, [], (2,))
    with pytest.raises(ValueError):
        sample_khop(host, [50], (2,))
    with pytest.raises(ValueError):
        sample_khop(host, [0], (0,))
    with pytest.raises(ValueError):
        sample_khop(host, [0], (2,), align=0)


# ---------------------------------------------------------------------------
# GCN degree bookkeeping.
# ---------------------------------------------------------------------------


def test_gcn_sample_prepare_matches_host_weights_under_full_fanout():
    host = power_law_host(nv=160, deg=4)
    g_full = full_graph_of(host)
    gl, wl = gcn_prepare(g_full)  # whole-graph reference prepare
    ref = {(int(s), int(d)): w
           for s, d, w in zip(gl.edge_src, gl.edge_dst, wl)}
    s = sample_khop(host, [11, 42], (None, None), align=20)
    g2, w2 = gcn_sample_prepare(s, host)
    # Every prepared sampled edge carries the bitwise-identical weight the
    # full-graph prepare assigns the same host edge.
    assert g2.num_edges > 0
    for src, dst, w in zip(g2.edge_src, g2.edge_dst, w2):
        hs, hd = int(s.host_ids[src]), int(s.host_ids[dst])
        assert hs >= 0 and hd >= 0  # loops only on real rows
        assert ref[(hs, hd)] == w  # exact fp32 equality


def test_gcn_sample_prepare_uses_host_not_subgraph_degrees():
    host = power_law_host(nv=200, deg=10)
    s = sample_khop(host, [3], (2,), rng_seed=0)  # truncated neighborhoods
    g2, w2 = gcn_sample_prepare(s, host)
    host_deg = host.in_degrees()
    # Pick a frontier edge (non-loop) and check its weight is built from
    # the *host* degrees, which exceed the truncated subgraph's.
    nonloop = np.flatnonzero(g2.edge_src != g2.edge_dst)
    assert nonloop.size
    e = int(nonloop[0])
    hs = int(s.host_ids[g2.edge_src[e]])
    hd = int(s.host_ids[g2.edge_dst[e]])
    ds = host_deg[hs] + (0 if host.has_loop[hs] else 1)
    dd = host_deg[hd] + (0 if host.has_loop[hd] else 1)
    expect = np.float32(1.0 / np.sqrt(np.maximum(np.float64(dd), 1)
                                      * np.maximum(np.float64(ds), 1)))
    assert w2[e] == expect


# ---------------------------------------------------------------------------
# The exactness contract: sampled serving == full-graph forward at seeds.
# ---------------------------------------------------------------------------


def _exactness_case(model_kind, backend, nv, seed, seeds):
    host = power_law_host(nv=nv, deg=4, f=5, seed=seed)
    g_full = full_graph_of(host)
    model = build_model(model_kind, 5, 2, hidden=8)
    params = model.init(jax.random.PRNGKey(seed))
    prep = gcn_prepare if model_kind == "gcn" else None

    eng = GnnServeEngine(cfg=CFG, slots=2, backend=backend)
    eng.register("m", model, params, task="node", prepare_fn=prep)
    eng.register_host_graph("hg", host, fanouts=(None, None))
    rid = eng.submit_nodes("m", seeds)
    eng.drain()

    ref_eng = GnnServeEngine(cfg=CFG, slots=2, backend=backend)
    ref_eng.register("m", model, params, task="node", prepare_fn=prep)
    ref_rid = ref_eng.submit("m", g_full)
    ref_eng.drain()

    np.testing.assert_array_equal(
        eng.results[rid], ref_eng.results[ref_rid][np.asarray(seeds)])


@pytest.mark.parametrize("backend", ["jnp", "pallas", "pallas_fused"])
@pytest.mark.parametrize("model_kind", ["sage", "gcn"])
def test_full_fanout_bit_exact_vs_full_graph(backend, model_kind):
    _exactness_case(model_kind, backend, nv=150, seed=0, seeds=[4, 77, 149])


@given(st.integers(1, 40), st.integers(0, 6))
def test_property_full_fanout_bit_exact(nv_scale, seed):
    """Random graph sizes and seed vertices: exactness is not a fluke of
    one layout (hypothesis where available, seeded replay otherwise)."""
    nv = 30 + 7 * nv_scale
    seeds = [seed % nv, (13 * seed + 7) % nv]
    _exactness_case("sage", "jnp", nv=nv, seed=seed, seeds=seeds)


@pytest.mark.parametrize("model_kind", ["sage", "gcn"])
def test_multi_seed_batch_matches_solo_submissions(model_kind):
    """One multi-seed query samples ONE shared subgraph, yet each seed row
    is bit-exact with that seed's solo submission: per-vertex draws depend
    only on (rng_seed, vertex), never on which other seeds rode along, and
    extra union vertices feed no messages into a seed's own neighborhood
    (sampling hops cover the model depth: 2 hops, 2 layers)."""
    host = power_law_host(nv=300, deg=8, f=5, seed=1)
    model = build_model(model_kind, 5, 2, hidden=8)
    params = model.init(jax.random.PRNGKey(0))
    prep = gcn_prepare if model_kind == "gcn" else None

    def engine():
        eng = GnnServeEngine(cfg=CFG, slots=2)
        eng.register("m", model, params, task="node", prepare_fn=prep)
        eng.register_host_graph("hg", host, fanouts=(4, 3), rng_seed=5)
        return eng

    seeds = [10, 20, 55, 123]
    eng_b = engine()
    rid = eng_b.submit_nodes("m", seeds)
    eng_b.drain()
    batch_out = eng_b.results[rid]
    assert batch_out.shape[0] == len(seeds)
    # One request, one sampled subgraph, one partitioning.
    rec = eng_b.records[-1]
    assert rec.num_seeds == len(seeds)
    assert eng_b.cache.stats.misses == 1
    assert len(eng_b.records) == 1

    for i, s in enumerate(seeds):
        eng_s = engine()
        rs = eng_s.submit_nodes("m", [s])
        eng_s.drain()
        np.testing.assert_array_equal(batch_out[i], eng_s.results[rs][0])


def test_restricted_fanout_serves_and_slices_seed_rows():
    host = power_law_host(nv=300, deg=8, f=5)
    model = build_model("sage", 5, 2, hidden=8)
    params = model.init(jax.random.PRNGKey(0))
    eng = GnnServeEngine(cfg=CFG, slots=2)
    eng.register("m", model, params, task="node")
    eng.register_host_graph("hg", host, fanouts=(4, 3), rng_seed=5)
    rid = eng.submit_nodes("m", [10, 20, 10])  # duplicate seeds allowed
    eng.drain()
    out = eng.results[rid]
    assert out.shape[0] == 3
    np.testing.assert_array_equal(out[0], out[2])  # same seed, same row
    rec = eng.records[-1]
    assert rec.node_query and rec.num_seeds == 3
    assert rec.fanouts == "4x3"
    assert rec.sampled_nodes > 0


# ---------------------------------------------------------------------------
# Determinism -> cache hits; engine/report integration.
# ---------------------------------------------------------------------------


def test_identical_queries_share_one_partition_entry():
    host = power_law_host(nv=500, deg=6, f=5)
    model = build_model("gcn", 5, 2, hidden=8)
    params = model.init(jax.random.PRNGKey(0))
    eng = GnnServeEngine(cfg=CFG, slots=4)
    eng.register("m", model, params, task="node", prepare_fn=gcn_prepare)
    eng.register_host_graph("hg", host, fanouts=(5, 5), rng_seed=3)
    r1 = eng.submit_nodes("m", [42])
    r2 = eng.submit_nodes("m", [42])  # hot query node
    r3 = eng.submit_nodes("m", [43])  # different structure
    eng.drain()
    assert eng.cache.stats.hits == 1
    assert eng.cache.stats.misses == 2
    np.testing.assert_array_equal(eng.results[r1], eng.results[r2])
    report = eng.report(1.0)
    assert report.node_query_stats["queries"] == 3
    assert report.node_query_stats["seeds"] == 3
    assert report.node_query_stats["fanouts"] == {"5x5": 3}
    assert "node queries: 3" in report.pretty()


def test_same_local_structure_different_hosts_do_not_collide():
    """Two disjoint host regions can sample isomorphic local subgraphs;
    with GCN host-degree weights they must NOT share a partition entry."""
    # Two structurally identical stars living in different host blocks,
    # whose hub in-degrees differ (extra edges into the second hub from
    # elsewhere are not sampled at fanout-limited depth 1... keep it
    # simple: full fanout depth 1, hub degrees differ via extra leaves).
    src = np.array([1, 2, 41, 42, 43])
    dst = np.array([0, 0, 40, 40, 40])
    feat = np.zeros((60, 3), np.float32)
    host = HostGraph.from_edges(src, dst, feat)
    model = build_model("gcn", 3, 2, hidden=4)
    params = model.init(jax.random.PRNGKey(0))
    eng = GnnServeEngine(cfg=CFG, slots=2)
    eng.register("m", model, params, task="node", prepare_fn=gcn_prepare)
    eng.register_host_graph("hg", host, fanouts=(2,), rng_seed=0)
    eng.submit_nodes("m", [0])   # star around 0: 2-of-2 in-edges
    eng.submit_nodes("m", [40])  # star around 40: 2-of-3 in-edges sampled
    eng.drain()
    # Both samples are a 2-leaf star with identical local layout, but the
    # hubs' host degrees (2 vs 3) give different GCN weights.
    assert eng.cache.stats.misses == 2
    assert eng.cache.stats.hits == 0


def test_node_query_model_contract_errors():
    host = power_law_host(nv=50, f=5)
    gin = build_model("gin", 5, 2, hidden=4, mlp_layers=2)
    sage = build_model("sage", 5, 2, hidden=8)

    def custom_prepare(g):
        return g, None

    eng = GnnServeEngine(cfg=CFG, slots=1)
    eng.register("graph_task", gin, gin.init(jax.random.PRNGKey(0)),
                 task="graph")
    eng.register("no_sample_prep", sage, sage.init(jax.random.PRNGKey(1)),
                 task="node", prepare_fn=custom_prepare)
    eng.register_host_graph("hg", host)
    with pytest.raises(ValueError, match="node-task"):
        eng.try_submit_nodes("graph_task", [0])
    with pytest.raises(ValueError, match="sample_prepare_fn"):
        eng.try_submit_nodes("no_sample_prep", [0])
    with pytest.raises(ValueError, match="features"):
        eng2 = GnnServeEngine(cfg=CFG, slots=1)
        wide = build_model("sage", 9, 2, hidden=8)
        eng2.register("wide", wide, wide.init(jax.random.PRNGKey(2)),
                      task="node")
        eng2.register_host_graph("hg", host)
        eng2.try_submit_nodes("wide", [0])


# ---------------------------------------------------------------------------
# Acceptance: 10^5-node host graph, bit-exact node queries.
# ---------------------------------------------------------------------------


def test_large_host_graph_node_queries_bit_exact():
    """>=10^5-node synthetic HostGraph: submit_nodes output is bit-exact vs
    the full-graph forward at the seed rows (jnp backend).

    The host uses window-local edges (each vertex draws in-edges from a
    nearby id range) so the full-graph *reference* partition stays a
    near-band matrix — a few tiles per block-row — instead of the dense
    tile soup a uniform random graph would produce at this size.
    """
    nv = 100_000
    rng = np.random.default_rng(0)
    deg = 4
    dst = np.repeat(np.arange(nv, dtype=np.int64), deg)
    src = (dst + rng.integers(-40, 41, dst.size)) % nv
    feat = rng.standard_normal((nv, 4)).astype(np.float32)
    host = HostGraph.from_edges(src, dst, feat)
    assert host.num_nodes >= 100_000

    model = build_model("sage", 4, 2, hidden=8)
    params = model.init(jax.random.PRNGKey(0))
    eng = GnnServeEngine(cfg=CFG, slots=1)
    eng.register("m", model, params, task="node")
    eng.register_host_graph("hg", host, fanouts=(None, None))
    seeds = [12, 50_000, 99_999]
    rid = eng.submit_nodes("m", seeds)
    eng.drain()
    out = eng.results[rid]

    ref_eng = GnnServeEngine(cfg=CFG, slots=1)
    ref_eng.register("m", model, params, task="node")
    ref_rid = ref_eng.submit("m", full_graph_of(host))
    ref_eng.drain()
    ref = ref_eng.results[ref_rid][np.asarray(seeds)]
    np.testing.assert_array_equal(out, ref)
    # The whole point: the sampled request is orders of magnitude smaller
    # than the graph it answers against.
    assert eng.records[-1].sampled_nodes < nv // 50
