"""Partitioner correctness: block-CSR reconstructs the adjacency exactly and
the occupancy stats drive the zero-block skip accounting."""

import numpy as np
import pytest
from _hypothesis_compat import given, st

from repro.core import Graph, partition_graph


def random_graph(seed, nv=50, ne=200, f=4):
    rng = np.random.default_rng(seed)
    return Graph(
        edge_src=rng.integers(0, nv, ne).astype(np.int32),
        edge_dst=rng.integers(0, nv, ne).astype(np.int32),
        node_feat=rng.standard_normal((nv, f)).astype(np.float32),
    ).validate()


def dense_ref(g, w=None):
    a = np.zeros((g.num_nodes, g.num_nodes), np.float32)
    vals = w if w is not None else np.ones(g.num_edges, np.float32)
    np.add.at(a, (g.edge_dst, g.edge_src), vals)
    return a


@given(st.integers(0, 1000), st.integers(1, 13), st.integers(1, 13))
def test_reconstruction_matches_dense(seed, v, n):
    g = random_graph(seed)
    pg = partition_graph(g, v=v, n=n)
    got = pg.reconstruct_dense()[:g.num_nodes, :g.num_nodes]
    np.testing.assert_allclose(got, dense_ref(g), atol=1e-6)


def test_edge_weights_accumulate():
    g = random_graph(3)
    w = np.random.default_rng(0).random(g.num_edges).astype(np.float32)
    pg = partition_graph(g, v=8, n=8, edge_weights=w)
    got = pg.reconstruct_dense()[:g.num_nodes, :g.num_nodes]
    np.testing.assert_allclose(got, dense_ref(g, w), atol=1e-5)


def test_zero_blocks_are_skipped():
    # A bipartite-ish graph: only a quarter of the tile grid is occupied.
    nv = 64
    src = np.arange(0, 32, dtype=np.int32)
    dst = (src + 32).astype(np.int32)
    g = Graph(edge_src=src, edge_dst=dst,
              node_feat=np.zeros((nv, 2), np.float32)).validate()
    pg = partition_graph(g, v=8, n=8)
    assert pg.stats.nonzero_tiles < pg.stats.total_tiles
    assert pg.stats.skipped_fraction > 0.8
    # Only non-zero tiles are materialized.
    assert pg.blocks.shape[0] == pg.stats.nonzero_tiles


def test_row_ptr_is_csr_consistent():
    g = random_graph(7)
    pg = partition_graph(g, v=6, n=9)
    assert pg.row_ptr[0] == 0
    assert pg.row_ptr[-1] == pg.stats.nonzero_tiles
    # tiles sorted by row; row_ptr brackets each row's tile range
    for r in range(pg.num_dst_groups):
        rows = pg.block_row[pg.row_ptr[r]:pg.row_ptr[r + 1]]
        assert (rows == r).all()


def test_invalid_sizes_raise():
    g = random_graph(0)
    with pytest.raises(ValueError):
        partition_graph(g, v=0, n=4)
