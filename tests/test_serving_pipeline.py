"""Pipelined serve loop + service-time admission.

The load-bearing claims of the pipeline refactor:
  * the pipelined always-on loop (stacker thread + N executor workers,
    ``pipeline_depth``) is BIT-EXACT vs the serial loop (depth 0) for an
    identical request set, on all three backends — overlapped execution
    cannot change any answer because per-request outputs are
    batch-composition-independent, and group-ordered writeback keeps the
    record bookkeeping in extraction order;
  * exactly-once delivery survives a multi-thread submit storm against
    the pipelined loop;
  * a crash in one executor worker surfaces to every client as
    ``RuntimeError("serve loop failed")`` instead of hanging;
  * intake closes atomically inside ``stop()``: a submit racing the
    shutdown either gets served by the final drain or fails fast with
    RuntimeError — never a silently stranded rid;
  * the learned service-time EWMA drives admission: a request whose SLO
    is unmeetable even if scheduled immediately is rejected at enqueue
    (counted in ``AdmissionStats.unmeetable``), while cold keys are
    always admitted;
  * the report surfaces the model (``service_time_ms``) and the pipeline
    overlap gauges (``pipeline`` busy fractions).
"""

import threading
import time

import jax
import numpy as np
import pytest

from repro.core.graph import Graph
from repro.gnn import build_model
from repro.photonic.perf import GhostConfig
from repro.serving import GnnServeEngine

CFG = GhostConfig(v=8, n=8)


def make_graph(seed, nv, ne, f=5):
    rng = np.random.default_rng(seed)
    return Graph(
        edge_src=rng.integers(0, nv, ne).astype(np.int32),
        edge_dst=rng.integers(0, nv, ne).astype(np.int32),
        node_feat=rng.standard_normal((nv, f)).astype(np.float32),
    ).validate()


def build(f=5, seed=0):
    model = build_model("gcn", f, 2, hidden=4)
    return model, model.init(jax.random.PRNGKey(seed))


# ---------------------------------------------------------------------------
# Bit-exactness: pipelined vs serial loop.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend,depth", [
    ("jnp", 2), ("jnp", 4), ("pallas", 2), ("pallas_fused", 2),
])
def test_pipelined_loop_bit_exact_vs_serial(backend, depth):
    """Identical request set, identical per-request outputs and record
    order — however the stacker and the workers happened to interleave."""
    graphs = [make_graph(s, nv=12 + 4 * (s % 3), ne=30) for s in range(8)]
    model, params = build()

    def fresh(pipeline_depth):
        eng = GnnServeEngine(cfg=CFG, slots=4, backend=backend,
                             scheduler="deadline",
                             pipeline_depth=pipeline_depth)
        eng.register("a", model, params, slo_ms=60_000.0)
        eng.register("b", model, params)
        return eng

    serial = fresh(0).start()
    for i, g in enumerate(graphs):
        serial.submit("a" if i % 2 else "b", g)
    serial.stop(drain=True)

    piped = fresh(depth).start()
    rids = [piped.submit("a" if i % 2 else "b", g)
            for i, g in enumerate(graphs)]
    piped.stop(drain=True)

    assert rids == list(range(len(graphs)))
    for rid in rids:
        np.testing.assert_array_equal(piped.results[rid],
                                      serial.results[rid])


def test_group_ordered_writeback_preserves_record_order():
    """One (model, bucket) group, many batches in flight: workers may
    execute out of order but must publish in extraction order, so the
    record stream matches the serial loop's exactly."""
    graphs = [make_graph(7, nv=12, ne=24)] * 18  # one structure, one group
    model, params = build()
    eng = GnnServeEngine(cfg=CFG, slots=2, pipeline_depth=3)
    eng.register("m", model, params)
    eng.start()
    rids = [eng.submit("m", g) for g in graphs]
    eng.stop(drain=True)
    assert sorted(rids) == rids
    # Single group + FIFO within it: records in rid order iff writeback
    # respected the extraction tickets.
    assert [r.rid for r in eng.records] == rids


# ---------------------------------------------------------------------------
# Concurrency: submit storm, worker crash, stop/submit race.
# ---------------------------------------------------------------------------


def test_pipelined_exactly_once_under_submit_storm():
    n_threads, per_thread = 6, 8
    graphs = [make_graph(s, nv=10 + 4 * s, ne=25) for s in range(3)]
    model, params = build()
    eng = GnnServeEngine(cfg=CFG, slots=4, scheduler="deadline",
                         pipeline_depth=2)
    eng.register("m", model, params, slo_ms=60_000.0)
    eng.start()

    rid_lists = [[] for _ in range(n_threads)]
    errors = []

    def client(t):
        try:
            for j in range(per_thread):
                rid_lists[t].append(
                    eng.submit("m", graphs[(t + j) % len(graphs)]))
        except BaseException as e:  # surfaced below, not swallowed
            errors.append(e)

    threads = [threading.Thread(target=client, args=(t,))
               for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    eng.stop(drain=True)

    assert not errors
    all_rids = [rid for rids in rid_lists for rid in rids]
    total = n_threads * per_thread
    assert len(all_rids) == total
    assert len(set(all_rids)) == total
    for rid in all_rids:
        out = eng.take_result(rid)
        assert out.shape[1] == 2
        with pytest.raises(KeyError):
            eng.take_result(rid)
    assert sorted(r.rid for r in eng.records) == sorted(all_rids)


def test_executor_worker_crash_surfaces_to_clients():
    g = make_graph(2, nv=12, ne=20)
    model, params = build()
    eng = GnnServeEngine(cfg=CFG, slots=2, pipeline_depth=2)
    eng.register("m", model, params)

    def boom(*a, **kw):
        raise RuntimeError("executor exploded")

    # pool.executor runs in the executor workers (stage 2), so this
    # crashes a worker, not the stacker — the failure still has to reach
    # every waiter and the join in stop().
    eng.pool.executor = boom
    eng.start()
    rid = eng.submit("m", g)
    with pytest.raises(RuntimeError, match="serve loop failed"):
        eng.result(rid, timeout=30.0)
    with pytest.raises(RuntimeError, match="serve loop failed"):
        eng.stop()


def test_submit_after_stop_fails_fast_and_start_reopens():
    g = make_graph(3, nv=12, ne=20)
    model, params = build()
    eng = GnnServeEngine(cfg=CFG, slots=2, pipeline_depth=2)
    eng.register("m", model, params)
    eng.start()
    rid = eng.submit("m", g)
    eng.stop(drain=True)
    assert rid in eng.results
    with pytest.raises(RuntimeError, match="intake is closed"):
        eng.try_submit("m", g)
    with pytest.raises(RuntimeError, match="intake is closed"):
        eng.submit("m", g)
    # start() reopens intake.
    eng.start()
    rid2 = eng.submit("m", g)
    eng.stop(drain=True)
    np.testing.assert_array_equal(eng.results[rid2], eng.results[rid])


def test_stop_racing_submitters_strands_nothing():
    """Clients hammer try_submit while the engine stops: every rid a
    client actually received must be served by the final drain (intake
    closed atomically before it), and late submitters see RuntimeError —
    no rid is silently lost."""
    graphs = [make_graph(s, nv=12, ne=24) for s in range(2)]
    model, params = build()
    eng = GnnServeEngine(cfg=CFG, slots=4, pipeline_depth=2)
    eng.register("m", model, params)
    eng.start()

    got, refused, bad = [], [], []
    lock = threading.Lock()
    stop_now = threading.Event()

    def client(t):
        i = 0
        while not stop_now.is_set():
            try:
                rid = eng.try_submit("m", graphs[(t + i) % 2])
                with lock:
                    got.append(rid)
            except RuntimeError as e:
                if "intake is closed" not in str(e):
                    with lock:
                        bad.append(e)
                return
            except BaseException as e:  # pragma: no cover - surfaced below
                with lock:
                    bad.append(e)
                return
            i += 1

    threads = [threading.Thread(target=client, args=(t,)) for t in range(4)]
    for t in threads:
        t.start()
    time.sleep(0.3)  # let traffic build
    stop_now_called_at = len(got)
    eng.stop(drain=True)  # closes intake atomically, then drains
    stop_now.set()
    for t in threads:
        t.join()

    assert not bad
    assert stop_now_called_at > 0  # the race was actually exercised
    # Every admitted rid was served; nothing was stranded un-served.
    for rid in got:
        assert rid is not None and rid in eng.results
    assert eng.num_waiting == 0
    # Submitters kept refused-at-close out of `got` via the RuntimeError
    # path; the refused list is allowed to be empty if timing was kind.
    assert len(eng.results) == len(got)


def test_pipeline_depth_validation_and_modes():
    model, params = build()
    with pytest.raises(ValueError, match="pipeline_depth"):
        GnnServeEngine(cfg=CFG, slots=2, pipeline_depth=-1)
    eng = GnnServeEngine(cfg=CFG, slots=2)
    assert eng.pipeline_depth == 2  # pipelined by default
    # Depth 0 = serial loop: still serves end to end.
    g = make_graph(4, nv=12, ne=20)
    serial = GnnServeEngine(cfg=CFG, slots=2, pipeline_depth=0)
    serial.register("m", model, params)
    serial.start()
    rid = serial.submit("m", g)
    out = serial.result(rid, timeout=60.0)
    serial.stop()
    assert out.shape[0] == g.num_nodes
    assert serial.pipeline_stats()["depth"] == 0


# ---------------------------------------------------------------------------
# Service-time model: admission, queue pressure, report surface.
# ---------------------------------------------------------------------------


def _warm_service_model(eng, model_id, g, times=2):
    """Tick-serve a few singles so the (model, bucket) key gets an EWMA
    (the first execution is compile-tainted and only warms the key)."""
    for _ in range(times):
        eng.submit(model_id, g)
        eng.drain()


def test_service_time_admission_rejects_unmeetable_slo():
    g = make_graph(5, nv=12, ne=24)
    model, params = build()
    eng = GnnServeEngine(cfg=CFG, slots=2)
    # 0.05 ms is unmeetable on any host; but with no learned estimate the
    # engine must admit (and serve, and record the miss).
    eng.register("tight", model, params, slo_ms=0.05)
    eng.register("free", model, params)

    assert eng.service_time_ms() == {}
    _warm_service_model(eng, "tight", g)     # 1st warms, 2nd feeds the EWMA
    assert eng.service_time_ms()             # model is learned now

    rid = eng.try_submit("tight", g)         # unmeetable at enqueue
    assert rid is None
    stats = eng.admission.stats
    assert stats.unmeetable == 1
    assert stats.rejected == 1
    # A model with no warm bucket (and no SLO) is untouched.
    assert eng.try_submit("free", g) is not None
    eng.drain()

    rep = eng.report(1.0)
    assert rep.unmeetable == 1
    assert rep.service_time_ms
    assert all(v > 0 for v in rep.service_time_ms.values())
    assert "SLO-unmeetable" in rep.pretty()
    assert "expected service (EWMA)" in rep.pretty()


def test_service_time_admission_can_be_disabled():
    g = make_graph(5, nv=12, ne=24)
    model, params = build()
    eng = GnnServeEngine(cfg=CFG, slots=2, service_time_admission=False)
    eng.register("tight", model, params, slo_ms=0.05)
    _warm_service_model(eng, "tight", g)
    rid = eng.try_submit("tight", g)         # served-late is allowed again
    assert rid is not None
    eng.drain()
    assert eng.admission.stats.unmeetable == 0
    assert rid in eng.results


def test_queue_pressure_tracks_time_backlog():
    g = make_graph(6, nv=12, ne=24)
    model, params = build()
    eng = GnnServeEngine(cfg=CFG, slots=2)
    eng.register("m", model, params)
    assert eng.queue_pressure() == (0.0, 0)
    _warm_service_model(eng, "m", g)
    for _ in range(3):
        eng.submit("m", g)
    backlog, waiting = eng.queue_pressure()
    assert waiting == 3
    assert backlog > 0.0     # ceil(3/2) batches x learned service time
    eng.drain()
    assert eng.queue_pressure()[1] == 0


def test_report_surfaces_pipeline_overlap_stats():
    graphs = [make_graph(s, nv=12, ne=24) for s in range(6)]
    model, params = build()
    eng = GnnServeEngine(cfg=CFG, slots=2, pipeline_depth=2)
    eng.register("m", model, params)
    eng.start()
    t0 = time.perf_counter()
    for g in graphs:
        eng.submit("m", g)
    eng.stop(drain=True)
    rep = eng.report(time.perf_counter() - t0)
    assert rep.pipeline["depth"] == 2
    assert rep.pipeline["exec_busy_s"] > 0
    assert rep.pipeline["stack_busy_s"] > 0
    assert "exec_busy_frac" in rep.pipeline
    assert "pipeline depth 2" in rep.pretty()
    # The EWMAs survive reset_metrics (a learned model, not a metric)...
    eng.reset_metrics()
    assert eng.service_time_ms()
    # ...but the busy gauges do not.
    assert eng.pipeline_stats()["exec_busy_s"] == 0.0
