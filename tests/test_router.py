"""Replica router (serving.router.EngineRouter).

The load-bearing claims:
  * catalog-aware placement — hot models land on every replica, cold
    models pin to exactly one (least-loaded, or an explicit pin);
  * load-aware routing with per-replica admission fallback: a rejection on
    the shortest queue fails over to the next eligible replica before
    surfacing;
  * global rids round-trip through ``take_result`` to the owning replica;
  * the merged report equals what one engine would say about the union
    stream, plus per-replica served counts.

No devices beyond the default are needed — replicas are plain engines.
"""

import jax
import numpy as np
import pytest

from repro.core import Graph
from repro.gnn import build_model
from repro.serving import EngineRouter, GnnServeEngine, QueueFullError


def make_graph(seed, nv=30, ne=100, f=8):
    rng = np.random.default_rng(seed)
    return Graph(
        edge_src=rng.integers(0, nv, ne).astype(np.int32),
        edge_dst=rng.integers(0, nv, ne).astype(np.int32),
        node_feat=rng.standard_normal((nv, f)).astype(np.float32),
    ).validate()


def make_model(classes=3, seed=0):
    model = build_model("gcn", 8, classes, hidden=8)
    return model, model.init(jax.random.PRNGKey(seed))


# ---------------------------------------------------------------------------
# Placement.
# ---------------------------------------------------------------------------


def test_hot_model_registers_everywhere():
    model, params = make_model()
    router = EngineRouter(3, slots=2)
    assert router.register("m", model, params, hot=True) == (0, 1, 2)
    for e in router.replicas:
        assert "m" in e.registry


def test_cold_models_balance_across_replicas():
    model, params = make_model()
    router = EngineRouter(2, slots=2)
    homes = [router.register(f"m{i}", model, params) for i in range(4)]
    assert all(len(h) == 1 for h in homes)
    # Least-loaded placement alternates 0,1,0,1.
    assert [h[0] for h in homes] == [0, 1, 0, 1]
    assert router.placement("m2") == (0,)


def test_explicit_pin_and_errors():
    model, params = make_model()
    router = EngineRouter(2, slots=2)
    assert router.register("m", model, params, replica=1) == (1,)
    with pytest.raises(ValueError, match="already placed"):
        router.register("m", model, params)
    with pytest.raises(ValueError, match="replica"):
        router.register("m2", model, params, hot=True, replica=0)
    with pytest.raises(ValueError, match="out of range"):
        router.register("m3", model, params, replica=5)
    with pytest.raises(KeyError, match="unknown model_id"):
        router.placement("nope")
    with pytest.raises(ValueError, match="num_replicas"):
        EngineRouter(0)


# ---------------------------------------------------------------------------
# Routing + admission fallback.
# ---------------------------------------------------------------------------


def test_routes_to_shortest_queue():
    model, params = make_model()
    router = EngineRouter(2, slots=2)
    router.register("m", model, params, hot=True)
    g = make_graph(0)
    router.submit("m", g)
    router.submit("m", g)
    # Without serving, two submissions must land on different replicas.
    assert [e.num_waiting for e in router.replicas] == [1, 1]


def test_routes_by_estimated_slack_not_raw_queue_length():
    """Two replicas with equal-length queues are NOT equally loaded when
    their learned service times differ: routing must prefer the smaller
    time backlog (queued batches x expected service), falling back to raw
    queue length only on ties (the cold-start behavior above)."""
    model, params = make_model()
    router = EngineRouter(2, slots=4)
    router.register("m", model, params, hot=True)
    g = make_graph(3)
    router.submit("m", g)   # cold: ties -> replica 0
    router.submit("m", g)   # cold: queue tie-break -> replica 1
    r0, r1 = router.replicas
    assert [r0.num_waiting, r1.num_waiting] == [1, 1]
    # Inject asymmetric learned service times for the one waiting group
    # (the EWMA the engines would learn from serving: replica 0 fast,
    # replica 1 slow — e.g. different device health or catalog pressure).
    (key,) = r0._groups
    r0._service_ewma[key] = 0.001   # 1 ms batches
    r1._service_ewma[key] = 0.100   # 100 ms batches
    # Every new request now lands on replica 0 — its queue grows LONGER
    # than replica 1's, yet its estimated backlog time stays smaller.
    for _ in range(3):
        router.submit("m", g)
    assert r0.num_waiting == 4      # 1 batch x 1 ms  << 1 batch x 100 ms
    assert r1.num_waiting == 1
    backlog0, _ = r0.queue_pressure()
    backlog1, _ = r1.queue_pressure()
    assert backlog0 < backlog1
    assert router.drain() == 5
    # The merged report surfaces the per-replica models it routed by.
    rep = router.report(1.0)
    assert rep.service_time_ms      # cross-replica mean per key
    assert rep.replicas["replica0"]["service_time_ms"]
    assert rep.replicas["replica1"]["service_time_ms"]


def test_admission_fallback_across_replicas():
    model, params = make_model()
    router = EngineRouter(2, slots=2, max_waiting=1,
                          admission_policy="reject")
    router.register("m", model, params, hot=True)
    g = make_graph(1)
    assert router.try_submit("m", g) is not None   # shortest queue: A
    assert router.try_submit("m", g) is not None   # A full -> lands on B
    assert router.try_submit("m", g) is None       # both full: tried A AND B
    with pytest.raises(QueueFullError):
        router.submit("m", g)
    # The failed attempts rejected on every eligible replica (fallback ran).
    total_rejected = sum(e.admission.stats.rejected
                        for e in router.replicas)
    assert total_rejected >= 2
    assert router.drain() == 2


def test_cold_traffic_stays_on_pinned_replica():
    model, params = make_model()
    router = EngineRouter(2, slots=2, max_waiting=1,
                          admission_policy="reject")
    home = router.register("cold", model, params)[0]
    g = make_graph(2)
    assert router.try_submit("cold", g) is not None
    # The pinned replica is full and there is no fallback target.
    assert router.try_submit("cold", g) is None
    router.drain()
    other = router.replicas[1 - home]
    assert not other.records


# ---------------------------------------------------------------------------
# Results + merged report.
# ---------------------------------------------------------------------------


def test_results_round_trip_matches_single_engine():
    model, params = make_model()
    graphs = [make_graph(s) for s in range(6)]

    router = EngineRouter(2, slots=2)
    router.register("m", model, params, hot=True)
    rids = [router.submit("m", g) for g in graphs]
    router.drain()

    single = GnnServeEngine(slots=2)
    single.register("m", model, params)
    srids = [single.submit("m", g) for g in graphs]
    single.drain()

    for rid, srid in zip(rids, srids):
        np.testing.assert_array_equal(router.take_result(rid),
                                      single.take_result(srid))
    with pytest.raises(KeyError):
        router.take_result(rids[0])  # already taken


def test_merged_report():
    hot_model, hot_params = make_model(3, seed=0)
    cold_model, cold_params = make_model(2, seed=1)
    router = EngineRouter(2, slots=2)
    router.register("hot", hot_model, hot_params, hot=True)
    cold_home = router.register("cold", cold_model, cold_params)[0]

    stream = ([("hot", make_graph(100 + i)) for i in range(6)]
              + [("cold", make_graph(200 + i)) for i in range(3)])
    rep = router.run(stream)

    assert rep.requests == 9
    assert rep.per_model == {"hot": 6, "cold": 3}
    assert rep.admitted == 9 and rep.rejected == 0
    assert set(rep.replicas) == {"replica0", "replica1"}
    assert sum(info["served"] for info in rep.replicas.values()) == 9
    # Cold traffic shows up only under its pinned replica.
    for name, info in rep.replicas.items():
        if name != f"replica{cold_home}":
            assert "cold" not in info["per_model"]
    assert rep.traces_compiled == sum(
        info["traces_compiled"] for info in rep.replicas.values())
    assert "replicas:" in rep.pretty()


def test_merged_report_unions_replica_views():
    """Regression: the merged report must not take replica 0's kernel
    configs / topology as the whole story — replicas with distinct meshes
    or overrides keep their contributions in the union."""
    from types import SimpleNamespace

    from repro.launch.mesh import make_data_mesh

    model, params = make_model()
    # Distinct meshes per replica: replica0 meshless, replica1 on a 1-device
    # data mesh (no extra host devices needed).
    router = EngineRouter(2, slots=2, meshes=[None, make_data_mesh(1)])
    router.register("m", model, params, hot=True)
    # Distinct per-replica kernel-config views (a per-replica override).
    cfg0 = SimpleNamespace(fused=True, tile=8)
    cfg1 = SimpleNamespace(fused=False, tile=4)
    router.replicas[0].pool.kernel_config = cfg0
    router.replicas[1].pool.kernel_config = cfg1
    rep = router.run([("m", make_graph(300 + i)) for i in range(4)])

    # Replica 1's mesh is not dropped: the merged topology aggregates.
    assert rep.topology["num_devices"] == 2
    assert rep.topology["heterogeneous"] is True
    assert rep.topology["mesh_shapes"]["replica1"] == {"data": 1}
    # Conflicting "*" overrides both survive, replica detail preserved.
    assert rep.kernel_configs["*"] == vars(cfg0)
    assert rep.kernel_configs["replica1:*"] == vars(cfg1)
    assert rep.replicas["replica0"]["kernel_configs"]["*"] == vars(cfg0)
    assert rep.replicas["replica1"]["kernel_configs"]["*"] == vars(cfg1)
    assert rep.replicas["replica1"]["topology"]["num_devices"] == 1
    # Uniform replicas still report the shared view unchanged.
    router2 = EngineRouter(2, slots=2)
    router2.register("m", model, params, hot=True)
    rep2 = router2.run([("m", make_graph(400))])
    assert rep2.topology == {}
    assert rep2.kernel_configs == {}


# ---------------------------------------------------------------------------
# Node-query routing.
# ---------------------------------------------------------------------------


def test_node_queries_route_to_host_graph_holders():
    from repro.serving import HostGraph

    host = HostGraph.synthetic_power_law(300, avg_degree=5, num_features=8,
                                         seed=0)
    model = build_model("sage", 8, 2, hidden=8)
    params = model.init(jax.random.PRNGKey(0))
    router = EngineRouter(2, slots=2)
    router.register("m", model, params, hot=True)
    # Host graph pinned to replica 1: queries must land there even though
    # the model is hot everywhere.
    assert router.register_host_graph("hg", host, replicas=[1],
                                      fanouts=(4, 4)) == (1,)
    rids = [router.submit_nodes("m", [i]) for i in range(4)]
    router.drain()
    assert len(router.replicas[1].records) == 4
    assert not router.replicas[0].records
    for rid in rids:
        assert router.take_result(rid).shape == (1, 2)
    # Placement bookkeeping + error paths.
    assert router.host_placement("hg") == (1,)
    with pytest.raises(ValueError, match="already placed"):
        router.register_host_graph("hg", host)
    with pytest.raises(KeyError, match="unknown host graph"):
        router.host_placement("nope")
    with pytest.raises(ValueError, match="out of range"):
        router.register_host_graph("hg2", host, replicas=[5])


def test_node_queries_balance_and_intersect_placement():
    from repro.serving import HostGraph

    host = HostGraph.synthetic_power_law(200, avg_degree=4, num_features=8,
                                         seed=1)
    model = build_model("sage", 8, 2, hidden=8)
    params = model.init(jax.random.PRNGKey(0))
    router = EngineRouter(2, slots=2)
    cold_home = router.register("cold", model, params)[0]
    router.register_host_graph("hg", host)  # every replica holds it
    # Eligible = model placement ∩ host placement = the cold pin.
    router.submit_nodes("cold", [3])
    router.submit_nodes("cold", [4])
    assert router.replicas[cold_home].num_waiting == 2
    assert router.replicas[1 - cold_home].num_waiting == 0
    router.drain()
    # Empty intersection raises rather than silently serving elsewhere.
    model2 = build_model("sage", 8, 2, hidden=8)
    router2 = EngineRouter(2, slots=2)
    router2.register("m", model2, model2.init(jax.random.PRNGKey(1)),
                     replica=0)
    router2.register_host_graph("hg", host, replicas=[1])
    with pytest.raises(ValueError, match="no replica holds both"):
        router2.try_submit_nodes("m", [0])


def test_router_bare_graph_single_model():
    model, params = make_model()
    router = EngineRouter(2, slots=2)
    router.register("m", model, params, hot=True)
    rep = router.run([make_graph(7)])
    assert rep.requests == 1


def test_meshes_length_validation():
    with pytest.raises(ValueError, match="meshes"):
        EngineRouter(2, meshes=[None])
    with pytest.raises(ValueError, match="not both"):
        EngineRouter(1, meshes=[None], mesh=None)
