"""Bucketed continuous-batching serving engine (repro.serving).

The load-bearing claims:
  * per-request engine outputs == the unbatched blocked forward, exactly
    (fp32 value-for-value), on both aggregation backends;
  * the preprocessing cache actually deduplicates partitioning work;
  * shape bucketing (including the feature dim) bounds the jit trace count;
  * bucket padding (zero tiles, padded groups, zero feature columns) is
    numerically inert;
  * hardware accounting survives cache eviction between submit and serve.

Multi-model catalogs, schedulers, and admission control are covered in
tests/test_serving_multimodel.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    Graph,
    ReduceOp,
    aggregate_backend,
    aggregate_blocked,
    partition_graph,
    to_blocked,
)
from repro.core.aggregate import BlockedGraph
from repro.gnn import build_model
from repro.photonic.perf import GhostConfig, GnnModelSpec
from repro.serving import (
    GnnServeEngine,
    PreprocessCache,
    bucket_for,
    gcn_prepare,
    graph_content_hash,
    next_pow2,
    pad_features_to_bucket,
    pad_partition_to_bucket,
)


def make_graph(seed, nv=None, ne=None, f=7, labeled=False):
    rng = np.random.default_rng(seed)
    nv = nv or int(rng.integers(6, 70))
    ne = ne or int(rng.integers(1, 200))
    g = Graph(
        edge_src=rng.integers(0, nv, ne).astype(np.int32),
        edge_dst=rng.integers(0, nv, ne).astype(np.int32),
        node_feat=rng.standard_normal((nv, f)).astype(np.float32),
    ).validate()
    if labeled:
        g.graph_label = int(rng.integers(0, 2))
    return g


def single_model_engine(model, params, **kw):
    """One-model engine: the common fixture shape in this file."""
    reg = {k: kw.pop(k) for k in ("task", "spec", "prepare_fn", "quantized")
           if k in kw}
    eng = GnnServeEngine(**kw)
    eng.register("m", model, params, **reg)
    return eng


# ---------------------------------------------------------------------------
# Bucketing primitives.
# ---------------------------------------------------------------------------


def test_next_pow2():
    assert [next_pow2(x) for x in (0, 1, 2, 3, 4, 5, 17, 64)] == \
        [1, 1, 2, 4, 4, 8, 32, 64]


@pytest.mark.parametrize("reduce", [ReduceOp.SUM, ReduceOp.MEAN, ReduceOp.MAX])
def test_bucket_padding_is_numerically_inert(reduce):
    """Aggregation over bucket-padded tiles == unpadded, on real rows."""
    g = make_graph(3, nv=45, ne=160)
    pg = partition_graph(g, v=8, n=8)
    bucket = bucket_for(pg, g.node_feat.shape[1])
    assert bucket.f == 8  # 7 features round up to the pow2 bucket
    blocks, row, col = pad_partition_to_bucket(pg, bucket)
    assert blocks.shape[0] == bucket.num_blocks
    assert (np.diff(row) >= 0).all()  # CSR sortedness preserved

    featp = jnp.asarray(pg.pad_features(g.node_feat))
    featb = jnp.asarray(pad_features_to_bucket(pg, bucket, g.node_feat))
    assert featb.shape == (bucket.padded_src, bucket.f)
    ref = aggregate_blocked(to_blocked(pg), featp, reduce)
    bg = BlockedGraph(
        blocks=jnp.asarray(blocks), block_row=jnp.asarray(row),
        block_col=jnp.asarray(col),
        num_dst_groups=bucket.num_dst_groups,
        num_src_groups=bucket.num_src_groups,
        v=pg.v, n=pg.n, num_nodes=g.num_nodes)
    got = aggregate_blocked(bg, featb, reduce)
    # Aggregation is columnwise: the zero padding columns stay zero and the
    # real columns match the unpadded forward exactly.
    np.testing.assert_array_equal(
        np.asarray(got)[: g.num_nodes, : g.node_feat.shape[1]],
        np.asarray(ref)[: g.num_nodes])
    np.testing.assert_array_equal(
        np.asarray(got)[: g.num_nodes, g.node_feat.shape[1]:], 0.0)


def test_feature_dim_bucketing_shares_host_shapes():
    """Different feature widths below one pow2 land in one bucket shape."""
    g6 = make_graph(4, nv=30, ne=80, f=6)
    g7 = make_graph(4, nv=30, ne=80, f=7)
    pg = partition_graph(g6, v=8, n=8)
    b6, b7 = bucket_for(pg, 6), bucket_for(pg, 7)
    assert b6 == b7 and b6.f == 8
    assert pad_features_to_bucket(pg, b6, g6.node_feat).shape == \
        pad_features_to_bucket(pg, b7, g7.node_feat).shape


# ---------------------------------------------------------------------------
# Preprocess cache.
# ---------------------------------------------------------------------------


def test_pad_features_preserves_dtype():
    """The padded buffer keeps the request dtype (f64 must not silently
    downcast before it reaches the executor); non-float dtypes raise."""
    g = make_graph(8, nv=20, ne=40)
    pg = partition_graph(g, v=8, n=8)
    bucket = bucket_for(pg, 7)
    f32 = pad_features_to_bucket(pg, bucket, g.node_feat)
    assert f32.dtype == np.float32
    feat64 = g.node_feat.astype(np.float64) + 1e-12
    f64 = pad_features_to_bucket(pg, bucket, feat64)
    assert f64.dtype == np.float64
    np.testing.assert_array_equal(f64[: g.num_nodes, :7], feat64)
    f16 = pad_features_to_bucket(pg, bucket, g.node_feat.astype(np.float16))
    assert f16.dtype == np.float16
    with pytest.raises(TypeError):
        pad_features_to_bucket(pg, bucket, g.node_feat.astype(np.int32))


def test_content_hash_weight_dtype_is_significant():
    """f64 weight vectors differing only beyond f32 precision must get
    distinct cache keys (downcast-before-hash collided them)."""
    g = make_graph(9, nv=16, ne=30)
    w = np.random.default_rng(0).uniform(0.1, 1.0, g.num_edges)
    w_eps = w + 1e-12
    assert not np.array_equal(w, w_eps)
    assert (w.astype(np.float32) == w_eps.astype(np.float32)).all()
    assert graph_content_hash(g, 4, 4, edge_weights=w) != \
        graph_content_hash(g, 4, 4, edge_weights=w_eps)
    # Same values at different dtypes are different partitioner inputs too.
    assert graph_content_hash(g, 4, 4, edge_weights=w) != \
        graph_content_hash(g, 4, 4, edge_weights=w.astype(np.float32))
    # Equal f32 inputs still collapse onto one key (the memoization point).
    assert graph_content_hash(g, 4, 4, edge_weights=w.astype(np.float32)) == \
        graph_content_hash(g, 4, 4,
                           edge_weights=w.astype(np.float32).copy())
    # The extra-bytes channel (sampled-serving host ids) keys too.
    assert graph_content_hash(g, 4, 4) != \
        graph_content_hash(g, 4, 4, extra=b"hosts")


def test_cache_peek_touches_recency_without_stats():
    cache = PreprocessCache(capacity=2)
    g1, g2, g3 = (make_graph(40 + s, nv=12, ne=20) for s in range(3))
    e1, _ = cache.get_or_partition(g1, 4, 4)
    cache.get_or_partition(g2, 4, 4)
    before = (cache.stats.hits, cache.stats.misses)
    assert cache.peek(e1.key) is e1          # touch=True refreshes recency
    assert cache.peek("missing") is None
    assert (cache.stats.hits, cache.stats.misses) == before  # stats pure
    cache.get_or_partition(g3, 4, 4)         # evicts g2, not the peeked g1
    _, hit = cache.get_or_partition(g1, 4, 4)
    assert hit
    # touch=False observes without promoting.
    cache2 = PreprocessCache(capacity=2)
    e1, _ = cache2.get_or_partition(g1, 4, 4)
    cache2.get_or_partition(g2, 4, 4)
    assert cache2.peek(e1.key, touch=False) is e1
    cache2.get_or_partition(g3, 4, 4)        # evicts g1: peek didn't touch
    _, hit = cache2.get_or_partition(g1, 4, 4)
    assert not hit


def test_serving_touches_lru_no_resubmit_needed():
    """Eviction-order regression: a structure that is *served* (hardware-
    costed) stays hot in the LRU even when it is never resubmitted."""
    model = build_model("gcn", 7, 2, hidden=4)
    params = model.init(jax.random.PRNGKey(0))
    eng = single_model_engine(model, params, task="node",
                              cfg=GhostConfig(v=8, n=8), slots=2,
                              cache_capacity=2,
                              spec=GnnModelSpec.gcn(7, 4, 2))
    a = make_graph(50, nv=12, ne=20)
    b = make_graph(51, nv=60, ne=160)   # different bucket -> its own group
    c = make_graph(52, nv=30, ne=70)
    eng.submit("m", a)
    eng.submit("m", b)
    served = eng.step()  # FIFO serves a's group only; hw-costing touches a
    assert served == 1
    eng.submit("m", c)   # capacity 2: must evict b (LRU), not the served a
    _, hit = eng.cache.get_or_partition(a, 8, 8)
    assert hit, "serving must refresh LRU recency for the served structure"
    eng.drain()


def test_content_hash_keys_structure_not_features():
    g1 = make_graph(0, nv=20, ne=40)
    g2 = Graph(edge_src=g1.edge_src.copy(), edge_dst=g1.edge_dst.copy(),
               node_feat=np.zeros_like(g1.node_feat)).validate()
    assert graph_content_hash(g1, 4, 4) == graph_content_hash(g2, 4, 4)
    assert graph_content_hash(g1, 4, 4) != graph_content_hash(g1, 8, 4)
    assert graph_content_hash(g1, 4, 4) != graph_content_hash(g1, 4, 4,
                                                              salt="gcn")


def test_cache_hits_and_lru_eviction():
    cache = PreprocessCache(capacity=2)
    g1, g2, g3 = (make_graph(s, nv=12, ne=20) for s in range(3))
    _, hit = cache.get_or_partition(g1, 4, 4)
    assert not hit
    _, hit = cache.get_or_partition(g1, 4, 4)
    assert hit
    cache.get_or_partition(g2, 4, 4)
    cache.get_or_partition(g3, 4, 4)      # evicts g1 (LRU)
    assert cache.stats.evictions == 1
    _, hit = cache.get_or_partition(g1, 4, 4)
    assert not hit
    assert len(cache) == 2


def test_cache_transform_runs_once():
    calls = []

    def prep(g):
        calls.append(1)
        return gcn_prepare(g)

    cache = PreprocessCache(capacity=8)
    g = make_graph(1, nv=15, ne=30)
    e1, _ = cache.get_or_partition(g, 4, 4, transform=prep, salt="gcn")
    e2, hit = cache.get_or_partition(g, 4, 4, transform=prep, salt="gcn")
    assert hit and e1 is e2 and len(calls) == 1
    # The entry's pg reflects the transformed (self-loop) structure.
    assert e1.pg.stats.num_edges > g.num_edges


# ---------------------------------------------------------------------------
# Engine end-to-end.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["jnp", "pallas", "pallas_fused"])
def test_engine_matches_unbatched_blocked_forward_exactly(backend):
    graphs = [make_graph(s) for s in range(6)]
    graphs += graphs[:3]  # repeats -> cache hits
    model = build_model("gcn", 7, 3, hidden=8)
    params = model.init(jax.random.PRNGKey(0))
    cfg = GhostConfig(v=8, n=8)
    eng = single_model_engine(model, params, task="node", cfg=cfg, slots=4,
                              backend=backend, prepare_fn=gcn_prepare,
                              spec=GnnModelSpec.gcn(7, 8, 3))
    rep = eng.run(graphs)

    assert rep.requests == len(graphs)
    assert rep.cache_hit_rate > 0
    assert rep.hw_latency_s > 0 and rep.hw_energy_j > 0
    for i, g in enumerate(graphs):
        g2, w = gcn_prepare(g)
        pg = partition_graph(g2, v=8, n=8, edge_weights=w)
        featp = jnp.asarray(pg.pad_features(g.node_feat))
        with aggregate_backend(backend):
            ref = np.asarray(model.apply_blocked(params, to_blocked(pg),
                                                 featp))[: g.num_nodes]
        np.testing.assert_array_equal(eng.results[i], ref)


@pytest.mark.parametrize("backend", ["jnp", "pallas", "pallas_fused"])
def test_engine_graph_task_gin_exact(backend):
    graphs = [make_graph(s, f=6, labeled=True) for s in range(5)]
    model = build_model("gin", 6, 2, hidden=8, mlp_layers=2)
    params = model.init(jax.random.PRNGKey(1))
    cfg = GhostConfig(v=5, n=7)  # v != n exercises asymmetric padding
    eng = single_model_engine(model, params, task="graph", cfg=cfg, slots=3,
                              backend=backend)
    eng.run(graphs)
    for i, g in enumerate(graphs):
        pg = partition_graph(g, v=5, n=7)
        featp = jnp.asarray(pg.pad_features(g.node_feat))
        bgs = to_blocked(pg)
        # The reference is the *jitted* unbatched blocked forward — the
        # engine's documented exactness contract (eager execution may
        # differ from any jitted run by a ULP, which GIN's sum-pool
        # readout amplifies to visible magnitude).
        with aggregate_backend(backend):
            ref = np.asarray(jax.jit(
                lambda p, f: model.apply_blocked(p, bgs, f))(params, featp))
        if backend == "pallas_fused":
            # pallas_fused distributes GIN's first MLP layer over the
            # (self, aggregate) sum; XLA associates those adds differently
            # in the batched and unbatched programs, and the sum-pool
            # readout amplifies the per-node ULPs — so the graph-task
            # contract on this backend is few-ULP relative, not bitwise.
            np.testing.assert_allclose(eng.results[i], ref, rtol=1e-6)
        else:
            np.testing.assert_array_equal(eng.results[i], ref)


def test_engine_trace_count_is_bounded_by_buckets():
    """Many distinct graphs, few shape buckets -> few traces."""
    rng = np.random.default_rng(7)
    graphs = [make_graph(int(rng.integers(0, 2**31)), nv=int(rng.integers(30, 64)),
                         ne=int(rng.integers(40, 200)))
              for _ in range(20)]
    model = build_model("gcn", 7, 3, hidden=8)
    params = model.init(jax.random.PRNGKey(0))
    eng = single_model_engine(model, params, task="node",
                              cfg=GhostConfig(v=8, n=8), slots=4)
    rep = eng.run(graphs)
    assert rep.traces_compiled == len(rep.buckets)
    assert rep.traces_compiled < len(graphs)
    assert sum(rep.buckets.values()) == len(graphs)


def test_engine_batches_share_buckets():
    """Identical-shape requests ride the same executor call (batch > 1)."""
    g = make_graph(11, nv=24, ne=50)
    graphs = [g] * 6
    model = build_model("sage", 7, 2, hidden=4)
    params = model.init(jax.random.PRNGKey(2))
    eng = single_model_engine(model, params, task="node",
                              cfg=GhostConfig(v=8, n=8), slots=4)
    rep = eng.run(graphs)
    assert rep.traces_compiled == 1
    assert rep.mean_batch_size > 1
    assert rep.cache_hits == 5


def test_engine_zero_edge_graph():
    g = Graph(edge_src=np.zeros(0, np.int32), edge_dst=np.zeros(0, np.int32),
              node_feat=np.random.default_rng(0)
              .standard_normal((9, 6)).astype(np.float32)).validate()
    model = build_model("gin", 6, 2, hidden=4, mlp_layers=2)
    params = model.init(jax.random.PRNGKey(3))
    eng = single_model_engine(model, params, task="graph",
                              cfg=GhostConfig(v=4, n=4), slots=2,
                              backend="pallas")
    eng.run([g])
    pg = partition_graph(g, v=4, n=4)
    featp = jnp.asarray(pg.pad_features(g.node_feat))
    with aggregate_backend("pallas"):
        ref = np.asarray(model.apply_blocked(params, to_blocked(pg), featp))
    np.testing.assert_array_equal(eng.results[0], ref)


def test_engine_report_json_roundtrips():
    import json

    g = make_graph(5, nv=16, ne=30)
    model = build_model("gcn", 7, 2, hidden=4)
    params = model.init(jax.random.PRNGKey(0))
    eng = single_model_engine(model, params, task="node",
                              cfg=GhostConfig(v=8, n=8), slots=2,
                              spec=GnnModelSpec.gcn(7, 4, 2))
    rep = eng.run([g, g, g])
    doc = json.loads(rep.to_json())
    for key in ("requests", "req_per_s", "p50_latency_ms", "p99_latency_ms",
                "cache_hit_rate", "traces_compiled", "hw_latency_s",
                "scheduler", "per_model", "admitted", "rejected", "shed",
                "max_wait_ticks"):
        assert key in doc
    assert doc["requests"] == 3
    assert doc["cache_hit_rate"] == pytest.approx(2 / 3)
    assert doc["scheduler"] == "fifo"
    assert doc["per_model"] == {"m": 3}
    assert doc["admitted"] == 3 and doc["rejected"] == 0
    # perf_counter latency accounting: never negative.
    assert all(r.latency_s >= 0 for r in eng.records)


def test_engine_rejects_bad_config():
    model = build_model("gcn", 7, 2, hidden=4)
    params = model.init(jax.random.PRNGKey(0))
    eng = GnnServeEngine()
    with pytest.raises(ValueError):
        eng.register("m", model, params, task="edge")
    with pytest.raises(ValueError):
        GnnServeEngine(slots=0)
    # Fail fast at construction, before any requests are queued:
    with pytest.raises(ValueError):
        GnnServeEngine(backend="nope")
    with pytest.raises(ValueError):
        GnnServeEngine(scheduler="nope")
    with pytest.raises(ValueError):
        GnnServeEngine(max_waiting=0)
    with pytest.raises(ValueError):
        GnnServeEngine(admission_policy="nope")
    with pytest.raises(ValueError):
        eng.register("m", model, params, task="graph")  # GCN has no readout
    eng.register("m", model, params)
    with pytest.raises(ValueError):
        eng.register("m", model, params)  # duplicate id
    with pytest.raises(KeyError):
        eng.submit("ghost", make_graph(0))
    with pytest.raises(ValueError):
        eng.submit("m", make_graph(0, f=9))  # feature-width mismatch


def test_engine_hw_cost_stable_under_eviction():
    """Hardware accounting must not depend on cache-eviction timing."""
    g = make_graph(21, nv=18, ne=36)
    other = make_graph(22, nv=50, ne=120)
    model = build_model("gcn", 7, 2, hidden=4)
    params = model.init(jax.random.PRNGKey(0))

    def run_with(capacity):
        eng = single_model_engine(model, params, task="node",
                                  cfg=GhostConfig(v=8, n=8), slots=2,
                                  prepare_fn=gcn_prepare,
                                  cache_capacity=capacity,
                                  spec=GnnModelSpec.gcn(7, 4, 2))
        # Submit g first, then evict it (capacity=1) before serving.
        eng.submit("m", g)
        eng.submit("m", other)
        eng.drain()
        return next(r for r in eng.records if r.rid == 0)

    roomy = run_with(capacity=8)
    evicted = run_with(capacity=1)
    assert evicted.hw_latency_s == pytest.approx(roomy.hw_latency_s)
    assert evicted.hw_energy_j == pytest.approx(roomy.hw_energy_j)


def test_engine_serves_exactly_through_capacity1_cache():
    """Regression: the evicted-between-submit-and-serve re-derivation path.

    With a capacity-1 PreprocessCache every second submission evicts the
    first request's entry before it is served.  The pending request carries
    its own padded arrays, so outputs must stay bit-exact and the hardware
    numbers must be re-derived (not silently zeroed or mis-keyed).
    """
    graphs = [make_graph(30 + s, nv=20 + 4 * s, ne=40 + 10 * s)
              for s in range(4)]
    model = build_model("gcn", 7, 2, hidden=4)
    params = model.init(jax.random.PRNGKey(0))
    eng = single_model_engine(model, params, task="node",
                              cfg=GhostConfig(v=8, n=8), slots=2,
                              prepare_fn=gcn_prepare, cache_capacity=1,
                              spec=GnnModelSpec.gcn(7, 4, 2))
    for g in graphs:
        eng.submit("m", g)   # each submit evicts the previous entry
    eng.drain()
    assert len(eng.cache) == 1
    for i, g in enumerate(graphs):
        g2, w = gcn_prepare(g)
        pg = partition_graph(g2, v=8, n=8, edge_weights=w)
        featp = jnp.asarray(pg.pad_features(g.node_feat))
        ref = np.asarray(model.apply_blocked(params, to_blocked(pg),
                                             featp))[: g.num_nodes]
        np.testing.assert_array_equal(eng.results[i], ref)
        rec = next(r for r in eng.records if r.rid == i)
        assert rec.hw_latency_s > 0 and rec.hw_energy_j > 0
