"""Per-architecture smoke tests (deliverable f): every pool config, reduced
to CPU size with its family structure intact, runs one forward/train step
and a prefill+decode round; outputs have the right shapes and no NaNs."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config, get_smoke_config
from repro.models import build_model
from repro.roofline.analysis import active_params, total_params

B, S = 2, 16


def _batch(cfg, rng):
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    labels = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    batch = {"tokens": tokens, "labels": labels}
    if cfg.encoder_layers:
        batch["frames"] = jnp.asarray(
            rng.standard_normal((B, cfg.encoder_frames, cfg.d_model)),
            jnp.float32)
    return batch


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_smoke_train_step(name):
    cfg = get_smoke_config(name)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, np.random.default_rng(0))
    loss, metrics = model.loss(params, batch, seq_chunk=8)
    assert jnp.isfinite(loss), f"{name}: non-finite loss"
    # chance-level CE at init
    assert 0.5 * np.log(cfg.vocab_size) < float(loss) < 3 * np.log(cfg.vocab_size)
    grads = jax.grad(lambda p: model.loss(p, batch, seq_chunk=8)[0])(params)
    gnorm = sum(float(jnp.sum(jnp.square(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_smoke_serve_round(name):
    cfg = get_smoke_config(name)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    if cfg.encoder_layers:
        frames = jnp.asarray(
            rng.standard_normal((B, cfg.encoder_frames, cfg.d_model)),
            jnp.float32)
        caches = model.init_cache(params, frames, B, 32)
    else:
        caches = model.init_cache(B, 32)
    logits, caches = model.prefill(params, tokens[:, :8], caches)
    assert logits.shape == (B, 1, cfg.vocab_size)
    logits, caches = model.decode_step(params, caches, tokens[:, 8:9],
                                       jnp.asarray(8))
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))


DENSE_ARCHS = [n for n in ARCH_NAMES
               if get_config(n).moe is None]


@pytest.mark.parametrize("name", DENSE_ARCHS)
def test_decode_matches_teacher_forcing(name):
    """Incremental decode == full forward (dense archs; MoE archs differ by
    capacity-drop semantics — tested separately below)."""
    cfg = get_smoke_config(name)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(2)
    s = 10
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, s)), jnp.int32)
    if cfg.encoder_layers:
        frames = jnp.asarray(
            rng.standard_normal((B, cfg.encoder_frames, cfg.d_model)),
            jnp.float32)
        enc = model.encode(params, frames)
        h, _ = model.decoder_states(params, tokens, enc, mode="train")
        full = h @ params["embed"].T
        caches = model.init_cache(params, frames, B, s)
    else:
        h, _, _ = model.hidden_states(params, tokens, jnp.arange(s),
                                      mode="train")
        full = model.logits(params, h)
        caches = model.init_cache(B, s)
    lg, caches = model.prefill(params, tokens[:, :5], caches)
    errs = [float(jnp.abs(lg[:, -1] - full[:, 4]).max())]
    for t in range(5, s):
        lg, caches = model.decode_step(params, caches, tokens[:, t:t + 1],
                                       jnp.asarray(t))
        errs.append(float(jnp.abs(lg[:, 0] - full[:, t]).max()))
    assert max(errs) < 5e-4, f"{name}: decode drift {max(errs)}"


MOE_ARCHS = [n for n in ARCH_NAMES if get_config(n).moe is not None]


@pytest.mark.parametrize("name", MOE_ARCHS)
def test_moe_decode_matches_with_ample_capacity(name):
    cfg = get_smoke_config(name)
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=64.0))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    s = 8
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, s)), jnp.int32)
    h, _, _ = model.hidden_states(params, tokens, jnp.arange(s), mode="train")
    full = model.logits(params, h)
    caches = model.init_cache(B, s)
    lg, caches = model.prefill(params, tokens[:, :4], caches)
    errs = [float(jnp.abs(lg[:, -1] - full[:, 3]).max())]
    for t in range(4, s):
        lg, caches = model.decode_step(params, caches, tokens[:, t:t + 1],
                                       jnp.asarray(t))
        errs.append(float(jnp.abs(lg[:, 0] - full[:, t]).max()))
    assert max(errs) < 5e-4, f"{name}: decode drift {max(errs)}"


def test_full_config_param_counts():
    """Sanity: analytic param counts land near the advertised sizes."""
    totals = {n: total_params(get_config(n)) for n in ARCH_NAMES}
    assert 100e9 < totals["mistral-large-123b"] < 140e9
    assert 600e9 < totals["deepseek-v3-671b"] < 750e9
    assert 40e9 < totals["mixtral-8x7b"] < 56e9
    assert 1.0e9 < totals["hymba-1.5b"] < 2.2e9
    assert 1.2e9 < totals["rwkv6-1.6b"] < 2.2e9
    assert 30e9 < totals["chameleon-34b"] < 40e9
    # MoE active << total
    assert active_params(get_config("deepseek-v3-671b")) < 0.1 * totals["deepseek-v3-671b"]


def test_long_context_policy():
    """long_500k runs only for sub-quadratic archs (DESIGN.md skip policy)."""
    sub = {n for n in ARCH_NAMES if get_config(n).subquadratic}
    assert sub == {"hymba-1.5b", "rwkv6-1.6b"}
