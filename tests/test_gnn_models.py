"""GNN models: learning works, blocked serving == edge-list training path,
8-bit quantization preserves accuracy (Table 3's claim)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import partition_graph, to_blocked
from repro.gnn import build_model, load
from repro.gnn.datasets import TABLE2, make_node_classification
from repro.gnn.train import (
    eval_graph_classifier,
    eval_node_classifier,
    node_graph_arrays,
    train_graph_classifier,
    train_node_classifier,
)

TABLE2["TinyTest"] = dict(nodes=220, edges=900, features=48, labels=4, graphs=1)


@pytest.fixture(scope="module")
def tiny_graph():
    return make_node_classification("TinyTest", seed=5)


@pytest.mark.parametrize("name,kw", [
    ("gcn", dict(hidden=16)),
    ("sage", dict(hidden=16)),
    ("gat", dict(hidden=4, heads=4)),
])
def test_training_beats_chance(name, kw, tiny_graph):
    model = build_model(name, 48, 4, **kw)
    params, _ = train_node_classifier(model, tiny_graph, steps=80, lr=0.02)
    acc = eval_node_classifier(model, params, tiny_graph)
    assert acc > 0.5  # 4 classes, chance = 0.25


@pytest.mark.parametrize("name,kw", [
    ("gcn", dict(hidden=16)),
    ("sage", dict(hidden=16)),
    ("gat", dict(hidden=4, heads=4)),
])
def test_blocked_serving_matches_edge_backend(name, kw, tiny_graph):
    model = build_model(name, 48, 4, **kw)
    params = model.init(jax.random.PRNGKey(0))
    arrs = node_graph_arrays(tiny_graph)
    ref = model.apply(params, arrs["feat"], arrs["edge_src"],
                      arrs["edge_dst"], arrs["edge_weight"], arrs["num_nodes"])

    g = arrs["graph"]
    weights = g.gcn_edge_weights() if name == "gcn" else None
    pg = partition_graph(g, v=20, n=20, edge_weights=weights)
    bg = to_blocked(pg)
    featp = jnp.asarray(pg.pad_features(g.node_feat))
    got = model.apply_blocked(params, bg, featp)[:g.num_nodes]
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=2e-3, rtol=1e-3)


def test_quantized_inference_accuracy_close(tiny_graph):
    """Table 3: 8-bit accuracy within a couple points of fp32."""
    model = build_model("gcn", 48, 4, hidden=16)
    params, _ = train_node_classifier(model, tiny_graph, steps=80, lr=0.02)
    fp32 = eval_node_classifier(model, params, tiny_graph)
    int8 = eval_node_classifier(model, params, tiny_graph, quantized=True)
    assert abs(fp32 - int8) < 0.05


def test_gin_graph_classification():
    graphs = load("Mutag", seed=0, num_graphs=60)
    model = build_model("gin", graphs[0].num_features, 2, hidden=16,
                        mlp_layers=2)
    params, test_set = train_graph_classifier(model, graphs, steps=60,
                                              batch_size=16)
    acc = eval_graph_classifier(model, params, test_set)
    assert acc > 0.6  # binary, structural classes are separable


def test_dataset_stats_match_table2():
    for name in ("Cora", "Citeseer"):
        g = load(name, seed=0)
        spec = TABLE2[name]
        assert g.num_nodes == spec["nodes"]
        assert g.num_edges == spec["edges"]
        assert g.num_features == spec["features"]
        assert int(g.labels.max()) + 1 == spec["labels"]
    graphs = load("Mutag", seed=0, num_graphs=30)
    spec = TABLE2["Mutag"]
    mean_nodes = np.mean([g.num_nodes for g in graphs])
    assert abs(mean_nodes - spec["nodes"]) < spec["nodes"] * 0.4
