"""Sharding spec helpers (distributed.sharding).

Rule-engine unit coverage plus the graph-aware specs this repo's serving
path uses.  Divisibility/spec tests run against a duck-typed stub mesh
(only ``mesh.shape`` is consulted), so they need no devices;
``NamedSharding``-producing helpers use a real 1-device mesh.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import Graph, partition_graph, shard_blocked, to_blocked
from repro.distributed.sharding import (
    _fits,
    auto_shard_params,
    blocked_graph_shardings,
    blocked_graph_specs,
    estimate_bytes_per_device,
    estimate_graph_bytes_per_device,
    spec_for_param,
)
from repro.launch.mesh import make_data_mesh


class StubMesh:
    """Duck-typed mesh: the rule engine only reads ``shape``."""

    def __init__(self, **shape):
        self.shape = shape
        self.axis_names = tuple(shape)


MESH = StubMesh(data=4, model=8)


def test_fits_edge_cases():
    assert _fits(32, MESH, "model")
    assert not _fits(12, MESH, "model")
    assert _fits(12, MESH, "data")
    # None axes -> size 1 -> everything fits (replication).
    assert _fits(7, MESH, None)
    # Tuple axes multiply.
    assert _fits(64, MESH, ("data", "model"))
    assert not _fits(16, MESH, ("data", "model"))
    assert _fits(0, MESH, "model")  # degenerate dim divides everything


def test_spec_for_param_tp_dims():
    spec, fb = spec_for_param("layers/attn/wq", (64, 32), MESH,
                              "data", "model")
    assert spec == P("data", "model") and not fb
    spec, fb = spec_for_param("layers/attn/wo", (32, 64), MESH,
                              "data", "model")
    assert spec == P("model", "data") and not fb
    # Non-divisible TP dim falls back to replication on that dim (recorded).
    spec, fb = spec_for_param("layers/attn/wq", (64, 12), MESH,
                              "data", "model")
    assert spec == P("data", None) and fb


def test_spec_for_param_generic_and_small():
    # Generic matrix: FSDP the larger dim, TP the smaller.
    spec, fb = spec_for_param("gcn/w1", (64, 32), MESH, "data", "model")
    assert spec == P("data", "model") and not fb
    # Small vectors and scalars replicate.
    assert spec_for_param("gcn/b1", (3,), MESH, "data", "model") == (P(), False)
    assert spec_for_param("eps", (), MESH, "data", "model") == (P(), False)
    # Long vectors get FSDP.
    spec, _ = spec_for_param("embed_bias", (2048,), MESH, "data", "model")
    assert spec == P("data")


def test_auto_shard_gnn_param_tree():
    """A GNN-shaped param tree flows through the generic rules: weight
    matrices shard, biases replicate, and every leaf gets a sharding."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    params = {
        "layer0": {"w": jnp.zeros((16, 32)), "b": jnp.zeros((32,))},
        "layer1": {"w": jnp.zeros((32, 3)), "b": jnp.zeros((3,))},
    }
    plan = auto_shard_params(params, mesh)
    assert set(plan.shardings) == {"layer0/w", "layer0/b",
                                   "layer1/w", "layer1/b"}
    assert plan.shardings["layer0/b"].spec == P()
    # On a 1-device mesh everything divides; bytes = full tree size.
    total = estimate_bytes_per_device(params, plan, mesh)
    assert total == sum(int(np.prod(l.shape)) * 4
                        for l in jax.tree.leaves(params))


def _blocked(seed=0, nv=50, ne=200, f=8, v=8, n=8):
    rng = np.random.default_rng(seed)
    g = Graph(
        edge_src=rng.integers(0, nv, ne).astype(np.int32),
        edge_dst=rng.integers(0, nv, ne).astype(np.int32),
        node_feat=rng.standard_normal((nv, f)).astype(np.float32),
    ).validate()
    return to_blocked(partition_graph(g, v=v, n=n))


def test_blocked_graph_specs():
    bg = _blocked()
    specs = blocked_graph_specs(bg)
    # Plain graphs replicate; to_blocked materializes deg eagerly.
    assert specs == {"blocks": P(), "block_row": P(), "block_col": P(),
                     "deg": P()}
    # deg only appears once materialized.
    assert "deg" not in blocked_graph_specs(bg._replace(deg=None))
    sbg = shard_blocked(bg, 2)
    specs = blocked_graph_specs(sbg, axis="data")
    assert specs == {"blocks": P("data"), "block_row": P("data"),
                     "block_col": P("data"), "deg": P("data")}
    with pytest.raises(TypeError, match="BlockedGraph"):
        blocked_graph_specs({"not": "a graph"})


def test_blocked_graph_shardings_real_mesh():
    mesh = make_data_mesh(1)
    sbg = shard_blocked(_blocked(), 1)
    shardings = blocked_graph_shardings(sbg, mesh)
    assert set(shardings) == {"blocks", "block_row", "block_col", "deg"}
    for s in shardings.values():
        assert s.mesh is mesh


def test_estimate_graph_bytes_per_device():
    bg = _blocked()
    sbg = shard_blocked(bg, 4)
    full = estimate_graph_bytes_per_device(sbg, 1)
    quarter = estimate_graph_bytes_per_device(sbg, 4)
    assert quarter == pytest.approx(full / 4)
    # A plain BlockedGraph replicates wholesale regardless of shard count.
    rep = estimate_graph_bytes_per_device(bg, 1)
    assert estimate_graph_bytes_per_device(bg, 4) == rep
    assert rep > 0
    with pytest.raises(ValueError, match="num_shards"):
        estimate_graph_bytes_per_device(bg, 0)
