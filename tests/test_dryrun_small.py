"""Dry-run machinery integration test on a small host-device mesh.

Runs in a SUBPROCESS because the 8-device XLA flag must be set before jax
initializes (the production dry-run does the same with 512 devices).
Exercises: input_specs, sharding plans, jit lower+compile of a train step
and a decode step under a (2, 4) ("data","model") mesh, and the roofline
metric extraction — the full deliverable-(e) path at CI scale.
"""

import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import numpy as np
from repro.configs import get_smoke_config
from repro.launch import dryrun
from repro.launch.mesh import make_debug_mesh
from repro.roofline.analysis import Roofline, model_flops

mesh = make_debug_mesh((2, 4), ("data", "model"))

# shrink the shape table to CPU scale
dryrun.SHAPES = {
    "train_4k": dict(seq_len=32, global_batch=4, kind="train"),
    "decode_32k": dict(seq_len=64, global_batch=4, kind="decode"),
}

out = {}
for arch in ("chatglm3-6b", "mixtral-8x7b"):
    cfg = get_smoke_config(arch)
    for shape in ("train_4k", "decode_32k"):
        rec, metrics, _ = dryrun.lower_cell(cfg, shape, mesh)
        mf = model_flops(cfg, dryrun.SHAPES[shape]["kind"], 32, 4)
        roof = Roofline.from_metrics(metrics, mf, 8)
        out[f"{arch}/{shape}"] = {
            "flops": metrics.flops,
            "collective_total": metrics.collective_total,
            "bottleneck": roof.bottleneck,
            "fallbacks": len(rec["sharding_fallbacks"]),
        }
print("RESULT::" + json.dumps(out))
"""


@pytest.mark.slow
def test_dryrun_lowers_on_small_mesh():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("JAX_PLATFORMS", None)
    env["JAX_PLATFORMS"] = "cpu"
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT], env=env,
        capture_output=True, text=True, timeout=480)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT::")]
    assert line, proc.stdout
    out = json.loads(line[0][len("RESULT::"):])
    assert len(out) == 4
    for cell, rec in out.items():
        assert rec["flops"] > 0, cell
        # a sharded step must communicate (TP matmuls at minimum)
        assert rec["collective_total"] > 0, cell
