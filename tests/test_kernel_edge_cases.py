"""Pallas block_spmm interpret-mode regression tests for degenerate shapes.

These are the shapes a serving queue actually produces: empty graphs, tiny
graphs that collapse to a single destination group, and feature dims far
below one TPU lane tile (128).  Each case historically stresses a different
part of the kernel wrapper: the visited-row zeroing, the first-visit
accumulator init, and the feature-padding path.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Graph, ReduceOp, aggregate_blocked, partition_graph, to_blocked
from repro.kernels import aggregate_blocked_kernel, block_spmm_padded


def _graph(nv, src, dst, f=5, seed=0):
    rng = np.random.default_rng(seed)
    return Graph(
        edge_src=np.asarray(src, np.int32),
        edge_dst=np.asarray(dst, np.int32),
        node_feat=rng.standard_normal((nv, f)).astype(np.float32),
    ).validate()


def test_zero_edge_graph_all_zero_output():
    """No edges -> no tiles -> the visited-mask path zeroes every row."""
    g = _graph(11, [], [], f=5)
    pg = partition_graph(g, v=4, n=4)
    assert pg.stats.nonzero_tiles == 0
    # Placeholder tile keeps the array triple consistent.
    assert pg.blocks.shape[0] == pg.block_row.shape[0] == pg.block_col.shape[0] == 1
    assert not pg.blocks.any()
    featp = jnp.asarray(pg.pad_features(g.node_feat))
    out = aggregate_blocked_kernel(pg, featp, block_f=8, interpret=True)
    assert out.shape == (pg.padded_dst, 5)
    np.testing.assert_array_equal(np.asarray(out), 0.0)
    # jnp oracle agrees on the degenerate case, every reduce mode.
    bg = to_blocked(pg)
    for op in ReduceOp:
        np.testing.assert_array_equal(
            np.asarray(aggregate_blocked(bg, featp, op)), 0.0)


def test_single_destination_group():
    """All destinations inside one group: one output block, accumulated in
    VMEM across every tile (the first_visit init must fire exactly once)."""
    g = _graph(12, [0, 3, 7, 11, 5, 2], [1, 1, 1, 2, 0, 1], f=6, seed=1)
    pg = partition_graph(g, v=16, n=4)  # v >= nv -> G_dst == 1
    assert pg.num_dst_groups == 1
    featp = jnp.asarray(pg.pad_features(g.node_feat))
    got = aggregate_blocked_kernel(pg, featp, block_f=8, interpret=True)
    ref = aggregate_blocked(to_blocked(pg), featp, ReduceOp.SUM)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-5)


def test_feature_dim_below_one_lane_tile():
    """F=3 with the production block_f=128: the wrapper must pad, run, and
    slice back without touching garbage lanes."""
    g = _graph(30, [0, 1, 2, 3, 29], [5, 5, 6, 7, 0], f=3, seed=2)
    pg = partition_graph(g, v=8, n=8)
    featp = jnp.asarray(pg.pad_features(g.node_feat))
    got = aggregate_blocked_kernel(pg, featp, block_f=128, interpret=True)
    assert got.shape == (pg.padded_dst, 3)
    ref = aggregate_blocked(to_blocked(pg), featp, ReduceOp.SUM)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-5)


def test_zero_edge_direct_wrapper_call():
    """block_spmm_padded itself (not just the pg wrapper) on the
    placeholder-tile arrays a zero-edge partition produces."""
    v, n, g_dst, g_src, f = 4, 4, 3, 3, 5
    blocks = jnp.zeros((1, v, n), jnp.float32)
    row = jnp.zeros((1,), jnp.int32)
    col = jnp.zeros((1,), jnp.int32)
    feat = jnp.asarray(
        np.random.default_rng(0).standard_normal((g_src * n, f)), jnp.float32)
    out = block_spmm_padded(blocks, row, col, feat, g_dst, block_f=8,
                            interpret=True)
    np.testing.assert_array_equal(np.asarray(out), 0.0)


@pytest.mark.parametrize("reduce", [ReduceOp.SUM, ReduceOp.MEAN])
def test_pallas_backend_context_equals_oracle(reduce):
    """core.aggregate_backend('pallas') routes through the kernel and stays
    numerically tight against the jnp path."""
    from repro.core import aggregate_backend

    g = _graph(40, np.arange(30) % 40, (np.arange(30) * 7) % 40, f=9, seed=3)
    pg = partition_graph(g, v=8, n=8)
    bg = to_blocked(pg)
    featp = jnp.asarray(pg.pad_features(g.node_feat))
    ref = aggregate_blocked(bg, featp, reduce)
    with aggregate_backend("pallas"):
        got = aggregate_blocked(bg, featp, reduce)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-5)
