"""Analytic performance model: paper-validation targets (Sections 4.3-4.5)
and structural invariants of the pipeline schedule."""

import numpy as np
import pytest

from repro.core.graph import Graph
from repro.core.pipeline import StageLoad, grouped_latency, sequential_latency
from repro.photonic.perf import (
    GhostConfig,
    GnnModelSpec,
    OrchFlags,
    profile_graph,
    simulate,
)


def small_graph(seed=0, nv=300, ne=1200, f=64):
    rng = np.random.default_rng(seed)
    return Graph(
        edge_src=rng.integers(0, nv, ne).astype(np.int32),
        edge_dst=rng.integers(0, nv, ne).astype(np.int32),
        node_feat=rng.standard_normal((nv, f)).astype(np.float32),
    ).validate()


CFG = GhostConfig()  # the paper's optimum [20, 20, 18, 7, 17]


def test_optimal_config_respects_device_limits():
    CFG.validate()
    with pytest.raises(ValueError):
        GhostConfig(rc=20).validate()   # 21 coherent MRs > 20
    with pytest.raises(ValueError):
        GhostConfig(rr=19).validate()   # > 18 WDM channels


def test_wb_flag_constraints():
    with pytest.raises(ValueError):
        OrchFlags(wb=True, dac_sharing=True).validate()
    with pytest.raises(ValueError):
        OrchFlags(wb=True, bp=False, dac_sharing=False).validate()
    OrchFlags(wb=True, dac_sharing=False).validate()


def test_power_near_paper_18w():
    """Paper: GHOST total power ~ 18 W."""
    g = small_graph(f=512)
    r = simulate(GnnModelSpec.gcn(512, 64, 8), g, CFG, OrchFlags())
    assert 8.0 < r.power < 22.0


def test_optimizations_reduce_energy_and_latency():
    g = small_graph()
    spec = GnnModelSpec.gcn(64, 32, 4)
    full = simulate(spec, g, CFG, OrchFlags())
    base = simulate(spec, g, CFG, OrchFlags(bp=False, pp=False,
                                            dac_sharing=False))
    assert base.energy > full.energy * 1.2
    assert base.latency > full.latency


def test_fig8_ordering_bp_pp_dac_best():
    """BP+PP+DAC <= any subset (Fig. 8's conclusion)."""
    g = small_graph()
    spec = GnnModelSpec.gcn(64, 32, 4)
    combos = {
        "none": OrchFlags(bp=False, pp=False, dac_sharing=False),
        "bp": OrchFlags(bp=True, pp=False, dac_sharing=False),
        "bp_pp": OrchFlags(bp=True, pp=True, dac_sharing=False),
        "bp_pp_dac": OrchFlags(bp=True, pp=True, dac_sharing=True),
    }
    energies = {k: simulate(spec, g, CFG, f).energy for k, f in combos.items()}
    assert energies["bp_pp_dac"] <= energies["bp_pp"] <= energies["none"]
    assert energies["bp"] <= energies["none"]


def skewed_graph(seed=0, nv=600, ne=4000, f=1024):
    """Power-law in-degrees — the citation-graph profile Fig. 9 reflects
    (aggregate latency follows the max-degree lane, Section 3.3.1)."""
    rng = np.random.default_rng(seed)
    theta = rng.pareto(1.5, nv) + 1.0
    p = theta / theta.sum()
    return Graph(
        edge_src=rng.integers(0, nv, ne).astype(np.int32),
        edge_dst=rng.choice(nv, size=ne, p=p).astype(np.int32),
        node_feat=np.zeros((nv, f), np.float32),
    ).validate()


def test_fig9_dominance_patterns():
    """Aggregate dominates GCN; combine dominates GAT and GIN (Fig. 9)."""
    g = skewed_graph(f=1024)
    gcn = simulate(GnnModelSpec.gcn(1024, 64, 8), g, CFG, OrchFlags())
    assert gcn.breakdown["aggregate"].latency > gcn.breakdown["combine"].latency

    gat = simulate(GnnModelSpec.gat(1024, 8, 8), g, CFG, OrchFlags())
    assert gat.breakdown["combine"].latency > gat.breakdown["aggregate"].latency

    small = small_graph(nv=30, ne=60, f=64)
    gin = simulate(GnnModelSpec.gin(64, 32, 2), [small] * 5, CFG, OrchFlags())
    assert gin.breakdown["combine"].latency > gin.breakdown["aggregate"].latency


def test_hbm_bandwidth_within_paper_limit():
    """Paper Section 4.1: max required bandwidth 174.4 GB/s < 256 GB/s."""
    g = small_graph(nv=2000, ne=20000, f=1433)
    r = simulate(GnnModelSpec.gcn(1433, 64, 8), g, CFG, OrchFlags())
    hbm_bytes = r.breakdown["memory"].energy / 31.2e-12  # rough inverse
    implied_bw = hbm_bytes / r.latency
    assert implied_bw < 256e9 * 1.05


def test_workload_balancing_reduces_latency_on_skewed_graphs():
    rng = np.random.default_rng(0)
    # Heavily skewed in-degree: a few hub destinations.
    nv, ne = 400, 4000
    dst = np.where(rng.random(ne) < 0.7,
                   rng.integers(0, 8, ne), rng.integers(0, nv, ne))
    g = Graph(edge_src=rng.integers(0, nv, ne).astype(np.int32),
              edge_dst=dst.astype(np.int32),
              node_feat=np.zeros((nv, 64), np.float32)).validate()
    spec = GnnModelSpec.gcn(64, 32, 4)
    no_wb = simulate(spec, g, CFG, OrchFlags(dac_sharing=False))
    wb = simulate(spec, g, CFG, OrchFlags(dac_sharing=False, wb=True))
    assert wb.latency < no_wb.latency


# ---- pipeline schedule model ----

def test_pipelined_never_slower_than_sequential():
    rng = np.random.default_rng(1)
    for _ in range(20):
        groups = []
        for _ in range(int(rng.integers(1, 6))):
            groups.append([
                StageLoad("a", int(rng.integers(1, 20)), float(rng.random() + .1)),
                StageLoad("b", int(rng.integers(1, 20)), float(rng.random() + .1)),
                StageLoad("c", int(rng.integers(1, 20)), float(rng.random() + .1)),
            ])
        seq = grouped_latency(groups, pipeline_within=False, pipeline_across=False)
        pp = grouped_latency(groups, pipeline_within=True, pipeline_across=True)
        assert pp <= seq + 1e-9
        # lower bound: no stage unit can be busy less than its own work
        for s in range(3):
            busy = sum(g[s].total for g in groups)
            assert pp >= busy - 1e-9


def test_pipeline_single_stage_equals_sum():
    groups = [[StageLoad("only", 5, 2.0)] for _ in range(3)]
    assert grouped_latency(groups) == pytest.approx(30.0)


def test_profile_caching_consistency():
    g = small_graph(3)
    p1 = profile_graph(g, 20, 20)
    p2 = profile_graph(g, 20, 20)
    assert p1 is p2  # cached
    assert p1.tiles_per_group.sum() == p1.nonzero_tiles
    assert int(p1.edges_per_group.sum()) == g.num_edges
