import os

# Tests must see the real (single-device) CPU backend; only the dry-run
# process forces 512 host devices.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# hypothesis is optional: the property-based tests in test_properties.py
# skip themselves when it is missing, and the CI profile only exists when
# the package is importable.
try:
    from hypothesis import settings
except ImportError:
    settings = None

if settings is not None:
    settings.register_profile("ci", max_examples=25, deadline=None)
    settings.load_profile("ci")
