import os

# Tests must see the real (single-device) CPU backend; only the dry-run
# process forces 512 host devices.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

from hypothesis import settings

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")
