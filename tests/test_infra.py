"""Infrastructure: optimizer, data pipeline, checkpointing, compression,
resilience, sharding rules, roofline parsing."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.data.tokens import TokenPipeline
from repro.distributed.compression import compress_grads, init_compression
from repro.distributed.resilience import StragglerWatchdog, plan_rescale
from repro.distributed.sharding import batch_spec, spec_for_param
from repro.optim import AdamWConfig, adamw_init, adamw_step
from repro.optim.schedule import warmup_cosine
from repro.roofline.analysis import parse_collective_bytes


# ---- optimizer ----

def numpy_adamw(params, grads, m, v, step, lr, b1, b2, eps, wd):
    m = b1 * m + (1 - b1) * grads
    v = b2 * v + (1 - b2) * grads ** 2
    mh = m / (1 - b1 ** step)
    vh = v / (1 - b2 ** step)
    return params - lr * (mh / (np.sqrt(vh) + eps) + wd * params), m, v


def test_adamw_matches_numpy_reference():
    rng = np.random.default_rng(0)
    p0 = rng.standard_normal(16).astype(np.float32)
    cfg = AdamWConfig(lr=0.01, b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.01,
                      grad_clip_norm=None)
    params = {"w": jnp.asarray(p0)}
    state = adamw_init(params, cfg)
    p_np, m_np, v_np = p0.copy(), np.zeros(16), np.zeros(16)
    for step in range(1, 6):
        g = rng.standard_normal(16).astype(np.float32)
        params, state, _ = adamw_step({"w": jnp.asarray(g)}, state, params, cfg)
        p_np, m_np, v_np = numpy_adamw(p_np, g, m_np, v_np, step,
                                       0.01, 0.9, 0.99, 1e-8, 0.01)
        np.testing.assert_allclose(np.asarray(params["w"]), p_np, atol=1e-5)


def test_grad_clipping_bounds_update():
    cfg = AdamWConfig(lr=1.0, grad_clip_norm=1.0)
    params = {"w": jnp.zeros(4)}
    state = adamw_init(params, cfg)
    big = {"w": jnp.full((4,), 1e6)}
    _, _, metrics = adamw_step(big, state, params, cfg)
    assert float(metrics["grad_norm"]) > 1e6  # reported pre-clip


def test_warmup_cosine_shape():
    fn = warmup_cosine(1.0, 10, 100)
    assert float(fn(jnp.asarray(0))) == 0.0
    assert float(fn(jnp.asarray(10))) == pytest.approx(1.0)
    assert float(fn(jnp.asarray(100))) == pytest.approx(0.1, abs=1e-3)
    assert float(fn(jnp.asarray(55))) < 1.0


# ---- data pipeline ----

def test_pipeline_determinism_and_resume():
    a = TokenPipeline(1000, 32, 4, seed=7)
    b1 = a.next_batch()
    b2 = a.next_batch()
    # restore from state: same stream
    b = TokenPipeline(1000, 32, 4, seed=7)
    b.load_state_dict({"seed": 7, "step": 1})
    b2r = b.next_batch()
    np.testing.assert_array_equal(b2["tokens"], b2r["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])
    # markov structure: chain is learnable (non-uniform successor stats)
    toks = np.concatenate([a.batch_at(i)["tokens"].ravel() for i in range(20)])
    assert len(np.unique(toks)) > 100


# ---- checkpointing ----

def test_checkpoint_roundtrip_and_gc(tmp_path):
    ck = Checkpointer(str(tmp_path), keep_last=2)
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "nested": {"b": jnp.ones((4,), jnp.int32)}}
    for step in (1, 2, 3):
        ck.save(step, jax.tree.map(lambda x: x * step, tree),
                extra={"pipeline": {"seed": 0, "step": step}})
    assert ck.available_steps() == [2, 3]  # gc kept last 2
    restored, extra = ck.restore(3, tree)
    np.testing.assert_allclose(np.asarray(restored["a"]),
                               np.asarray(tree["a"]) * 3)
    assert extra["pipeline"]["step"] == 3


def test_checkpoint_async_and_atomic(tmp_path):
    ck = Checkpointer(str(tmp_path))
    tree = {"w": jnp.ones((8, 8))}
    ck.save_async(5, tree, extra={"x": 1})
    ck.wait()
    assert ck.latest_step() == 5
    # a stale tmp dir must not be treated as a checkpoint
    os.makedirs(tmp_path / "step_9.tmp")
    assert ck.latest_step() == 5


def test_checkpoint_shape_mismatch_raises(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, {"w": jnp.ones((4,))})
    with pytest.raises(ValueError):
        ck.restore(1, {"w": jnp.ones((5,))})


def test_checkpoint_elastic_restore_resharding(tmp_path):
    """Restore under an explicit sharding tree (the elastic-rescale path)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_debug_mesh
    mesh = make_debug_mesh((1,), ("data",))
    ck = Checkpointer(str(tmp_path))
    tree = {"w": jnp.arange(8, dtype=jnp.float32)}
    ck.save(1, tree)
    sh = {"w": NamedSharding(mesh, P("data"))}
    restored, _ = ck.restore(1, tree, shardings=sh)
    assert restored["w"].sharding == sh["w"]
    np.testing.assert_allclose(np.asarray(restored["w"]), np.arange(8))


# ---- gradient compression ----

def test_compression_error_feedback_is_unbiased():
    """Sum over steps of dequantized grads == sum of true grads (+ final
    residual): error feedback makes compression lossless in the limit."""
    rng = np.random.default_rng(0)
    g_true = [rng.standard_normal(32).astype(np.float32) for _ in range(20)]
    state = init_compression({"w": jnp.zeros(32)})
    total_deq = np.zeros(32)
    for g in g_true:
        deq, state = compress_grads({"w": jnp.asarray(g)}, state)
        total_deq += np.asarray(deq["w"])
    residual = np.asarray(state.error["w"])
    np.testing.assert_allclose(total_deq + residual, np.sum(g_true, axis=0),
                               atol=1e-3)


def test_compression_is_int8_resolution():
    state = init_compression({"w": jnp.zeros(4)})
    deq, _ = compress_grads({"w": jnp.asarray([1.0, 0.5, -1.0, 0.0])}, state)
    vals = np.asarray(deq["w"]) * 127.0
    np.testing.assert_allclose(vals, np.round(vals), atol=1e-4)


# ---- resilience ----

def test_watchdog_flags_persistent_straggler():
    wd = StragglerWatchdog(evict_after=3)
    for step in range(6):
        for h in ("h0", "h1", "h2", "h3"):
            wd.record(h, 1.0 if h != "h3" else 3.0)
        v = wd.verdict()
    assert v["h3"] == "evict"
    assert v["h0"] == "ok"


def test_watchdog_ignores_transients():
    wd = StragglerWatchdog(evict_after=3)
    for step in range(6):
        for h in ("h0", "h1", "h2", "h3"):
            slow = step == 2 and h == "h3"
            wd.record(h, 3.0 if slow else 1.0)
        v = wd.verdict()
    assert v["h3"] != "evict"


def test_elastic_plan():
    p = plan_rescale(16, 16, 16 * 16 - 16)  # lost one data row
    assert p is not None and p.model == 16 and p.data < 16
    assert plan_rescale(16, 16, 8) is None  # cannot even fit TP


# ---- sharding rules ----

class FakeMesh:
    axis_names = ("data", "model")
    shape = {"data": 4, "model": 8}


def test_spec_for_param_tp_and_fsdp():
    from jax.sharding import PartitionSpec as P
    spec, fb = spec_for_param("segments/0/attn/wq", (2, 1024, 512),
                              FakeMesh(), "data", "model")
    assert spec == P(None, "data", "model") and not fb
    spec, fb = spec_for_param("segments/0/attn/wo", (2, 512, 1024),
                              FakeMesh(), "data", "model")
    assert spec == P(None, "model", "data")
    spec, fb = spec_for_param("embed", (32000, 4096), FakeMesh(),
                              "data", "model")
    assert spec == P("model", "data")


def test_spec_divisibility_fallback():
    spec, fb = spec_for_param("segments/0/attn/wq", (2, 1021, 512),
                              FakeMesh(), "data", "model")
    assert fb and spec[1] is None


def test_batch_spec():
    from jax.sharding import PartitionSpec as P

    class M3:
        axis_names = ("pod", "data", "model")
        shape = {"pod": 2, "data": 16, "model": 16}

    assert batch_spec(256, M3()) == P(("pod", "data"))
    assert batch_spec(16, M3()) == P(("pod",))  # 16 % 32 != 0 but 16 % 2 == 0
    assert batch_spec(1, M3()) == P()


# ---- roofline HLO parsing ----

def test_parse_collective_bytes():
    hlo = """
  %ag = bf16[16,1024]{1,0} all-gather(bf16[1,1024] %x), dim=0
  %ar.1 = f32[4096]{0} all-reduce(f32[4096] %y), to_apply=%add
  %arst = (f32[8,8]{1,0}, f32[8,8]{1,0}) all-reduce-start(f32[8,8] %z, f32[8,8] %w)
  %noise = f32[2,2]{1,0} add(f32[2,2] %a, f32[2,2] %b)
  %a2a = s8[64,32]{1,0} all-to-all(s8[64,32] %q), dimensions={0}
"""
    got = parse_collective_bytes(hlo)
    assert got["all-gather"] == 16 * 1024 * 2
    assert got["all-reduce"] == 4096 * 4 + 2 * 64 * 4
    assert got["all-to-all"] == 64 * 32
    assert got["reduce-scatter"] == 0
