"""GNN serving driver: the paper-side analogue of ``repro.launch.serve``.

Drives the bucketed continuous-batching engine (repro.serving) with a
synthetic request stream drawn from a hot working set of Mutag graphs —
the deployment shape GHOST targets: repeated inference over a catalog of
known structures, where the offline partitioning (Section 3.4.1) is paid
once per structure and served from the content-hash cache afterwards.

Prints the served-throughput report: functional req/s on this host,
latency percentiles, preprocessing cache hit rate, the bounded jit-trace
count, and the analytic GHOST hardware estimate for the same stream.

Run:  PYTHONPATH=src python examples/serve_gnn.py --requests 40
"""

import argparse

import jax
import numpy as np

from repro.gnn import build_model, load
from repro.gnn.train import train_graph_classifier
from repro.photonic.perf import GhostConfig, GnnModelSpec
from repro.serving import GnnServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=40)
    ap.add_argument("--slots", type=int, default=8,
                    help="continuous-batching width R")
    ap.add_argument("--working-set", type=int, default=12,
                    help="distinct graphs the request stream cycles over")
    ap.add_argument("--backend", choices=("jnp", "pallas"), default="jnp")
    ap.add_argument("--quantized", action="store_true",
                    help="route combines through the photonic 8-bit MVM")
    ap.add_argument("--train-steps", type=int, default=60)
    args = ap.parse_args()
    if args.requests < 1 or args.working_set < 1 or args.slots < 1:
        ap.error("--requests, --working-set and --slots must be >= 1")

    # Offline: train the model once (deployment-side training).
    pool = load("Mutag", seed=0, num_graphs=max(args.working_set, 60))
    model = build_model("gin", pool[0].num_features, 2, hidden=16,
                        mlp_layers=2)
    params, _ = train_graph_classifier(model, pool, steps=args.train_steps)
    print("model trained; starting serving loop")

    cfg = GhostConfig()
    spec = GnnModelSpec.gin(pool[0].num_features, 16, 2, mlp_layers=2)
    engine = GnnServeEngine(
        model, params, task="graph", cfg=cfg, spec=spec,
        slots=args.slots, backend=args.backend, quantized=args.quantized,
        dataset_name="Mutag")

    # Request stream: cycle the hot working set (repeat structures -> the
    # preprocessing cache earns its keep, as in a production catalog).
    rng = np.random.default_rng(0)
    working = pool[: args.working_set]
    stream = [working[int(rng.integers(0, len(working)))]
              for _ in range(args.requests)]
    report = engine.run(stream)

    correct = sum(
        int(np.argmax(engine.results[i]) == g.graph_label)
        for i, g in enumerate(stream))
    print(report.pretty())
    print(f"  accuracy over stream: {correct / len(stream):.3f}")
    assert report.cache_hit_rate > 0, "working-set stream must hit the cache"
    assert report.traces_compiled <= len(report.buckets), \
        "bucketing must bound the jit trace count"


if __name__ == "__main__":
    main()
