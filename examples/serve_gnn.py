"""Multi-model GNN serving driver: one engine, a heterogeneous catalog.

Drives the multi-model continuous-batching engine (repro.serving) the way
GHOST pitches the hardware (Section 4.1): one substrate serving GCN /
GraphSAGE / GIN side by side.  The catalog mixes tasks *and* feature
widths — a trained GIN graph classifier on Mutag (143 features) next to
GCN/GraphSAGE node taggers on Proteins structures (3 features) — so the
request stream exercises model registry, feature-dim bucketing, the
pluggable scheduler, and admission control in one run.

Prints the served-throughput report: functional req/s on this host,
latency percentiles, per-model served counts, admission outcomes, the
preprocessing-cache hit rate, the bounded jit-trace count (<= |models| x
|buckets|), and the analytic GHOST hardware estimate for the same stream.

Run:  PYTHONPATH=src python examples/serve_gnn.py --requests 40 \
          --scheduler occupancy --max-waiting 32

Node-query mode: ``--node-queries`` swaps the per-request graph stream
for GraphSAGE-style neighborhood-sampled serving against one resident
million-scale synthetic power-law host graph (``--host-nodes``).  Each
request names seed vertices; the engine samples a bounded k-hop subgraph
(deterministic per-seed fanouts) and routes it through the same
cache/bucketing/executor machinery.  The skewed (hot-node) seed stream
makes identical resamples share partition-cache entries, which the run
asserts on:

  PYTHONPATH=src python examples/serve_gnn.py --node-queries \
      --host-nodes 200000 --requests 48

Async loop: ``--async-loop`` starts the always-on background serve
thread instead of the caller-driven tick loop — clients just
``try_submit`` from any thread and ``stop(drain=True)`` at the end.  The
catalog is registered with per-model SLOs (``slo_ms``), so the report
gains the deadline-attainment line; pair with ``--scheduler deadline``
to see EDF preemption protect tight-SLO models under load.
``--pipeline-depth N`` sets the serve-loop pipelining: 0 runs the serial
stack-then-execute loop, N >= 1 overlaps host batch stacking with device
execution across N executor workers (bit-exact with serial; the report
then shows device-busy vs stack-busy overlap fractions):

  PYTHONPATH=src python examples/serve_gnn.py --async-loop \
      --scheduler deadline --requests 60 --pipeline-depth 2

Multi-seed node queries: ``--seeds-per-query K`` batches K seed
vertices into one request in ``--node-queries`` mode; the engine
samples a single shared subgraph and slices one result row per seed —
bit-exact with K solo submissions.

Multi-device: ``--devices N`` builds a 1-D data mesh over the first N
local devices (launch.mesh.make_data_mesh) and hands it to the engine;
every executor trace then partitions its fp32 combine contractions across
the mesh (core.aggregate shard_scope, feature-dim strategy — few-ULP vs
single-device; quantized GIN combines stay single-device since the
per-tensor int8 scale is a global reduction).  On a CPU host, split the
platform into virtual devices first:

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python examples/serve_gnn.py --devices 8
"""

import argparse
import time

import jax
import numpy as np

from repro.gnn import build_model, load
from repro.gnn.train import train_graph_classifier
from repro.photonic.perf import GhostConfig, GnnModelSpec
from repro.serving import GnnServeEngine, HostGraph, gcn_prepare


def run_node_queries(args):
    """Neighborhood-sampled node queries against one resident host graph."""
    f = 16
    host = HostGraph.synthetic_power_law(
        args.host_nodes, avg_degree=6, num_features=f, seed=0)
    print(f"host graph ready: {host.num_nodes} nodes, "
          f"{host.num_edges} edges (synthetic power-law)")

    sage = build_model("sage", f, 4, hidden=16)
    engine = GnnServeEngine(
        cfg=GhostConfig(), slots=args.slots, backend=args.backend,
        scheduler=args.scheduler, max_waiting=args.max_waiting,
        admission_policy=args.admission_policy,
        pipeline_depth=args.pipeline_depth)
    engine.register("sage_host", sage, sage.init(jax.random.PRNGKey(0)),
                    task="node", spec=GnnModelSpec.graphsage(f, 16, 4),
                    slo_ms=100.0 if args.async_loop else None)
    engine.register_host_graph("hg", host, fanouts=(8, 4), rng_seed=0)

    # Skewed seed stream: a small hot set dominates, so deterministic
    # resampling produces identical subgraphs that share cache entries.
    # With --seeds-per-query K each request carries K seeds sampled as one
    # shared subgraph; the result has one row per seed.
    k = args.seeds_per_query
    rng = np.random.default_rng(1)
    hot = rng.permutation(host.num_nodes)[:max(8, args.requests // 6)]
    seeds = hot[rng.integers(0, len(hot), (args.requests, k))]

    t0 = time.perf_counter()
    rids = []
    if args.async_loop:
        engine.start()
        for row in seeds:
            rids.append(engine.try_submit_nodes(
                "sage_host", [int(s) for s in row]))
        engine.stop(drain=True)
    else:
        for i, row in enumerate(seeds):
            rids.append(engine.try_submit_nodes(
                "sage_host", [int(s) for s in row]))
            if (i + 1) % args.slots == 0:
                engine.step()
        engine.drain()
    report = engine.report(time.perf_counter() - t0)

    print(report.pretty())
    served = [rid for rid in rids if rid is not None]
    for rid in served[:1]:
        assert engine.results[rid].shape == (k, 4)
    assert report.node_query_stats["queries"] == len(served)
    assert report.cache_hits > 0, \
        "hot-node stream must share subgraph-level cache entries"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=40)
    ap.add_argument("--slots", type=int, default=8,
                    help="continuous-batching width R")
    ap.add_argument("--working-set", type=int, default=12,
                    help="distinct graphs per dataset the stream cycles over")
    ap.add_argument("--backend", choices=("jnp", "pallas", "pallas_fused"),
                    default="jnp")
    ap.add_argument("--scheduler",
                    choices=("fifo", "occupancy", "deadline"),
                    default="occupancy")
    ap.add_argument("--async-loop", action="store_true",
                    help="serve via the always-on background thread "
                         "(start/try_submit/stop) instead of caller-driven "
                         "ticks; registers per-model SLOs so the report "
                         "shows deadline attainment")
    ap.add_argument("--pipeline-depth", type=int, default=2,
                    help="serve-loop pipelining under --async-loop: 0 = "
                         "serial stack-then-execute, N >= 1 overlaps host "
                         "stacking with N device-executor workers "
                         "(bit-exact with serial)")
    ap.add_argument("--seeds-per-query", type=int, default=1,
                    help="seed vertices per request in --node-queries mode "
                         "(one shared sampled subgraph, one result row per "
                         "seed)")
    ap.add_argument("--max-waiting", type=int, default=None,
                    help="admission bound on the waiting queue")
    ap.add_argument("--admission-policy", choices=("reject", "shed-oldest"),
                    default="reject")
    ap.add_argument("--quantized", action="store_true",
                    help="route the GIN combines through the photonic 8-bit MVM")
    ap.add_argument("--devices", type=int, default=1,
                    help="partition executor traces over a 1-D mesh of this "
                         "many devices (CPU hosts: set XLA_FLAGS="
                         "--xla_force_host_platform_device_count first)")
    ap.add_argument("--train-steps", type=int, default=60)
    ap.add_argument("--node-queries", action="store_true",
                    help="neighborhood-sampled node queries against one "
                         "resident synthetic power-law host graph")
    ap.add_argument("--host-nodes", type=int, default=200_000,
                    help="host-graph size for --node-queries")
    args = ap.parse_args()
    if args.requests < 1 or args.working_set < 1 or args.slots < 1:
        ap.error("--requests, --working-set and --slots must be >= 1")
    if args.devices < 1:
        ap.error("--devices must be >= 1")
    if args.host_nodes < 100:
        ap.error("--host-nodes must be >= 100")
    if args.seeds_per_query < 1:
        ap.error("--seeds-per-query must be >= 1")
    if args.pipeline_depth < 0:
        ap.error("--pipeline-depth must be >= 0")
    if args.node_queries:
        run_node_queries(args)
        return
    mesh = None
    if args.devices > 1:
        from repro.launch.mesh import make_data_mesh
        mesh = make_data_mesh(args.devices)  # raises with the XLA_FLAGS hint

    # Offline: build the catalog.  The GIN graph classifier is trained
    # (deployment-side training); the node taggers ship with fresh params —
    # the serving mechanics are identical either way.
    mutag = load("Mutag", seed=0, num_graphs=max(args.working_set, 60))
    proteins = load("Proteins", seed=0, num_graphs=args.working_set)
    f_gin, f_node = mutag[0].num_features, proteins[0].num_features
    gin = build_model("gin", f_gin, 2, hidden=16, mlp_layers=2)
    gin_params, _ = train_graph_classifier(gin, mutag,
                                           steps=args.train_steps)
    gcn = build_model("gcn", f_node, 2, hidden=16)
    sage = build_model("sage", f_node, 2, hidden=16)
    print(f"catalog ready: gin(f={f_gin}, graph task, trained), "
          f"gcn/sage(f={f_node}, node task); starting serving loop")

    cfg = GhostConfig()
    engine = GnnServeEngine(
        cfg=cfg, slots=args.slots, backend=args.backend,
        scheduler=args.scheduler, max_waiting=args.max_waiting,
        admission_policy=args.admission_policy, mesh=mesh,
        pipeline_depth=args.pipeline_depth)
    # Under --async-loop the catalog carries SLO contracts: the graph
    # classifier is latency-tolerant, the node taggers are interactive.
    slo = {"gin": 250.0, "gcn": 50.0, "sage": 100.0} if args.async_loop \
        else {"gin": None, "gcn": None, "sage": None}
    engine.register("gin_mutag", gin, gin_params, task="graph",
                    spec=GnnModelSpec.gin(f_gin, 16, 2, mlp_layers=2),
                    quantized=args.quantized, dataset_name="Mutag",
                    slo_ms=slo["gin"])
    engine.register("gcn_proteins", gcn,
                    gcn.init(jax.random.PRNGKey(1)), task="node",
                    spec=GnnModelSpec.gcn(f_node, 16, 2),
                    prepare_fn=gcn_prepare, dataset_name="Proteins",
                    slo_ms=slo["gcn"])
    engine.register("sage_proteins", sage,
                    sage.init(jax.random.PRNGKey(2)), task="node",
                    spec=GnnModelSpec.graphsage(f_node, 16, 2),
                    dataset_name="Proteins", slo_ms=slo["sage"])

    # Request stream: cycle hot working sets (repeat structures -> the
    # preprocessing cache earns its keep), mixing the catalog 2:1:1.
    rng = np.random.default_rng(0)
    hot_mutag = mutag[: args.working_set]
    stream = []
    for _ in range(args.requests):
        r = rng.random()
        if r < 0.5:
            stream.append(("gin_mutag",
                           hot_mutag[int(rng.integers(0, len(hot_mutag)))]))
        else:
            mid = "gcn_proteins" if r < 0.75 else "sage_proteins"
            stream.append((mid,
                           proteins[int(rng.integers(0, len(proteins)))]))
    if args.async_loop:
        # Always-on loop: the background thread forms batches while
        # clients submit; stop(drain=True) serves the tail before joining.
        engine.start()
        t0 = time.perf_counter()
        rids = [engine.try_submit(mid, g) for mid, g in stream]
        engine.stop(drain=True)
        report = engine.report(time.perf_counter() - t0)
    elif args.max_waiting is None:
        report = engine.run(stream)
        rids = list(range(len(stream)))
    else:
        # Open loop: paced arrivals against the bounded queue, so the
        # admission knobs actually bite (closed-loop run() drains ahead of
        # the bound and never rejects or sheds).
        t0 = time.perf_counter()
        rids = []
        for i, (mid, g) in enumerate(stream):
            rids.append(engine.try_submit(mid, g))
            if (i + 1) % args.slots == 0:
                engine.step()
        engine.drain()
        report = engine.report(time.perf_counter() - t0)

    gin_rids = [(rid, g) for rid, (mid, g) in zip(rids, stream)
                if mid == "gin_mutag" and rid is not None
                and rid in engine.results]
    correct = sum(int(np.argmax(engine.results[rid]) == g.graph_label)
                  for rid, g in gin_rids)
    print(report.pretty())
    if gin_rids:
        print(f"  gin accuracy over stream: {correct / len(gin_rids):.3f}")
    assert report.cache_hit_rate > 0, "working-set stream must hit the cache"
    assert report.traces_compiled <= 3 * len(report.buckets), \
        "executor pool must bound the jit trace count"
    assert set(report.per_model) <= {"gin_mutag", "gcn_proteins",
                                     "sage_proteins"}


if __name__ == "__main__":
    main()
