"""Continuous-batching LM serving demo (any pool arch, reduced size).

Run:  PYTHONPATH=src python examples/lm_serve.py --arch mixtral-8x7b
"""

from repro.launch.serve import main

if __name__ == "__main__":
    main()
