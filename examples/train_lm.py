"""LM pretraining driver with checkpoint-restart (fault-tolerance demo).

Trains a reduced pool architecture for a few hundred steps on CPU, kills the
loop halfway (simulated failure), and resumes from the latest checkpoint —
verifying bit-exact continuation of the data stream and optimizer state.

Run:  PYTHONPATH=src python examples/train_lm.py --arch chatglm3-6b --steps 200
"""

import argparse
import shutil
import tempfile

from repro.launch.train import TrainConfig, run


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="chatglm3-6b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    args = ap.parse_args()

    ckpt_dir = tempfile.mkdtemp(prefix="repro_lm_")
    base = dict(arch=args.arch, preset="cpu-demo", seq_len=args.seq_len,
                global_batch=args.batch, checkpoint_dir=ckpt_dir,
                checkpoint_every=max(args.steps // 4, 10), log_every=20)

    half = args.steps // 2
    print(f"=== phase 1: train to step {half}, then 'crash' ===")
    out1 = run(TrainConfig(steps=half, resume="none", **base))

    print("=== phase 2: restart, auto-resume from latest checkpoint ===")
    out2 = run(TrainConfig(steps=args.steps, resume="auto", **base))

    l0 = out1["history"][0]["loss"]
    l1 = out2["final_loss"]
    print(f"loss: {l0:.3f} (start) -> {l1:.3f} (final after resume)")
    assert l1 < l0, "training (across a restart) must reduce loss"
    shutil.rmtree(ckpt_dir, ignore_errors=True)
    print("OK: checkpoint-restart training converges")


if __name__ == "__main__":
    main()
