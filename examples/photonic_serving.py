"""End-to-end GNN serving driver (the paper's use case: batched inference).

Simulates a GHOST deployment serving graph-classification requests through
the multi-model continuous-batching engine (repro.serving.GnnServeEngine):

  (a) offline preprocessing — partition + fetch-order generation (Section
      3.4.1) — runs once per distinct graph via the content-hash cache;
  (b) requests are shape-bucketed and served as vmapped quantized blocked
      forwards (one bounded jit trace per (model, bucket));
  (c) the analytic hardware model accumulates photonic latency/energy per
      request (memoized per structure) into a served-throughput report.

This driver registers a single quantized GIN in the catalog and keeps the
original quantized-accuracy + hardware-estimate story of the ad-hoc loop
it replaced; see examples/serve_gnn.py for the heterogeneous-catalog /
scheduler / admission-control demo.

Run:  PYTHONPATH=src python examples/photonic_serving.py [--requests 40]
"""

import argparse

import numpy as np

from repro.gnn import build_model, load
from repro.gnn.train import train_graph_classifier
from repro.photonic.perf import GhostConfig, GnnModelSpec
from repro.serving import GnnServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=40)
    ap.add_argument("--batch", type=int, default=8,
                    help="engine slots (continuous-batching width)")
    args = ap.parse_args()

    # offline: train the model once (deployment-side training)
    graphs = load("Mutag", seed=0, num_graphs=max(args.requests, 60))
    model = build_model("gin", graphs[0].num_features, 2, hidden=16,
                        mlp_layers=2)
    params, _ = train_graph_classifier(model, graphs, steps=60)
    print("model trained; starting serving loop")

    cfg = GhostConfig()
    spec = GnnModelSpec.gin(graphs[0].num_features, 16, 2, mlp_layers=2)
    engine = GnnServeEngine(cfg=cfg, slots=args.batch)
    engine.register("gin_int8", model, params, task="graph", spec=spec,
                    quantized=True, dataset_name="Mutag")

    queue = graphs[: args.requests]
    report = engine.run(queue)   # bare graphs: single-model convenience
    correct = sum(
        int(np.argmax(engine.results[i]) == g.graph_label)
        for i, g in enumerate(queue))

    print(report.pretty())
    print(f"accuracy (int8): {correct / len(queue):.3f}")


if __name__ == "__main__":
    main()
