"""End-to-end GNN serving driver (the paper's use case: batched inference).

Simulates a GHOST deployment serving graph-classification requests through
the bucketed continuous-batching engine (repro.serving.GnnServeEngine):

  (a) offline preprocessing — partition + fetch-order generation (Section
      3.4.1) — runs once per distinct graph via the content-hash cache;
  (b) requests are shape-bucketed and served as vmapped quantized blocked
      forwards (one bounded jit trace per bucket);
  (c) the analytic hardware model accumulates photonic latency/energy per
      request (memoized per structure) into a served-throughput report.

Compare examples/serve_gnn.py (the fp32 engine driver with CLI knobs);
this script keeps the original quantized-accuracy + hardware-estimate
story of the ad-hoc loop it replaced.

Run:  PYTHONPATH=src python examples/photonic_serving.py [--requests 40]
"""

import argparse

import numpy as np

from repro.gnn import build_model, load
from repro.gnn.train import train_graph_classifier
from repro.photonic.perf import GhostConfig, GnnModelSpec
from repro.serving import GnnServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=40)
    ap.add_argument("--batch", type=int, default=8,
                    help="engine slots (continuous-batching width)")
    args = ap.parse_args()

    # offline: train the model once (deployment-side training)
    graphs = load("Mutag", seed=0, num_graphs=max(args.requests, 60))
    model = build_model("gin", graphs[0].num_features, 2, hidden=16,
                        mlp_layers=2)
    params, _ = train_graph_classifier(model, graphs, steps=60)
    print("model trained; starting serving loop")

    cfg = GhostConfig()
    spec = GnnModelSpec.gin(graphs[0].num_features, 16, 2, mlp_layers=2)
    engine = GnnServeEngine(model, params, task="graph", cfg=cfg, spec=spec,
                            slots=args.batch, quantized=True,
                            dataset_name="Mutag")

    queue = graphs[: args.requests]
    report = engine.run(queue)
    correct = sum(
        int(np.argmax(engine.results[i]) == g.graph_label)
        for i, g in enumerate(queue))

    print(report.pretty())
    print(f"accuracy (int8): {correct / len(queue):.3f}")


if __name__ == "__main__":
    main()
