"""End-to-end GNN serving driver (the paper's use case: batched inference).

Simulates a GHOST deployment serving graph-classification requests: a queue
of graphs flows through (a) offline preprocessing — partition + fetch-order
generation (Section 3.4.1), (b) the quantized blocked forward pass, and
(c) the analytic hardware model accumulating photonic latency/energy per
request — producing a served-throughput report (requests/s functional on
CPU; GOPS/EPB from the GHOST model).

Run:  PYTHONPATH=src python examples/photonic_serving.py [--requests 40]
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import partition_graph, to_blocked
from repro.gnn import build_model, load
from repro.gnn.train import pad_graph_batch, train_graph_classifier
from repro.photonic.perf import GhostConfig, GnnModelSpec, OrchFlags, simulate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=40)
    ap.add_argument("--batch", type=int, default=8)
    args = ap.parse_args()

    # offline: train the model once (deployment-side training)
    graphs = load("Mutag", seed=0, num_graphs=max(args.requests, 60))
    model = build_model("gin", graphs[0].num_features, 2, hidden=16,
                        mlp_layers=2)
    params, _ = train_graph_classifier(model, graphs, steps=60)
    print("model trained; starting serving loop")

    cfg = GhostConfig()
    spec = GnnModelSpec.gin(graphs[0].num_features, 16, 2, mlp_layers=2)

    queue = graphs[:args.requests]
    served = 0
    correct = 0
    hw_latency = 0.0
    hw_energy = 0.0
    t0 = time.time()
    while queue:
        batch, queue = queue[:args.batch], queue[args.batch:]
        # (a) offline preprocessing per request (partition matrix)
        parts = [partition_graph(g, v=cfg.v, n=cfg.n) for g in batch]
        # (b) functional quantized inference (padded batch)
        feat, es, ed, nmask, labels, max_n = pad_graph_batch(batch)
        logits = jax.vmap(
            lambda f, s, d, m: model.apply(params, f, s, d, None, max_n,
                                           quantized=True, node_mask=m)
        )(feat, es, ed, nmask)
        pred = np.asarray(jnp.argmax(logits, -1))
        correct += int((pred == np.asarray(labels)).sum())
        served += len(batch)
        # (c) hardware cost of this batch on GHOST
        r = simulate(spec, batch, cfg, OrchFlags(), "Mutag")
        hw_latency += r.latency
        hw_energy += r.energy

    wall = time.time() - t0
    print(f"served {served} requests in {wall:.2f}s wall "
          f"({served / wall:.1f} req/s functional on CPU)")
    print(f"accuracy (int8): {correct / served:.3f}")
    print(f"GHOST hardware estimate: {hw_latency * 1e6:.1f} us total, "
          f"{hw_energy * 1e3:.3f} mJ, "
          f"{served / hw_latency:.0f} req/s, "
          f"avg power {hw_energy / hw_latency:.1f} W")


if __name__ == "__main__":
    main()
