"""Quickstart: the full GHOST pipeline in one minute on CPU.

1. Generate a synthetic citation graph (Table-2-style stats).
2. Train a GCN in fp32 (edge-list backend).
3. Quantize to the photonic 8-bit sign-split format.
4. Serve through the GHOST blocked dataflow (V x N partitioning,
   zero-block skipping, Pallas block-SpMM kernel in interpret mode).
5. Estimate the photonic accelerator's latency/energy/GOPS/EPB with the
   paper's analytic performance model.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core import partition_graph, to_blocked
from repro.gnn import build_model
from repro.gnn.datasets import TABLE2, make_node_classification
from repro.gnn.train import eval_node_classifier, train_node_classifier
from repro.kernels import aggregate_blocked_kernel
from repro.photonic.perf import GhostConfig, GnnModelSpec, OrchFlags, simulate

# 1. a small citation-style graph
TABLE2["QuickStart"] = dict(nodes=500, edges=2500, features=96, labels=5,
                            graphs=1)
graph = make_node_classification("QuickStart", seed=0)
print(f"graph: {graph.num_nodes} nodes, {graph.num_edges} edges, "
      f"{graph.num_features} features")

# 2. train fp32
model = build_model("gcn", 96, 5, hidden=32)
params, _ = train_node_classifier(model, graph, steps=120, lr=0.02)
acc = eval_node_classifier(model, params, graph)
print(f"fp32 test accuracy: {acc:.3f}")

# 3 + 4. quantized serving through the blocked dataflow
g = graph.with_self_loops()
pg = partition_graph(g, v=20, n=20, edge_weights=g.gcn_edge_weights())
print(f"partition: {pg.stats.nonzero_tiles}/{pg.stats.total_tiles} tiles "
      f"({pg.stats.skipped_fraction:.0%} skipped as all-zero)")
featp = jnp.asarray(pg.pad_features(g.node_feat))
acc_q = eval_node_classifier(model, params, graph, quantized=True)
print(f"int8 (photonic sign-split) accuracy: {acc_q:.3f} "
      f"(delta {acc - acc_q:+.3f})")

# the Pallas kernel computes the aggregate stage
agg = aggregate_blocked_kernel(pg, featp, block_f=32, interpret=True)
print(f"pallas block_spmm output: {agg.shape}, "
      f"finite={bool(jnp.all(jnp.isfinite(agg)))}")

# 5. analytic hardware estimate at the paper's optimal config [20,20,18,7,17]
report = simulate(GnnModelSpec.gcn(96, 32, 5), graph, GhostConfig(),
                  OrchFlags(), "QuickStart")
print(report.pretty())
